"""Quickstart: design a communication-efficient mixing matrix for DFL
over a bandwidth-limited edge network, route its traffic, and price the
total training time — the paper's full pipeline in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ConvergenceConstants, design
from repro.net import (
    PAPER_MODEL_BYTES,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)


def main() -> None:
    # 1. The edge network: Roofnet-like mesh, 10 lowest-degree agents.
    underlay = roofnet_like(seed=0)
    overlay = build_overlay(underlay, lowest_degree_nodes(underlay, 10))

    # 2. What the overlay can learn about the underlay (Def. 1 / [17]).
    categories = compute_categories(overlay)
    print(f"categories: {len(categories.families)}, "
          f"C_min = {categories.min_capacity()/1e3:.0f} KB/s")

    # 3. Joint design: FMMD-WP mixing matrix + optimal overlay routing.
    constants = ConvergenceConstants(epsilon=0.05)
    for method in ("clique", "ring", "fmmd-wp"):
        out = design(
            method, categories, PAPER_MODEL_BYTES, 10,
            overlay=overlay, iterations=12, constants=constants,
        )
        print(
            f"{method:8s}: links={len(out.design.activated_links):2d} "
            f"rho={out.rho:.3f} tau={out.tau:8.1f}s "
            f"K(rho)={out.iterations_to_eps:8.1f} "
            f"total={out.total_time/3600:8.1f}h [{out.routing.method}]"
        )


if __name__ == "__main__":
    main()
