"""Elastic membership demo: agents leave AND join during training; each
event re-runs the paper's design on the new overlay and re-maps state.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_dpsgd_step, mixing, replicate_for_agents
from repro.net import build_overlay, lowest_degree_nodes, roofnet_like
from repro.runtime.fault_tolerance import (
    FaultToleranceController,
    grow_state,
)


def main() -> None:
    m = 8
    u = roofnet_like(seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, m))
    ftc = FaultToleranceController(ov, kappa=1e6)

    # toy objective: agents pull their value to per-agent targets
    targets = jnp.arange(m, dtype=jnp.float32)[:, None]
    loss_fn = lambda p, b: jnp.mean((p["x"] - b) ** 2)
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.05)
    params = {"x": jnp.zeros((m, 1))}
    from repro.launch.fabric import design_mixing_matrix

    w, design0 = design_mixing_matrix(m, kappa_bytes=1e6)
    print(f"start: m={m} rho={mixing.rho(w):.3f}")

    for k in range(240):
        params, loss = step_fn(
            params, targets[: params["x"].shape[0]],
            jnp.asarray(w, jnp.float32), jnp.asarray(k),
        )
        if k == 80:
            params, w, _ = ftc.handle_failures((1, 5), params, step=k)
            print(f"[{k}] agents 1,5 failed -> m={w.shape[0]} "
                  f"rho={mixing.rho(w):.3f}")
        if k == 160:
            new_m = w.shape[0] + 2
            params = grow_state(params, new_m)
            # rejoin: design for the enlarged membership
            from repro.runtime.fault_tolerance import redesign_after_failure

            alive = tuple(range(new_m))
            w, _, _ = redesign_after_failure(ov, alive, kappa=1e6)
            print(f"[{k}] 2 agents joined -> m={new_m} "
                  f"rho={mixing.rho(w):.3f}")
    print(f"final values: {np.asarray(params['x']).ravel().round(2)}")
    print(f"events: {[(e.step, e.failed) for e in ftc.events]}")


if __name__ == "__main__":
    main()
