"""Elastic membership demo on the design-as-a-service loop: training
continues while a replayed event stream degrades links, drops agents,
and adds one — and one redesign happens during a *pricing outage*
(injected fault), exercising the incumbent-keep degradation tier
instead of crashing the run.

    PYTHONPATH=src python examples/elastic_failover.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import make_dpsgd_step, mixing
from repro.net import build_overlay, lowest_degree_nodes, roofnet_like
from repro.runtime.design_service import DesignService, ServiceConfig
from repro.runtime.events import AgentJoin, AgentLeave, LinkStateChange
from repro.runtime.fault_tolerance import grow_state, shrink_state
from repro.runtime.faultinject import FaultInjector, FaultPlan


def main() -> None:
    m = 8
    u = roofnet_like(seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, m))
    svc = DesignService(
        ov, kappa=1e6, config=ServiceConfig(design_iterations=12)
    )
    print(f"start: m={svc.num_agents} rho={mixing.rho(svc.design):.3f} "
          f"tau={svc.tau:.3g}s")

    # The replayed stream: capacities sag, two agents depart (the first
    # while the pricing service is down), one joins, the links recover.
    worst = sorted(svc._binc.edges)[:3]
    free_node = next(
        n for n in sorted(u.graph.nodes) if n not in set(ov.agents)
    )
    events = [
        LinkStateChange(time=1.0, scales={e: 0.3 for e in worst}),
        AgentLeave(time=2.0, agent=1),   # pricing outage active here
        AgentLeave(time=3.0, agent=5),
        AgentJoin(time=4.0, node=free_node),
        LinkStateChange(time=5.0, scales={e: 1.0 for e in worst}),
    ]
    outage_at = 2.0  # every pricing attempt raises while processing this

    # toy objective: agents pull their value to per-agent targets
    targets = jnp.arange(16, dtype=jnp.float32)[:, None]
    loss_fn = lambda p, b: jnp.mean((p["x"] - b) ** 2)
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.05)
    params = {"x": jnp.zeros((m, 1))}

    k = 0
    for ev in events:
        for _ in range(40):  # train between events
            cur_m = params["x"].shape[0]
            params, _ = step_fn(
                params, targets[:cur_m],
                jnp.asarray(svc.design, jnp.float32), jnp.asarray(k),
            )
            k += 1
        if ev.time == outage_at:
            svc.injector = FaultInjector(
                FaultPlan(seed=0, rate=1.0, modes=("raise",)),
                clock=svc.clock,
            )
        members_before = svc.members
        rec = svc.process(ev)
        svc.injector = None
        # re-map the stacked state to the new membership
        if isinstance(ev, AgentLeave) and svc.members != members_before:
            keep = tuple(
                p for p, h in enumerate(members_before)
                if h in set(svc.members)
            )
            params = shrink_state(params, keep, len(members_before))
        elif isinstance(ev, AgentJoin) and svc.members != members_before:
            params = grow_state(params, svc.num_agents)
        print(
            f"[step {k}] {rec.event}: {rec.decision} ({rec.tier}) "
            f"m={svc.num_agents} rho={mixing.rho(svc.design):.3f} "
            f"tau={svc.tau:.3g}s"
            + (f" retries={rec.retries} faults={len(rec.faults)}"
               if rec.faults else "")
            + f" -- {rec.detail}"
        )

    print(f"final values: {np.asarray(params['x']).ravel().round(2)}")
    print(f"decision trail: {svc.log.decisions}")
    print(f"tiers hit: {svc.log.tiers}")


if __name__ == "__main__":
    main()
