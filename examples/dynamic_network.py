"""Price topology designs under degraded, time-varying edge networks.

The paper evaluates designs on a static network; real edge deployments
see diurnal capacity swings, background traffic, stragglers, and churn.
This example designs mixing topologies on the paper's Roofnet-like
scenario and re-prices each one under a configurable ``Scenario``:

    PYTHONPATH=src python examples/dynamic_network.py \
        [--capacity-drop 0.5] [--local-drop 4] [--cross-flows 4] \
        [--stragglers 2] [--churn-agent 3] [--no-reroute] \
        [--stochastic] [--rollouts 5]

Columns: τ_static is the closed-form per-iteration time on the healthy
network; τ_scen the fluid-simulated makespan of the *static-optimal*
schedule under the degraded network; τ_phased the makespan of the
*phase-adaptive* schedule (``route_time_expanded`` — one routing per
capacity phase, swapped mid-round with per-branch volume carryover).
The win column is τ_scen / τ_phased: how much of the degradation the
schedule claws back by re-routing around where the bottlenecks actually
moved. ``--local-drop N`` degrades only the middle underlay hops of N
overlay links' default paths (the hops a re-route can avoid) instead of
every edge uniformly — a uniform drop moves no bottleneck, so there is
nothing for phase-adaptive routing to exploit there.

``--stochastic`` replaces the single deterministic scenario with a
Markov-modulated capacity process on the same local-drop edges
(persistent good↔degraded chain, ``StochasticScenario``) and prices
each design as a *seeded expectation* over ``--rollouts`` realizations.
The schedule column becomes the *online* re-router — deciding at every
realized phase boundary from observed state only, with the
carryover-aware objective — and the table reports E[τ] for both
schedules plus the online p95 tail.
"""

import argparse

import numpy as np

from repro.core import ConvergenceConstants, design
from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CrossTraffic,
    MarkovLinkModel,
    Scenario,
    StochasticScenario,
    StragglerEvent,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    mid_path_edges,
    roofnet_like,
)
from repro.runtime.fault_tolerance import failure_scenario


def build_scenario(args, overlay, tau_hint: float) -> Scenario:
    rng = np.random.default_rng(args.seed)
    phases = ()
    if args.capacity_drop < 1.0:
        if args.local_drop > 0:
            # Degrade the middle hops of a few neighboring-agent
            # overlay links' default paths — bottlenecks move, so
            # re-routing has somewhere to go (agent access edges are
            # spared; nothing avoids those). The sag persists for the
            # rest of the round: re-routing pays off when the phase it
            # adapts to actually lasts.
            m = overlay.num_agents
            drop = {
                e: args.capacity_drop
                for e in mid_path_edges(
                    overlay,
                    [(i, i + 1)
                     for i in range(min(args.local_drop, m - 1))],
                )
            }
            phases = (
                CapacityPhase(
                    start=tau_hint / 6,
                    scale=drop if drop else args.capacity_drop,
                ),
            )
        else:
            # Uniform sag a sixth of the way into the round, recovered
            # at two thirds — a bursty-interference profile. (Uniform
            # scaling moves no bottleneck, so phase-adaptive routing
            # has nothing to exploit here; use --local-drop for that.)
            phases = (
                CapacityPhase(start=tau_hint / 6,
                              scale=args.capacity_drop),
                CapacityPhase(start=2 * tau_hint / 3, scale=1.0),
            )
    nodes = list(overlay.underlay.graph.nodes)
    cross = tuple(
        CrossTraffic(
            src=int(rng.choice(nodes)),
            dst=int(rng.choice(nodes)),
            rate=args.cross_rate_mbps * 125_000.0,
        )
        for _ in range(args.cross_flows)
    )
    stragglers = tuple(
        StragglerEvent(
            agent=int(a), slowdown=args.straggler_slowdown,
            start=0.0, stop=tau_hint * 10,
        )
        for a in rng.choice(
            overlay.num_agents, size=args.stragglers, replace=False
        )
    )
    churn = ()
    if args.churn_agent >= 0:
        churn = failure_scenario(
            {args.churn_agent: tau_hint / 2}
        ).churn
    return Scenario(
        capacity_phases=phases, cross_traffic=cross,
        stragglers=stragglers, churn=churn,
    )


def build_stochastic(args, overlay, tau_hint: float) -> StochasticScenario:
    """Markov-modulated version of the local-drop degradation: the same
    mid-path hops, but sagging and recovering stochastically (persistent
    chain — mean sojourns of several boundaries), with the example's
    deterministic cross-traffic/stragglers/churn riding in ``base``."""
    m = overlay.num_agents
    edges = mid_path_edges(
        overlay,
        [(i, i + 1) for i in range(min(max(args.local_drop, 1), m - 1))],
    )
    base = build_scenario(
        argparse.Namespace(**{**vars(args), "capacity_drop": 1.0}),
        overlay, tau_hint,
    )
    return StochasticScenario(
        links=(MarkovLinkModel(
            edges=edges or ((0, 1),),
            scales=(1.0, args.capacity_drop if args.capacity_drop < 1.0
                    else 0.1),
            transition=((0.8, 0.2), (0.05, 0.95)),
        ),),
        step=0.5 * tau_hint,
        horizon=8 * tau_hint,
        base=base,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=10)
    ap.add_argument("--kappa-mb", type=float, default=94.47)
    ap.add_argument("--capacity-drop", type=float, default=0.1,
                    help="mid-round capacity multiplier (1.0 disables)")
    ap.add_argument("--local-drop", type=int, default=4,
                    help="degrade only the mid-path edges of this many "
                         "overlay links (0: degrade every edge)")
    ap.add_argument("--cross-flows", type=int, default=4)
    ap.add_argument("--cross-rate-mbps", type=float, default=0.3)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    ap.add_argument("--churn-agent", type=int, default=-1,
                    help="agent index that departs mid-round (-1: none)")
    ap.add_argument("--no-reroute", action="store_true",
                    help="skip the phase-adaptive schedule (static "
                         "pricing only, as in earlier revisions)")
    ap.add_argument("--stochastic", action="store_true",
                    help="Markov-modulate the local-drop edges and price "
                         "as a seeded expectation (online re-routing)")
    ap.add_argument("--rollouts", type=int, default=5,
                    help="realizations per design in --stochastic mode")
    ap.add_argument("--milp-time-limit", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    reroute = not args.no_reroute

    u = roofnet_like(seed=args.seed)
    ov = build_overlay(u, lowest_degree_nodes(u, args.agents))
    cats = compute_categories(ov)
    kappa = args.kappa_mb * 1e6
    consts = ConvergenceConstants(epsilon=0.05)

    print(
        f"roofnet-like nodes={u.num_nodes} links={u.num_links} "
        f"agents={args.agents} drop={args.capacity_drop} "
        f"local={args.local_drop} cross={args.cross_flows} "
        f"stragglers={args.stragglers} churn={args.churn_agent} "
        f"reroute={reroute} stochastic={args.stochastic}"
        + (f" rollouts={args.rollouts}" if args.stochastic else "")
    )
    header = f"{'method':8s} {'tau_static':>11s} "
    scen_col = "E[tau_scen]" if args.stochastic else "tau_scen"
    header += f"{scen_col:>11s} "
    if reroute:
        on_col = "E[tau_onl]" if args.stochastic else "tau_phased"
        header += f"{on_col:>11s} {'win':>6s} "
        if args.stochastic:
            header += f"{'p95_onl':>9s} "
    header += f"{'total_h':>9s} {'total_scen_h':>13s}"
    print(header)
    for method in ("ring", "clique", "fmmd-wp"):
        static = design(
            method, cats, kappa, args.agents, overlay=ov,
            constants=consts, optimize_routing=reroute,
            milp_time_limit=args.milp_time_limit,
        )
        if args.stochastic:
            sto = build_stochastic(args, ov, static.tau or 1.0)
            degraded = design(
                method, cats, kappa, args.agents, overlay=ov,
                constants=consts, optimize_routing=reroute,
                stochastic=sto, stochastic_rollouts=args.rollouts,
                stochastic_seed=args.seed,
                reroute_per_phase=reroute,
                milp_time_limit=args.milp_time_limit,
            )
        else:
            scenario = build_scenario(args, ov, static.tau or 1.0)
            degraded = design(
                method, cats, kappa, args.agents, overlay=ov,
                constants=consts, optimize_routing=reroute,
                scenario=scenario, reroute_per_phase=reroute,
                milp_time_limit=args.milp_time_limit,
            )
        row = f"{method:8s} {static.tau:11.1f} "
        if reroute:
            win = (
                degraded.tau_static_sched / degraded.tau_phased
                if degraded.tau_phased else float("nan")
            )
            row += (
                f"{degraded.tau_static_sched:11.1f} "
                f"{degraded.tau_phased:11.1f} {win:5.2f}x "
            )
            if args.stochastic:
                row += f"{degraded.tau_p95:9.1f} "
        else:
            row += f"{degraded.tau:11.1f} "
        row += (
            f"{static.total_time/3600:9.1f} "
            f"{degraded.total_time/3600:13.1f}"
        )
        print(row)


if __name__ == "__main__":
    main()
