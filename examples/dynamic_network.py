"""Price topology designs under degraded, time-varying edge networks.

The paper evaluates designs on a static network; real edge deployments
see diurnal capacity swings, background traffic, stragglers, and churn.
This example designs mixing topologies on the paper's Roofnet-like
scenario and re-prices each one under a configurable ``Scenario``:

    PYTHONPATH=src python examples/dynamic_network.py \
        [--capacity-drop 0.5] [--local-drop 4] [--cross-flows 4] \
        [--stragglers 2] [--churn-agent 3] [--no-reroute]

Columns: τ_static is the closed-form per-iteration time on the healthy
network; τ_scen the fluid-simulated makespan of the *static-optimal*
schedule under the degraded network; τ_phased the makespan of the
*phase-adaptive* schedule (``route_time_expanded`` — one routing per
capacity phase, swapped mid-round with per-branch volume carryover).
The win column is τ_scen / τ_phased: how much of the degradation the
schedule claws back by re-routing around where the bottlenecks actually
moved. ``--local-drop N`` degrades only the middle underlay hops of N
overlay links' default paths (the hops a re-route can avoid) instead of
every edge uniformly — a uniform drop moves no bottleneck, so there is
nothing for phase-adaptive routing to exploit there.
"""

import argparse

import numpy as np

from repro.core import ConvergenceConstants, design
from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CrossTraffic,
    Scenario,
    StragglerEvent,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)
from repro.runtime.fault_tolerance import failure_scenario


def build_scenario(args, overlay, tau_hint: float) -> Scenario:
    rng = np.random.default_rng(args.seed)
    phases = ()
    if args.capacity_drop < 1.0:
        if args.local_drop > 0:
            # Degrade the middle hops of a few neighboring-agent
            # overlay links' default paths — bottlenecks move, so
            # re-routing has somewhere to go (agent access edges are
            # spared; nothing avoids those). The sag persists for the
            # rest of the round: re-routing pays off when the phase it
            # adapts to actually lasts.
            m = overlay.num_agents
            drop: dict = {}
            for i in range(min(args.local_drop, m - 1)):
                for e in overlay.path_edges(i, i + 1)[1:-1]:
                    drop[(min(e), max(e))] = args.capacity_drop
            phases = (
                CapacityPhase(
                    start=tau_hint / 6,
                    scale=drop if drop else args.capacity_drop,
                ),
            )
        else:
            # Uniform sag a sixth of the way into the round, recovered
            # at two thirds — a bursty-interference profile. (Uniform
            # scaling moves no bottleneck, so phase-adaptive routing
            # has nothing to exploit here; use --local-drop for that.)
            phases = (
                CapacityPhase(start=tau_hint / 6,
                              scale=args.capacity_drop),
                CapacityPhase(start=2 * tau_hint / 3, scale=1.0),
            )
    nodes = list(overlay.underlay.graph.nodes)
    cross = tuple(
        CrossTraffic(
            src=int(rng.choice(nodes)),
            dst=int(rng.choice(nodes)),
            rate=args.cross_rate_mbps * 125_000.0,
        )
        for _ in range(args.cross_flows)
    )
    stragglers = tuple(
        StragglerEvent(
            agent=int(a), slowdown=args.straggler_slowdown,
            start=0.0, stop=tau_hint * 10,
        )
        for a in rng.choice(
            overlay.num_agents, size=args.stragglers, replace=False
        )
    )
    churn = ()
    if args.churn_agent >= 0:
        churn = failure_scenario(
            {args.churn_agent: tau_hint / 2}
        ).churn
    return Scenario(
        capacity_phases=phases, cross_traffic=cross,
        stragglers=stragglers, churn=churn,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=10)
    ap.add_argument("--kappa-mb", type=float, default=94.47)
    ap.add_argument("--capacity-drop", type=float, default=0.1,
                    help="mid-round capacity multiplier (1.0 disables)")
    ap.add_argument("--local-drop", type=int, default=4,
                    help="degrade only the mid-path edges of this many "
                         "overlay links (0: degrade every edge)")
    ap.add_argument("--cross-flows", type=int, default=4)
    ap.add_argument("--cross-rate-mbps", type=float, default=0.3)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    ap.add_argument("--churn-agent", type=int, default=-1,
                    help="agent index that departs mid-round (-1: none)")
    ap.add_argument("--no-reroute", action="store_true",
                    help="skip the phase-adaptive schedule (static "
                         "pricing only, as in earlier revisions)")
    ap.add_argument("--milp-time-limit", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    reroute = not args.no_reroute

    u = roofnet_like(seed=args.seed)
    ov = build_overlay(u, lowest_degree_nodes(u, args.agents))
    cats = compute_categories(ov)
    kappa = args.kappa_mb * 1e6
    consts = ConvergenceConstants(epsilon=0.05)

    print(
        f"roofnet-like nodes={u.num_nodes} links={u.num_links} "
        f"agents={args.agents} drop={args.capacity_drop} "
        f"local={args.local_drop} cross={args.cross_flows} "
        f"stragglers={args.stragglers} churn={args.churn_agent} "
        f"reroute={reroute}"
    )
    header = (
        f"{'method':8s} {'tau_static':>11s} {'tau_scen':>10s} "
    )
    if reroute:
        header += f"{'tau_phased':>11s} {'win':>6s} "
    header += f"{'total_h':>9s} {'total_scen_h':>13s}"
    print(header)
    for method in ("ring", "clique", "fmmd-wp"):
        static = design(
            method, cats, kappa, args.agents, overlay=ov,
            constants=consts, optimize_routing=reroute,
            milp_time_limit=args.milp_time_limit,
        )
        scenario = build_scenario(args, ov, static.tau or 1.0)
        degraded = design(
            method, cats, kappa, args.agents, overlay=ov,
            constants=consts, optimize_routing=reroute,
            scenario=scenario, reroute_per_phase=reroute,
            milp_time_limit=args.milp_time_limit,
        )
        row = f"{method:8s} {static.tau:11.1f} "
        if reroute:
            win = (
                degraded.tau_static_sched / degraded.tau_phased
                if degraded.tau_phased else float("nan")
            )
            row += (
                f"{degraded.tau_static_sched:10.1f} "
                f"{degraded.tau_phased:11.1f} {win:5.2f}x "
            )
        else:
            row += f"{degraded.tau:10.1f} "
        row += (
            f"{static.total_time/3600:9.1f} "
            f"{degraded.total_time/3600:13.1f}"
        )
        print(row)


if __name__ == "__main__":
    main()
