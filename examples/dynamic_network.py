"""Price topology designs under degraded, time-varying edge networks.

The paper evaluates designs on a static network; real edge deployments
see diurnal capacity swings, background traffic, stragglers, and churn.
This example designs mixing topologies on the paper's Roofnet-like
scenario and re-prices each one under a configurable ``Scenario``:

    PYTHONPATH=src python examples/dynamic_network.py \
        [--capacity-drop 0.5] [--cross-flows 4] [--stragglers 2] \
        [--churn-agent 3]

Columns: τ_static is the closed-form per-iteration time on the healthy
network; τ_scenario the fluid-simulated makespan under the degraded one;
the last columns show the projected total training time for both.
"""

import argparse

import numpy as np

from repro.core import ConvergenceConstants, design
from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CrossTraffic,
    Scenario,
    StragglerEvent,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)
from repro.runtime.fault_tolerance import failure_scenario


def build_scenario(args, overlay, tau_hint: float) -> Scenario:
    rng = np.random.default_rng(args.seed)
    phases = ()
    if args.capacity_drop < 1.0:
        # Capacity sags to `drop`× a third of the way into the round and
        # recovers at two thirds — a bursty-interference profile.
        phases = (
            CapacityPhase(start=tau_hint / 3, scale=args.capacity_drop),
            CapacityPhase(start=2 * tau_hint / 3, scale=1.0),
        )
    nodes = list(overlay.underlay.graph.nodes)
    cross = tuple(
        CrossTraffic(
            src=int(rng.choice(nodes)),
            dst=int(rng.choice(nodes)),
            rate=args.cross_rate_mbps * 125_000.0,
        )
        for _ in range(args.cross_flows)
    )
    stragglers = tuple(
        StragglerEvent(
            agent=int(a), slowdown=args.straggler_slowdown,
            start=0.0, stop=tau_hint * 10,
        )
        for a in rng.choice(
            overlay.num_agents, size=args.stragglers, replace=False
        )
    )
    churn = ()
    if args.churn_agent >= 0:
        churn = failure_scenario(
            {args.churn_agent: tau_hint / 2}
        ).churn
    return Scenario(
        capacity_phases=phases, cross_traffic=cross,
        stragglers=stragglers, churn=churn,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=10)
    ap.add_argument("--kappa-mb", type=float, default=94.47)
    ap.add_argument("--capacity-drop", type=float, default=0.5,
                    help="mid-round capacity multiplier (1.0 disables)")
    ap.add_argument("--cross-flows", type=int, default=4)
    ap.add_argument("--cross-rate-mbps", type=float, default=0.3)
    ap.add_argument("--stragglers", type=int, default=2)
    ap.add_argument("--straggler-slowdown", type=float, default=4.0)
    ap.add_argument("--churn-agent", type=int, default=-1,
                    help="agent index that departs mid-round (-1: none)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    u = roofnet_like(seed=args.seed)
    ov = build_overlay(u, lowest_degree_nodes(u, args.agents))
    cats = compute_categories(ov)
    kappa = args.kappa_mb * 1e6
    consts = ConvergenceConstants(epsilon=0.05)

    print(
        f"roofnet-like nodes={u.num_nodes} links={u.num_links} "
        f"agents={args.agents} drop={args.capacity_drop} "
        f"cross={args.cross_flows} stragglers={args.stragglers} "
        f"churn={args.churn_agent}"
    )
    print(
        f"{'method':8s} {'tau_static':>11s} {'tau_scen':>10s} "
        f"{'slowdown':>9s} {'total_h':>9s} {'total_scen_h':>13s}"
    )
    for method in ("ring", "clique", "fmmd-wp"):
        static = design(
            method, cats, kappa, args.agents, overlay=ov,
            constants=consts, optimize_routing=False,
        )
        scenario = build_scenario(args, ov, static.tau or 1.0)
        degraded = design(
            method, cats, kappa, args.agents, overlay=ov,
            constants=consts, optimize_routing=False, scenario=scenario,
        )
        slow = degraded.tau / static.tau if static.tau else float("nan")
        print(
            f"{method:8s} {static.tau:11.1f} {degraded.tau:10.1f} "
            f"{slow:8.2f}x {static.total_time/3600:9.1f} "
            f"{degraded.total_time/3600:13.1f}"
        )


if __name__ == "__main__":
    main()
