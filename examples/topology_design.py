"""Design-space exploration CLI: compare all design methods on a chosen
underlay and report the full Table-I-style summary.

    PYTHONPATH=src python examples/topology_design.py --underlay roofnet \
        --agents 10 --kappa-mb 94.47 [--routing]
"""

import argparse

from repro.core import ConvergenceConstants, design
from repro.net import (
    build_overlay,
    compute_categories,
    grid_underlay,
    lowest_degree_nodes,
    random_geometric_underlay,
    roofnet_like,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--underlay", default="roofnet",
                    choices=["roofnet", "grid", "geometric"])
    ap.add_argument("--agents", type=int, default=10)
    ap.add_argument("--kappa-mb", type=float, default=94.47)
    ap.add_argument("--iterations", type=int, default=12)
    ap.add_argument("--routing", action="store_true",
                    help="solve optimal overlay routing (slower)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.underlay == "roofnet":
        u = roofnet_like(seed=args.seed)
    elif args.underlay == "grid":
        u = grid_underlay(6, 7)
    else:
        u = random_geometric_underlay(40, seed=args.seed)
    ov = build_overlay(u, lowest_degree_nodes(u, args.agents))
    cats = compute_categories(ov)
    kappa = args.kappa_mb * 1e6
    consts = ConvergenceConstants(epsilon=0.05)

    print(f"underlay={args.underlay} nodes={u.num_nodes} links={u.num_links} "
          f"agents={args.agents} categories={len(cats.families)}")
    print(f"{'method':8s} {'links':>5s} {'rho':>7s} {'tau_bar':>9s} "
          f"{'tau':>9s} {'K(rho)':>10s} {'total_h':>9s} {'design_s':>9s}")
    for method in ("clique", "ring", "prim", "sca", "fmmd-wp"):
        out = design(method, cats, kappa, args.agents, overlay=ov,
                     iterations=args.iterations, constants=consts,
                     optimize_routing=args.routing)
        print(
            f"{method:8s} {len(out.design.activated_links):5d} "
            f"{out.rho:7.4f} {out.tau_bar:9.1f} {out.tau:9.1f} "
            f"{out.iterations_to_eps:10.1f} {out.total_time/3600:9.1f} "
            f"{out.design.design_seconds:9.2f}"
        )


if __name__ == "__main__":
    main()
