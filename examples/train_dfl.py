"""End-to-end driver: decentralized training of a ~100M-parameter LM with
D-PSGD, the designed mixing matrix, non-IID data, checkpointing, and
fault injection (one agent dies mid-run; the mixing matrix is re-designed
on the survivors, the charged τ switches to the new design's, and
training continues) — every gossip round charged its *simulated*
network time.

    PYTHONPATH=src python examples/train_dfl.py [--steps 300] [--agents 8]
        [--pricing static|phased|stochastic] [--engine batched|jax]
        [--gossip-rounds 1] [--prox-mu 0.0] [--log-json out.json]

Pricing models (see docs/priced-training.md):
  static     — every round costs the design's routed τ.
  phased     — a mid-run capacity sag (25% on the overlay's mid-path
               hops at --degrade-at wall-seconds); round k is priced
               under the phase active at its wall-clock start.
  stochastic — Markov-modulated mid-path hops; per-round τ cycles the
               seeded rollout samples (one XLA launch with
               --engine jax).

This runs the REAL model substrate (xlstm-125m-class config reduced to
CPU-feasible width by --width-scale) through the simulation-mode D-PSGD
trainer. On a pod, the same design feeds repro.launch.train instead.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step
from repro.configs.base import ModelConfig
from repro.core import (
    ConvergenceConstants,
    GossipStrategy,
    design,
    evaluate_design,
    make_dpsgd_step,
    mixing,
    pricer_for,
    replicate_for_agents,
    train_priced,
)
from repro.core.fmmd import FMMDResult
from repro.data import DataConfig, SyntheticTokenStream
from repro.models import model as M
from repro.net import (
    CapacityPhase,
    MarkovLinkModel,
    Scenario,
    StochasticScenario,
    activated_links_from_matrix,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    mid_path_edges,
    roofnet_like,
)
from repro.runtime.fault_tolerance import FaultToleranceController

CONSTANTS = ConvergenceConstants(epsilon=0.05)


def build_model(width_scale: float) -> ModelConfig:
    d = max(64, int(768 * width_scale))
    return ModelConfig(
        name="dfl-lm",
        family="dense",
        num_layers=4,
        d_model=d,
        num_heads=4,
        num_kv_heads=2,
        d_ff=4 * d,
        vocab_size=8192,
        block_pattern=("attn",),
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )


def outcome_from_matrix(w, cats, kappa, m, overlay):
    """Price an externally produced mixing matrix (the fault-tolerance
    redesign) through the same evaluate_design path as a fresh design."""
    d = FMMDResult(
        matrix=np.asarray(w, dtype=np.float64),
        activated_links=tuple(activated_links_from_matrix(w)),
        rho=mixing.rho(np.asarray(w, dtype=np.float64)),
        rho_trajectory=(),
        selected_atoms=(),
        design_seconds=0.0,
        variant="fmmd-wp-redesign",
    )
    return evaluate_design(d, cats, kappa, m, CONSTANTS, overlay=overlay)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--width-scale", type=float, default=0.25)
    ap.add_argument("--fail-agent-at", type=int, default=60)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--pricing", default="static",
                    choices=("static", "phased", "stochastic"))
    ap.add_argument("--engine", default="batched",
                    help="simulate engine for pricing (jax = one-launch "
                         "stochastic rollouts)")
    ap.add_argument("--degrade-at", type=float, default=None,
                    help="phased pricing: wall-seconds at which mid-path "
                         "hops sag to 25%% (default: 3 rounds in)")
    ap.add_argument("--rollouts", type=int, default=32)
    ap.add_argument("--gossip-rounds", type=int, default=1,
                    help=">1 = multi-round graph gossip (W^r per update, "
                         "r priced rounds)")
    ap.add_argument("--prox-mu", type=float, default=0.0,
                    help="FedProx-style proximal coefficient (non-IID "
                         "drift damping)")
    ap.add_argument("--log-json", default=None,
                    help="write the replayable per-round τ log here")
    args = ap.parse_args()

    m = args.agents
    cfg = build_model(args.width_scale)
    print(f"model: {M.parameter_count(cfg)/1e6:.1f}M params")

    underlay = roofnet_like(seed=0)
    overlay = build_overlay(underlay, lowest_degree_nodes(underlay, m))
    cats = compute_categories(overlay)
    kappa = M.parameter_count(cfg) * 4  # fp32 payload
    out = design("fmmd-wp", cats, kappa, m, overlay=overlay, iterations=12,
                 constants=CONSTANTS)
    print(f"design: rho={out.rho:.3f} tau={out.tau:.1f}s "
          f"links={len(out.design.activated_links)}")

    # --- pricing model -----------------------------------------------------
    scenario = None
    sto = None
    if args.pricing == "phased":
        t_sag = (
            args.degrade_at if args.degrade_at is not None else 3 * out.tau
        )
        hops = mid_path_edges(overlay, out.design.activated_links)
        scenario = Scenario(capacity_phases=(
            CapacityPhase(start=t_sag,
                          scale={e: 0.25 for e in hops}),
        ))
        print(f"phased pricing: {len(hops)} mid-path hops sag to 25% "
              f"at t={t_sag:.0f}s")
    elif args.pricing == "stochastic":
        hops = mid_path_edges(overlay, out.design.activated_links)
        sto = StochasticScenario(
            links=(MarkovLinkModel(
                edges=tuple(hops), scales=(1.0, 0.2),
                transition=((0.8, 0.2), (0.3, 0.7)),
            ),),
            step=max(out.tau / 2, 1.0), horizon=8 * max(out.tau, 1.0),
        )
        print(f"stochastic pricing: {len(hops)} Markov-modulated hops, "
              f"{args.rollouts} rollouts, engine={args.engine}")

    def make_pricer(outcome, ov):
        return pricer_for(
            outcome, mode=args.pricing, overlay=ov,
            scenario=scenario, stochastic=sto, rollouts=args.rollouts,
            engine=args.engine,
            reduce="sample" if args.pricing == "stochastic" else "mean",
        )

    # --- data / step / state ----------------------------------------------
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   num_agents=m, dirichlet_alpha=0.3, seed=1)
    )
    loss_fn = lambda p, b: M.loss(cfg, p, {"tokens": b}, remat=False)[0]
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.05,
                              prox_mu=args.prox_mu)
    params = replicate_for_agents(M.init(cfg, jax.random.key(0)), m)

    ftc = FaultToleranceController(overlay, kappa)
    ckdir = tempfile.mkdtemp(prefix="dfl_ckpt_")
    ck = AsyncCheckpointer(ckdir, keep=2)

    live = {"m": m}

    def batcher(k):
        return jnp.asarray(
            np.stack([
                stream.batch(a % stream.cfg.num_agents, k, args.batch,
                             args.seq)
                for a in range(live["m"])
            ])
        )

    def intervene(k, params):
        """Failure injection: shrink the state, redesign on the
        survivors, and hand the trainer the new design's pricer — the
        charged τ switches on this very round."""
        if k == args.fail_agent_at and live["m"] > 2:
            print(f"[step {k}] injecting failure of agent 2")
            params, w, _ = ftc.handle_failures((2,), params, step=k)
            live["m"] -= 1
            surviving = build_overlay(
                underlay, [overlay.agents[a] for a in ftc.alive]
            )
            cats2 = compute_categories(surviving)
            out2 = outcome_from_matrix(w, cats2, kappa, live["m"], surviving)
            print(f"redesign: rho={out2.rho:.3f} tau={out2.tau:.1f}s")
            return params, ("fmmd-wp-redesign", out2.design.matrix,
                            make_pricer(out2, surviving))
        if k % args.ckpt_every == 0 and k > 0:
            ck.save(k, {"params": params, "step": jnp.asarray(k)})
        return params, None

    t_start = time.time()
    params, log = train_priced(
        params, step_fn, batcher, out.design.matrix,
        make_pricer(out, overlay),
        num_steps=args.steps,
        strategy=GossipStrategy(rounds=args.gossip_rounds),
        design_label=out.name, intervene=intervene, log_every=20,
    )
    log.validate()
    ck.wait()

    for r in log.records:
        if r.step % 20 == 0 or r.step == args.steps - 1:
            print(
                f"step {r.step:4d} loss={r.loss:.4f} "
                f"consensus={r.consensus:.2e} design={r.design} "
                f"tau={r.tau:.1f}s [{r.pricing}] "
                f"modeled_wall={r.wall_clock/3600:.2f}h"
            )
    print(f"done in {time.time()-t_start:.0f}s wall; modeled "
          f"{log.total_wall/3600:.2f}h network time over "
          f"{len(log.records)} steps; checkpoints at {ckdir} "
          f"(latest step {latest_step(ckdir)})")
    if args.log_json:
        with open(args.log_json, "w") as f:
            f.write(log.to_json())
        print(f"replayable per-round τ log: {args.log_json}")


if __name__ == "__main__":
    main()
