"""End-to-end driver: decentralized training of a ~100M-parameter LM with
D-PSGD, the designed mixing matrix, non-IID data, checkpointing, and
fault injection (one agent dies mid-run; the mixing matrix is re-designed
on the survivors and training continues).

    PYTHONPATH=src python examples/train_dfl.py [--steps 300] [--agents 8]

This runs the REAL model substrate (xlstm-125m-class config reduced to
CPU-feasible width by --width-scale) through the simulation-mode D-PSGD
trainer. On a pod, the same design feeds repro.launch.train instead.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, restore, latest_step
from repro.configs.base import ModelConfig
from repro.core import (
    ConvergenceConstants,
    design,
    make_dpsgd_step,
    replicate_for_agents,
)
from repro.core.dpsgd import consensus_distance
from repro.data import DataConfig, SyntheticTokenStream
from repro.models import model as M
from repro.net import (
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)
from repro.runtime.fault_tolerance import FaultToleranceController


def build_model(width_scale: float) -> ModelConfig:
    d = max(64, int(768 * width_scale))
    return ModelConfig(
        name="dfl-lm",
        family="dense",
        num_layers=4,
        d_model=d,
        num_heads=4,
        num_kv_heads=2,
        d_ff=4 * d,
        vocab_size=8192,
        block_pattern=("attn",),
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--width-scale", type=float, default=0.25)
    ap.add_argument("--fail-agent-at", type=int, default=60)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    m = args.agents
    cfg = build_model(args.width_scale)
    print(f"model: {M.parameter_count(cfg)/1e6:.1f}M params")

    underlay = roofnet_like(seed=0)
    overlay = build_overlay(underlay, lowest_degree_nodes(underlay, m))
    cats = compute_categories(overlay)
    kappa = M.parameter_count(cfg) * 4  # fp32 payload
    out = design("fmmd-wp", cats, kappa, m, iterations=12,
                 constants=ConvergenceConstants(epsilon=0.05))
    w = out.design.matrix
    print(f"design: rho={out.rho:.3f} tau={out.tau:.1f}s "
          f"links={len(out.design.activated_links)}")

    stream = SyntheticTokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   num_agents=m, dirichlet_alpha=0.3, seed=1)
    )
    loss_fn = lambda p, b: M.loss(cfg, p, {"tokens": b}, remat=False)[0]
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.05)
    params = replicate_for_agents(M.init(cfg, jax.random.key(0)), m)

    ftc = FaultToleranceController(overlay, kappa)
    ckdir = tempfile.mkdtemp(prefix="dfl_ckpt_")
    ck = AsyncCheckpointer(ckdir, keep=2)
    wall = 0.0
    t_start = time.time()
    for k in range(args.steps):
        if k == args.fail_agent_at and m > 2:
            print(f"[step {k}] injecting failure of agent 2")
            params, w, _ = ftc.handle_failures((2,), params, step=k)
            m -= 1
            out = None  # tau now stale; keep modeled wall unchanged
        batch = jnp.asarray(
            np.stack([
                stream.batch(a % stream.cfg.num_agents, k, args.batch,
                             args.seq)
                for a in range(m)
            ])
        )
        params, loss = step_fn(params, batch, jnp.asarray(w, jnp.float32),
                               jnp.asarray(k))
        wall += out.tau if out else 0.0
        if k % args.ckpt_every == 0:
            ck.save(k, {"params": params, "step": jnp.asarray(k)})
        if k % 20 == 0 or k == args.steps - 1:
            print(
                f"step {k:4d} loss={float(loss):.4f} "
                f"consensus={float(consensus_distance(params)):.2e} "
                f"agents={m} modeled_wall={wall/3600:.2f}h"
            )
    ck.wait()
    print(f"done in {time.time()-t_start:.0f}s wall; "
          f"checkpoints at {ckdir} (latest step {latest_step(ckdir)})")


if __name__ == "__main__":
    main()
