"""Serving example: batched decode with KV caches through the real model
substrate (smoke-sized config on CPU; the same code path the decode_32k
dry-run cells lower for TPU).

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = M.init(cfg, jax.random.key(0))
    prompt = {
        "tokens": jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size,
        )
    }
    if cfg.frontend == "vision_patches":
        prompt["patch_embeds"] = jax.random.normal(
            jax.random.key(2),
            (args.batch, cfg.num_patches, cfg.d_model), jnp.float32,
        )
    max_len = args.prompt_len + args.new_tokens + (
        cfg.num_patches if cfg.frontend == "vision_patches" else 0
    )
    t0 = time.time()
    logits, caches = M.prefill(cfg, params, prompt, max_len=max_len)
    print(f"prefill {args.prompt_len} tokens x {args.batch}: "
          f"{time.time()-t0:.2f}s")

    decode = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, t))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, caches = decode(params, caches, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(outs, axis=1)
    print(f"decoded {args.new_tokens} tokens x {args.batch} in {dt:.2f}s "
          f"({args.new_tokens*args.batch/dt:.1f} tok/s)")
    print("sample:", seq[0].tolist())


if __name__ == "__main__":
    main()
