import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing


@given(
    m=st.integers(3, 12),
    seed=st.integers(0, 1000),
)
@settings(max_examples=30, deadline=None)
def test_matrix_from_weights_is_valid_mixing(m, seed):
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.5
    ]
    alpha = rng.normal(0, 0.3, len(links))
    w = mixing.matrix_from_weights(m, links, alpha)
    mixing.validate_mixing(w)  # symmetric, rows sum to one
    # round trip
    links2, alpha2 = mixing.weights_from_matrix(w)
    w2 = mixing.matrix_from_weights(m, links2, alpha2)
    np.testing.assert_allclose(w, w2, atol=1e-12)


def test_rho_of_ideal_matrix_is_zero():
    assert mixing.rho(mixing.ideal_matrix(7)) == pytest.approx(0.0, abs=1e-12)


def test_rho_of_identity_is_one():
    assert mixing.rho(np.eye(5)) == pytest.approx(1.0)


@given(m=st.integers(3, 10), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_lemma_3_4_decomposition(m, seed):
    """Any mixing matrix = (1−Σα)I + Σ α_ij S^(i,j)."""
    rng = np.random.default_rng(seed)
    links = [(i, j) for i in range(m) for j in range(i + 1, m)]
    alpha = rng.normal(0, 0.2, len(links))
    w = mixing.matrix_from_weights(m, links, alpha)
    recon = (1 - alpha.sum()) * np.eye(m)
    for (i, j), a in zip(links, alpha):
        recon += a * mixing.swapping_matrix(m, i, j)
    np.testing.assert_allclose(w, recon, atol=1e-12)


def test_rho_gradient_is_unit_rank_one():
    rng = np.random.default_rng(0)
    links = [(0, 1), (1, 2), (2, 3)]
    w = mixing.matrix_from_weights(4, links, [0.3, 0.2, 0.4])
    g = mixing.rho_gradient(w)
    assert np.linalg.matrix_rank(g, tol=1e-8) == 1
    assert np.linalg.norm(g, 2) == pytest.approx(1.0)


def test_iterations_to_converge_monotone_in_rho():
    ks = [mixing.iterations_to_converge(r, 10) for r in (0.1, 0.5, 0.9, 0.99)]
    assert all(a < b for a, b in zip(ks, ks[1:]))
    assert mixing.iterations_to_converge(1.0, 10) == np.inf


@given(m=st.integers(2, 12), seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_fw_step_bitwise_matches_dense_formula(m, seed):
    """Property: the in-place Frank-Wolfe step equals forming the atom
    densely and evaluating (1−γ)·W + γ·S — bitwise, at every point of a
    random FW trajectory (identity and swapping atoms interleaved)."""
    rng = np.random.default_rng(seed)
    w = np.eye(m)
    for k in range(int(rng.integers(1, 25))):
        gamma = 2.0 / (k + 2.0)
        if rng.random() < 0.2:
            atom, s = None, np.eye(m)
        else:
            i, j = sorted(int(x) for x in rng.choice(m, 2, replace=False))
            atom, s = (i, j), mixing.swapping_matrix(m, i, j)
        dense = (1.0 - gamma) * w + gamma * s
        mixing.fw_step(w, gamma, atom)
        assert np.array_equal(w, dense)
    mixing.validate_mixing(w)
