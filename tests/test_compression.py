import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compression import (
    ErrorFeedback,
    compressed_kappa,
    int8_compress,
    randk_compress,
    topk_compress,
)


def _tree(seed=0, n=1024):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (n,)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (32, 16))}


def test_topk_keeps_largest():
    t = _tree()
    c = topk_compress(t, fraction=0.1)
    d = c.decode()
    # decoded entries are either 0 or exact originals
    for key in t:
        orig, dec = np.asarray(t[key]), np.asarray(d[key])
        nz = dec != 0
        np.testing.assert_allclose(dec[nz], orig[nz])
        # kept fraction ≈ requested
        assert abs(nz.mean() - 0.1) < 0.05
        # smallest kept |value| >= largest dropped |value|
        if nz.any() and (~nz).any():
            assert np.abs(orig[nz]).min() >= np.abs(orig[~nz]).max() - 1e-6


def test_randk_unbiased():
    t = {"a": jnp.ones((512,))}
    est = np.zeros(512)
    reps = 64
    for s in range(reps):
        est += np.asarray(randk_compress(t, 0.25, seed=s).decode()["a"])
    est /= reps
    assert abs(est.mean() - 1.0) < 0.15


def test_int8_roundtrip_error_bounded():
    t = _tree(2)
    d = int8_compress(t).decode()
    for key in t:
        orig = np.asarray(t[key])
        err = np.abs(np.asarray(d[key]) - orig).max()
        assert err <= np.abs(orig).max() / 127.0 + 1e-6


def test_error_feedback_accumulates_residual():
    ef = ErrorFeedback()
    g = {"a": jnp.asarray([1.0, 0.1, 0.1, 0.1])}
    c1 = ef.step(g, lambda t: topk_compress(t, 0.25))
    # residual holds the dropped mass
    assert float(jnp.sum(jnp.abs(ef.residual["a"]))) == pytest.approx(0.3)
    # second round: residual + new grads pushes small coords through
    c2 = ef.step(g, lambda t: topk_compress(t, 0.25))
    assert c2.nbytes == c1.nbytes


def test_compressed_kappa_consistency():
    t = _tree(3)
    full = compressed_kappa(t, "none")
    tk = compressed_kappa(t, "topk", fraction=0.01)
    q8 = compressed_kappa(t, "int8")
    assert tk < q8 < full
