"""Parallel (train) vs recurrent (decode) equivalence for every mixer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _roll(decode_fn, params, x, state0):
    outs = []
    state = state0
    for t in range(x.shape[1]):
        o, state = decode_fn(params, x[:, t : t + 1], state)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def test_mamba_parallel_equals_recurrent():
    spec = ssm.MambaSpec(d_model=16, d_state=4, d_conv=3, expand=2)
    params = ssm.mamba_init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 12, 16))
    y_par = ssm.mamba_apply_train(params, x, spec, jnp.float32)
    y_rec = _roll(
        lambda p, xt, s: ssm.mamba_apply_decode(p, xt, s, spec, jnp.float32),
        params, x, ssm.mamba_init_state(2, spec, jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_parallel_equals_recurrent():
    spec = ssm.MLSTMSpec(d_model=16, num_heads=2)
    params = ssm.mlstm_init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 10, 16))
    y_par = ssm.mlstm_apply_train(params, x, spec, jnp.float32)
    y_rec = _roll(
        lambda p, xt, s: ssm.mlstm_apply_decode(p, xt, s, spec, jnp.float32),
        params, x, ssm.mlstm_init_state(2, spec, jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=1e-3, atol=1e-3)


def test_slstm_train_equals_stepping():
    spec = ssm.SLSTMSpec(d_model=12, num_heads=2)
    params = ssm.slstm_init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 9, 12))
    y_scan = ssm.slstm_apply_train(params, x, spec, jnp.float32)
    y_step = _roll(
        lambda p, xt, s: ssm.slstm_apply_decode(p, xt, s, spec, jnp.float32),
        params, x, ssm.slstm_init_state(2, spec, jnp.float32),
    )
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-5, atol=1e-5)


def test_mamba_gradients_finite():
    spec = ssm.MambaSpec(d_model=8, d_state=4, d_conv=2, expand=2)
    params = ssm.mamba_init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 8))
    g = jax.grad(
        lambda p: jnp.sum(
            ssm.mamba_apply_train(p, x, spec, jnp.float32) ** 2
        )
    )(params)
    assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
