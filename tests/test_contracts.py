"""Runtime CSR contract layer (`repro.analysis.contracts`).

Corrupted-CSR fixtures — non-monotone ptr, out-of-bounds index, wrong
dtype, mismatched lengths — must each raise a named, actionable
``ContractViolation`` at construction under ``REPRO_VALIDATE=1``, and
pass silently when validation is off. Well-formed structures from the
real pipeline must validate clean on all three contract classes.
"""

import dataclasses

import numpy as np
import pytest

from repro.analysis.contracts import (
    ContractViolation,
    maybe_validate,
    validation_enabled,
)
from repro.net import (
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)
from repro.net.categories import compile_category_incidence
from repro.net.demands import demands_from_links
from repro.net.routing import route
from repro.net.simulator import CapacityPhase, Scenario, compile_incidence, simulate

KAPPA = 1e6
M = 6
LINKS = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]


@pytest.fixture()
def validate_on(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE", "1")


@pytest.fixture()
def validate_off(monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)


@pytest.fixture(scope="module")
def pipeline():
    u = roofnet_like(seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, M))
    cats = compute_categories(ov)
    demands = demands_from_links(LINKS, KAPPA, M)
    sol = route(demands, cats, KAPPA, M)
    return ov, cats, sol


def test_validation_flag_semantics(monkeypatch):
    for value, expect in [("1", True), ("yes", True), ("0", False),
                          ("", False)]:
        monkeypatch.setenv("REPRO_VALIDATE", value)
        assert validation_enabled() is expect
    monkeypatch.delenv("REPRO_VALIDATE")
    assert validation_enabled() is False


def test_wellformed_pipeline_validates_clean(pipeline, validate_on):
    """All three structures, built by the real pipeline, pass under
    REPRO_VALIDATE=1 — including the rescaled per-phase recompile."""
    ov, cats, sol = pipeline
    assert cats.flat is not None
    maybe_validate(cats.flat)  # _FlatCategories
    inc = compile_category_incidence(cats, M, KAPPA)   # CategoryIncidence
    inc.rescaled(cats.scaled(0.5))                     # replace() path
    binc = compile_incidence(sol, ov)                  # BranchIncidence
    assert binc.num_branches > 0
    sc = Scenario(capacity_phases=(CapacityPhase(start=4.0, scale=0.5),))
    r = simulate(sol, ov, scenario=sc)
    assert np.isfinite(r.makespan)


def _cat_corruptions(inc):
    nnz = inc.entry_link.size
    return {
        "non-monotone ptr": (
            "link_ptr", np.concatenate((inc.link_ptr[:1] + nnz,
                                        inc.link_ptr[1:])), "ptr"),
        "out-of-bounds index": (
            "entry_cat", inc.entry_cat + inc.capacity.size, "index-bounds"),
        "wrong dtype": (
            "capacity", inc.capacity.astype(np.float32), "dtype"),
        "mismatched lengths": (
            "entry_coef", inc.entry_coef[:-1], "length"),
        "stale coefficients": (
            "entry_coef", inc.entry_coef * 2.0, "coef-consistency"),
        "non-positive capacity": (
            "capacity", inc.capacity * -1.0, "finite-positive"),
    }


def test_category_incidence_corruptions_raise_named(pipeline, validate_on):
    _, cats, _ = pipeline
    inc = compile_category_incidence(cats, M, KAPPA)
    for label, (field, bad, invariant) in _cat_corruptions(inc).items():
        with pytest.raises(ContractViolation) as err:
            dataclasses.replace(inc, **{field: bad})
        assert invariant in str(err.value), label
        assert field in str(err.value), label
        assert err.value.structure == "CategoryIncidence"


def test_category_incidence_corruptions_silent_when_off(
    pipeline, validate_off
):
    _, cats, _ = pipeline
    inc = compile_category_incidence(cats, M, KAPPA)
    for field, bad, _ in _cat_corruptions(inc).values():
        dataclasses.replace(inc, **{field: bad})  # must not raise


def test_branch_incidence_corruptions_raise_named(pipeline, validate_on):
    ov, _, sol = pipeline
    inc = compile_incidence(sol, ov)
    cases = {
        "non-monotone ptr": (
            "branch_ptr", inc.branch_ptr[::-1].copy(), "ptr"),
        "out-of-bounds edge": (
            "flat_edge", inc.flat_edge + inc.base_capacity.size,
            "index-bounds"),
        "wrong index dtype": (
            "flat_branch", inc.flat_branch.astype(np.int32), "dtype"),
        "mismatched lengths": (
            "edge_branch", inc.edge_branch[:-1], "length"),
        "float32 capacities": (
            "base_capacity", inc.base_capacity.astype(np.float32), "dtype"),
    }
    for label, (field, bad, invariant) in cases.items():
        with pytest.raises(ContractViolation) as err:
            dataclasses.replace(inc, **{field: bad})
        assert invariant in str(err.value), label
        assert err.value.structure == "BranchIncidence"


def test_branch_incidence_corruptions_silent_when_off(
    pipeline, validate_off
):
    ov, _, sol = pipeline
    inc = compile_incidence(sol, ov)
    dataclasses.replace(inc, branch_ptr=inc.branch_ptr[::-1].copy())
    dataclasses.replace(inc, flat_branch=inc.flat_branch.astype(np.int32))


def test_flat_categories_corruptions_raise_named(pipeline, validate_on):
    _, cats, _ = pipeline
    flat = cats.flat
    cases = {
        "non-monotone ptr": (
            "link_ptr", flat.link_ptr[::-1].copy(), "ptr"),
        "out-of-bounds category": (
            "entry_cat", flat.entry_cat + flat.num_categories,
            "index-bounds"),
        "wrong dtype": (
            "entry_link", flat.entry_link.astype(np.int32), "dtype"),
        "mismatched lengths": (
            "entry_cat", flat.entry_cat[:-1], "length"),
    }
    # Unsorted entries: swap two categories inside one multi-entry
    # link's CSR slice — everything else (bounds, dtypes, ptr) stays
    # valid, only the promised (link, category) sort order breaks.
    multi = np.flatnonzero(np.diff(flat.entry_link) == 0)
    assert multi.size, "fixture needs a link with >=2 categories"
    i = int(multi[0])
    swapped = flat.entry_cat.copy()
    swapped[i], swapped[i + 1] = swapped[i + 1], swapped[i]
    cases["unsorted entries"] = ("entry_cat", swapped, "entries-sorted")
    for label, (field, bad, invariant) in cases.items():
        with pytest.raises(ContractViolation) as err:
            dataclasses.replace(flat, **{field: bad})
        assert invariant in str(err.value), label
        assert err.value.structure == "_FlatCategories"


def test_ptr_entry_consistency_catches_shifted_entries(
    pipeline, validate_on
):
    """In-bounds, right-dtype, right-length — but the entry array no
    longer agrees with the pointer's slices: the mismatch incremental
    incidence *patching* would produce."""
    _, cats, _ = pipeline
    inc = compile_category_incidence(cats, M, KAPPA)
    rolled = np.roll(inc.entry_link, 1)
    with pytest.raises(ContractViolation) as err:
        dataclasses.replace(inc, entry_link=rolled)
    assert "ptr-entry-consistency" in str(err.value) or \
        "index-bounds" in str(err.value)


def _device(pipeline):
    ov, _, sol = pipeline
    from repro.net.jax_engine import device_incidence

    inc = compile_incidence(sol, ov)
    sizes = np.array([d.size for d in sol.demands], dtype=np.float64)
    return device_incidence(inc, sizes)


def _dev_corruptions(dev):
    nb = dev.num_branches
    live_pad = dev.sizes.copy()
    live_pad[nb:] = 1.0
    stale_cap = dev.base_capacity.copy()
    stale_cap[0] *= 2.0
    wide = np.hstack(
        (dev.branch_table,
         np.full((dev.branch_table.shape[0], 1), dev.num_edges,
                 dtype=np.int32))
    )
    mispacked = dev.edge_table.copy()
    mispacked[0, 0] = nb  # inert id where a real branch id belongs
    negative = dev.sizes.copy()
    negative[0] = -1.0
    return {
        "declared extents disagree": (
            "num_branches", nb + 1, "source-extents"),
        "non-power-of-two bucket": (
            "sizes", np.append(dev.sizes, 0.0), "padded-bucket"),
        "live padding tail": ("sizes", live_pad, "inert-padding"),
        "rewritten live prefix": (
            "base_capacity", stale_cap, "source-prefix"),
        "wrong table width": ("branch_table", wide, "table-shape"),
        "mispacked table row": ("edge_table", mispacked, "table-packing"),
        "negative demand size": ("sizes", negative, "finite-nonnegative"),
        "wrong index dtype": (
            "flat_branch", dev.flat_branch.astype(np.int32), "dtype"),
        "mismatched padded lengths": (
            "flat_edge", dev.flat_edge[:-1], "length"),
    }


def test_device_incidence_corruptions_raise_named(pipeline, validate_on):
    """Each padded-table invariant of `DeviceIncidence`, corrupted one
    field at a time via `dataclasses.replace`, raises its *named*
    violation. (`entries-sorted` cannot be tripped in isolation: the
    edge-major prefix must be bitwise the source's CSC order, which is
    ascending by construction, so `source-prefix` always fires first —
    the sorted-segment licence is subsumed by prefix equality.)"""
    dev = _device(pipeline)
    assert int(np.diff(dev.source.edge_ptr)[0]) >= 1  # row 0 is real
    for label, (field, bad, invariant) in _dev_corruptions(dev).items():
        with pytest.raises(ContractViolation) as err:
            dataclasses.replace(dev, **{field: bad})
        assert invariant in str(err.value), label
        assert err.value.structure == "DeviceIncidence", label


def test_device_incidence_corruptions_silent_when_off(
    pipeline, validate_off
):
    dev = _device(pipeline)
    for field, bad, _ in _dev_corruptions(dev).values():
        dataclasses.replace(dev, **{field: bad})  # must not raise


def test_device_incidence_wellformed_validates_clean(
    pipeline, validate_on
):
    dev = _device(pipeline)  # construction itself validates
    from repro.analysis.contracts import validate_device_incidence

    validate_device_incidence(dev)  # and so does an explicit call


def test_error_message_is_actionable(pipeline, validate_on):
    _, cats, _ = pipeline
    inc = compile_category_incidence(cats, M, KAPPA)
    with pytest.raises(ContractViolation) as err:
        dataclasses.replace(inc, capacity=inc.capacity.astype(np.float32))
    msg = str(err.value)
    assert "CategoryIncidence.capacity" in msg
    assert "float64" in msg  # says what well-formed looks like
