"""Multi-device coverage via subprocess (needs its own XLA device count)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.core import gossip
from repro.core.weight_opt import optimize_weights
from repro.launch.mesh import make_test_mesh
from repro.launch.train import build_train_artifacts
from repro.launch.fabric import design_mixing_matrix
from repro.configs.base import get_config, get_train_config, get_shape

# 1) sparse shard_map gossip == dense einsum
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
m = 4
links = [(0, 1), (1, 2), (2, 3), (0, 3)]
W = optimize_weights(m, links, steps=150).matrix
sched = gossip.build_schedule(W)
params = {"a": jax.random.normal(jax.random.key(0), (4, 8, 6))}
specs = {"a": P(("pod", "data"), None, "model")}
sharded = jax.device_put(
    params, {k: NamedSharding(mesh, s) for k, s in specs.items()}
)
dense = gossip.mix_dense(params, jnp.asarray(W))
with compat.set_mesh(mesh):
    sparse = gossip.mix_sparse_shardmap(sharded, sched, mesh,
                                        ("pod", "data"), specs)
err = float(jnp.max(jnp.abs(dense["a"] - sparse["a"])))
assert err < 1e-5, f"gossip mismatch {err}"

# 2) end-to-end distributed train step: loss decreases, ppermute in HLO
cfg = get_config("qwen2-0.5b", smoke=True)
tcfg = dataclasses.replace(get_train_config("qwen2-0.5b"), microbatch=2)
shape = dataclasses.replace(get_shape("train_4k"), seq_len=64,
                            global_batch=16)
mesh2 = make_test_mesh((4, 2), ("data", "model"))
W2, _ = design_mixing_matrix(4, pods=1, kappa_bytes=1e6)
with compat.set_mesh(mesh2):
    art = build_train_artifacts(cfg, tcfg, shape, mesh2, W2)
    compiled = art.jit(donate=False).lower(
        art.state_shapes, art.batch_shapes
    ).compile()
    state = art.init_state(jax.random.key(0))
    batch = jax.device_put(
        {"tokens": jax.random.randint(
            jax.random.key(1), art.batch_shapes["tokens"].shape, 0,
            cfg.vocab_size)},
        art.batch_shardings,
    )
    losses = []
    for i in range(8):
        state, metrics = compiled(state, batch)
        losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0], losses
print("MULTIDEVICE_OK")
"""


def test_multidevice_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert "MULTIDEVICE_OK" in res.stdout, res.stdout + res.stderr
