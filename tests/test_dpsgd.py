import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    consensus_distance,
    make_dpsgd_step,
    mix_params,
    replicate_for_agents,
)
from repro.core.weight_opt import optimize_weights


def _quadratic_setup(m=6):
    targets = jnp.arange(m, dtype=jnp.float32)[:, None]
    loss_fn = lambda p, b: jnp.mean((p["x"] - b) ** 2)
    params = {"x": jnp.zeros((m, 1))}
    ring = [(min(i, (i + 1) % m), max(i, (i + 1) % m)) for i in range(m)]
    w = jnp.asarray(
        optimize_weights(m, ring, steps=200).matrix, jnp.float32
    )
    return params, targets, loss_fn, w


def test_consensus_contracts_on_quadratic():
    params, targets, loss_fn, w = _quadratic_setup()
    step = make_dpsgd_step(loss_fn, learning_rate=0.05)
    for k in range(1500):
        params, loss = step(params, targets, w, jnp.asarray(k))
    x = np.asarray(params["x"]).ravel()
    # consensus neighborhood of the global optimum (mean target = 2.5)
    assert abs(x.mean() - 2.5) < 0.2
    assert float(consensus_distance(params)) < 2.0


def test_both_update_rules_converge_similarly():
    params0, targets, loss_fn, w = _quadratic_setup()
    outs = []
    for mix_first in (False, True):
        params = jax.tree.map(jnp.copy, params0)
        step = make_dpsgd_step(loss_fn, learning_rate=0.05,
                               mix_first=mix_first)
        for k in range(800):
            params, _ = step(params, targets, w, jnp.asarray(k))
        outs.append(np.asarray(params["x"]).mean())
    assert abs(outs[0] - outs[1]) < 0.3


def test_mix_params_matches_manual_einsum():
    params = {"a": jnp.arange(12.0).reshape(4, 3)}
    w = jnp.asarray(np.random.default_rng(0).random((4, 4)), jnp.float32)
    out = mix_params(params, w)
    np.testing.assert_allclose(
        np.asarray(out["a"]), np.asarray(w) @ np.asarray(params["a"]),
        rtol=1e-5,
    )


def test_replicate_for_agents():
    p = {"w": jnp.ones((3, 2))}
    r = replicate_for_agents(p, 5)
    assert r["w"].shape == (5, 3, 2)
    assert float(consensus_distance(r)) == 0.0
