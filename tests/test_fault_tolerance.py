import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing
from repro.net import build_overlay, lowest_degree_nodes, roofnet_like
from repro.runtime.fault_tolerance import (
    FaultToleranceController,
    HeartbeatMonitor,
    grow_state,
    redesign_after_failure,
    shrink_state,
)
from repro.runtime.stragglers import (
    StragglerSimulator,
    deadline_from_history,
    renormalized_mixing,
)


def test_heartbeat_detects_failure():
    t = [0.0]
    mon = HeartbeatMonitor((0, 1, 2), timeout=1.0, now=lambda: t[0])
    t[0] = 0.5
    mon.beat(0)
    mon.beat(1)
    t[0] = 1.2
    assert mon.failed() == (2,)


def test_controller_redesigns_after_failure(roofnet_overlay):
    ctl = FaultToleranceController(roofnet_overlay, kappa=1e6)
    state = {"x": jnp.arange(10.0)[:, None]}
    new_state, w, sched = ctl.handle_failures((3, 7), state, step=10)
    assert new_state["x"].shape == (8, 1)
    mixing.validate_mixing(w)
    assert ctl.alive == (0, 1, 2, 4, 5, 6, 8, 9)
    # rows kept correspond to the surviving agents
    np.testing.assert_allclose(
        np.asarray(new_state["x"]).ravel(), [0, 1, 2, 4, 5, 6, 8, 9]
    )
    # second failure round composes
    new_state, w2, _ = ctl.handle_failures((0,), new_state, step=20)
    assert new_state["x"].shape == (7, 1)
    mixing.validate_mixing(w2)


def test_grow_state_clones():
    st = {"x": jnp.arange(6.0).reshape(3, 2)}
    g = grow_state(st, 5)
    assert g["x"].shape == (5, 2)
    np.testing.assert_allclose(g["x"][3], g["x"][0])


def test_shrink_state_spares_non_agent_leaves():
    """Regression: only leaves whose leading dim equals the CURRENT agent
    count are sliced. The old ``shape[0] > max(alive)`` heuristic would
    corrupt a replicated RNG key of shape [2] (2 > max index 1) and any
    global vector longer than the largest alive index."""
    state = {
        "params": jnp.arange(8.0).reshape(4, 2),  # stacked agent axis
        "rng": jnp.array([7, 11], dtype=jnp.uint32),  # replicated key
        "sched": jnp.arange(100.0),  # global 1-D schedule table
        "scalar": jnp.float32(3.0),
    }
    out = shrink_state(state, (0, 1), num_agents=4)
    assert out["params"].shape == (2, 2)
    np.testing.assert_array_equal(out["rng"], state["rng"])  # untouched
    assert out["sched"].shape == (100,)
    assert out["scalar"].shape == ()

    with pytest.raises(ValueError, match="out of range"):
        shrink_state(state, (0, 5), num_agents=4)


def test_controller_prices_transition_round(roofnet_overlay):
    """Regression: handle_failures simulates the in-flight round under a
    failure_scenario and records the transition τ and cancelled-exchange
    count in the RecoveryEvent (the ROADMAP gap: redesign happened but
    the recovery cost was never measured)."""
    ctl = FaultToleranceController(roofnet_overlay, kappa=1e6)
    state = {"x": jnp.arange(10.0)[:, None]}
    _, _, _ = ctl.handle_failures((3,), state, step=5)
    ev = ctl.events[-1]
    assert np.isfinite(ev.transition_tau) and ev.transition_tau > 0
    assert ev.cancelled_exchanges > 0
    # Explicit failure times refine the pricing: failing at t=0 cancels
    # everything the agent touches before any of it completes.
    ctl2 = FaultToleranceController(roofnet_overlay, kappa=1e6)
    _, _, _ = ctl2.handle_failures(
        (3,), state, step=5, failure_times={3: 1e-6}
    )
    assert ctl2.events[-1].cancelled_exchanges >= ev.cancelled_exchanges

    ctl3 = FaultToleranceController(
        roofnet_overlay, kappa=1e6, price_transitions=False
    )
    _, _, _ = ctl3.handle_failures((3,), state, step=5)
    assert np.isnan(ctl3.events[-1].transition_tau)
    assert ctl3.events[-1].cancelled_exchanges == 0


@given(seed=st.integers(0, 200), m=st.integers(3, 8))
@settings(max_examples=25, deadline=None)
def test_renormalized_mixing_stays_valid(seed, m):
    rng = np.random.default_rng(seed)
    links = [(i, j) for i in range(m) for j in range(i + 1, m)]
    alpha = rng.uniform(0.01, 0.3, len(links))
    w = mixing.matrix_from_weights(m, links, alpha)
    drop = rng.random((m, m)) < 0.3
    delivered = ~(drop | drop.T)
    np.fill_diagonal(delivered, True)
    we = renormalized_mixing(w, delivered)
    mixing.validate_mixing(we)
    # undelivered exchanges are truly skipped
    for i in range(m):
        for j in range(m):
            if i != j and not delivered[i, j]:
                assert we[i, j] == 0.0


def test_deadline_and_straggler_sim():
    sim = StragglerSimulator(num_agents=6, prob=0.5, severity=4.0, seed=1)
    w = mixing.matrix_from_weights(6, [(0, 1), (2, 3), (4, 5)],
                                   [0.3, 0.3, 0.3])
    t_free, delivered_free = sim.round_time(1.0, w, deadline=None)
    assert t_free >= 1.0 and delivered_free.all()
    t_dl, delivered = sim.round_time(1.0, w, deadline=1.5)
    assert t_dl <= 1.5
    hist = [1.0, 1.1, 0.9, 4.0]
    assert deadline_from_history(hist, 0.75, 1.5) < 4.0


def test_redesign_single_survivor_returns_empty_categories(
    roofnet_overlay,
):
    """Regression: the m==1 branch used to return ``cats=None``,
    breaking every caller that unpacks the promised Categories."""
    w, sched, cats = redesign_after_failure(
        roofnet_overlay, alive=(4,), kappa=1e6
    )
    assert w.shape == (1, 1) and w[0, 0] == 1.0
    assert cats is not None
    assert cats.members == {} and cats.capacity == {}
    assert cats.edge_capacity == {}


def test_controller_clock_is_injectable(roofnet_overlay):
    """Telemetry timestamps come from the injected clock — no direct
    wall-clock reads in the handler (determinism lint, no waiver)."""
    t = [100.0]

    def clock():
        t[0] += 1.0
        return t[0]

    ctl = FaultToleranceController(
        roofnet_overlay, kappa=1e6, price_transitions=False, clock=clock
    )
    state = {"x": jnp.arange(10.0)[:, None]}
    _, w, _ = ctl.handle_failures((3,), state, step=1)
    mixing.validate_mixing(w)
    ev = ctl.events[-1]
    # three ticks: pricing start, redesign start, redesign end
    assert ev.pricing_seconds == 1.0
    assert ev.redesign_seconds == 1.0
