"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mixing_combine import mixing_sgd_combine

FLASH_CASES = [
    # b, h, kv, s, d, window, softcap, dtype
    (2, 4, 2, 128, 64, None, None, jnp.float32),
    (1, 8, 4, 256, 64, 64, None, jnp.float32),
    (2, 4, 4, 128, 128, None, 50.0, jnp.float32),
    (1, 2, 1, 256, 32, 128, 30.0, jnp.float32),
    (1, 4, 2, 128, 64, None, None, jnp.bfloat16),
    (1, 4, 4, 128, 256, 96, None, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_oracle(case):
    b, h, kv, s, d, window, cap, dtype = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d)).astype(dtype)
    out = flash_attention(q, k, v, window=window, softcap=cap,
                          block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, window=window, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol,
    )


DECODE_CASES = [
    (2, 4, 2, 512, 64, 300, None, jnp.float32),
    (1, 8, 8, 1024, 128, 1024, None, jnp.float32),
    (3, 4, 1, 512, 32, 1, None, jnp.float32),
    (2, 4, 2, 512, 64, 511, 50.0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_oracle(case):
    b, h, kv, s, d, length, cap, dtype = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, h, 1, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d)).astype(dtype)
    out = decode_attention(q, k, v, length, softcap=cap, block_k=256,
                           interpret=True)
    exp = ref.decode_attention_ref(q, k, v, length, softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("n,r,block", [(1 << 16, 3, 16384),
                                       (1 << 14, 1, 1 << 14),
                                       (1 << 15, 6, 4096)])
def test_mixing_combine_matches_oracle(n, r, block):
    ks = jax.random.split(jax.random.key(n + r), 4)
    x = jax.random.normal(ks[0], (n,), jnp.float32)
    recv = jax.random.normal(ks[1], (r, n), jnp.float32)
    w = jax.random.uniform(ks[2], (r + 1,))
    mom = jax.random.normal(ks[3], (n,), jnp.float32)
    out = mixing_sgd_combine(x, recv, w, mom, lr=0.1, block_n=block,
                             interpret=True)
    exp = ref.mixing_sgd_combine_ref(x, recv, w, mom, lr=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
