import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save


def _state(m=4):
    return {
        "params": {"w": jnp.arange(float(m * 3)).reshape(m, 3)},
        "opt": {"momentum": {"w": jnp.ones((m, 3))}},
        "step": jnp.asarray(5),
    }


def test_save_restore_roundtrip():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        save(d, 5, st)
        got, step = restore(d, st)
        assert step == 5
        np.testing.assert_allclose(got["params"]["w"], st["params"]["w"])


def test_retention_keeps_latest_k():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save(d, s, st, keep=2)
        names = sorted(os.listdir(d))
        assert names == ["step_0000000004", "step_0000000005"]


def test_elastic_restore_grow_and_shrink():
    st = _state(m=4)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, st)
        small = _state(m=2)
        got, _ = restore(d, small, num_agents=2)
        assert got["params"]["w"].shape == (2, 3)
        big = _state(m=7)
        got, _ = restore(d, big, num_agents=7)
        assert got["params"]["w"].shape == (7, 3)
        # grown agents are clones of agent 0
        np.testing.assert_allclose(got["params"]["w"][4],
                                   got["params"]["w"][0])


def test_async_checkpointer():
    st = _state()
    with tempfile.TemporaryDirectory() as d:
        ac = AsyncCheckpointer(d, keep=2)
        ac.save(10, st)
        ac.save(20, st)
        ac.wait()
        assert latest_step(d) == 20


def test_restore_missing_raises():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            restore(d, _state())
