"""Design-as-a-service: incremental patching (bitwise vs scratch),
warm-started FMMD-P, the event loop's decision policy, and every
degradation tier (incumbent-keep, scratch-rebuild, quarantine) asserted
through the ``ServiceLog`` decision trail."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.analysis.contracts import ContractViolation
from repro.core import mixing
from repro.core.fmmd import _PriorityState, fmmd
from repro.net import build_overlay, lowest_degree_nodes, roofnet_like
from repro.net.categories import (
    compile_category_incidence,
    compute_categories,
    edge_category_index,
    patch_categories_capacity,
    patch_category_incidence,
)
from repro.net.simulator import compile_incidence, simulate
from repro.net.stochastic import (
    MarkovLinkModel,
    StochasticScenario,
    realization_deltas,
)
from repro.net.topology import OverlayNetwork
from repro.runtime import design_service as ds
from repro.runtime.design_service import (
    DesignService,
    ServiceConfig,
    VirtualClock,
)
from repro.runtime.events import (
    AgentJoin,
    AgentLeave,
    LinkStateChange,
    events_from_stochastic,
    malformed_reason,
)
from repro.runtime.faultinject import (
    FaultInjector,
    FaultPlan,
    PricingFault,
)

KAPPA = 1e6


@pytest.fixture(params=["0", "1"], ids=["plain", "validated"])
def validate_mode(request, monkeypatch):
    """Run a test both plain and under REPRO_VALIDATE=1."""
    monkeypatch.setenv("REPRO_VALIDATE", request.param)
    return request.param


def _scaled_reference(overlay, scale):
    """Ground truth for a capacity-only change: the overlay's routing
    paths are pinned (a LinkStateChange does not re-route), so the
    scratch recompute keeps the paths and mutates only capacities."""
    und = overlay.underlay.with_scaled_capacities(scale)
    return compute_categories(
        OverlayNetwork(
            underlay=und, agents=overlay.agents, paths=overlay.paths
        )
    )


def _assert_cats_bitwise(a, b):
    assert list(a.capacity.keys()) == list(b.capacity.keys())
    assert a.members == b.members
    for F in a.capacity:
        assert a.capacity[F] == b.capacity[F]
    assert a.edge_capacity == b.edge_capacity


def _assert_inc_bitwise(a, b):
    for f in ("capacity", "entry_link", "entry_cat", "entry_coef",
              "link_ptr"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


# ---------------------------------------------------------------------------
# Satellite: patch vs recompile, bitwise (plain AND REPRO_VALIDATE=1)
# ---------------------------------------------------------------------------


def test_patch_matches_recompile_bitwise(
    roofnet_overlay, roofnet_categories, validate_mode
):
    cats = roofnet_categories
    inc = compile_category_incidence(
        cats, roofnet_overlay.num_agents, KAPPA
    )
    edge_index = edge_category_index(cats)
    # Physical (undirected) links: a scale change hits BOTH member
    # directions, exactly as ``with_scaled_capacities`` would.
    links = sorted(
        {(u, v) if u < v else (v, u) for u, v in cats.edge_capacity}
    )
    rng = np.random.default_rng(int(validate_mode))
    for case in range(6):
        k = int(rng.integers(1, len(links)))
        picked = [
            links[i]
            for i in sorted(
                rng.choice(len(links), size=k, replace=False).tolist()
            )
        ]
        und_scale = {
            e: float(s)
            for e, s in zip(
                picked, rng.uniform(0.2, 2.5, size=len(picked))
            )
        }
        changed = {
            d: roofnet_overlay.underlay.capacity(*d) * s
            for (u, v), s in und_scale.items()
            for d in ((u, v), (v, u))
            if d in cats.edge_capacity
        }
        patched, touched = patch_categories_capacity(
            cats, changed, edge_index
        )
        patched_inc = patch_category_incidence(
            inc, patched, touched
        )
        ref = _scaled_reference(roofnet_overlay, und_scale)
        _assert_cats_bitwise(patched, ref)
        _assert_inc_bitwise(
            patched_inc,
            compile_category_incidence(
                ref, roofnet_overlay.num_agents, KAPPA
            ),
        )
        # _FlatCategories payload is shared, not recomputed.
        assert patched.flat is cats.flat


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_patch_property_random_subsets(seed):
    und = roofnet_like(seed=1)
    ov = build_overlay(und, lowest_degree_nodes(und, 6))
    cats = compute_categories(ov)
    inc = compile_category_incidence(cats, 6, KAPPA)
    links = sorted(
        {(u, v) if u < v else (v, u) for u, v in cats.edge_capacity}
    )
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, min(len(links), 8) + 1))
    picked = [
        links[i]
        for i in sorted(
            rng.choice(len(links), size=k, replace=False).tolist()
        )
    ]
    und_scale = {
        e: float(s)
        for e, s in zip(picked, rng.uniform(0.1, 3.0, size=k))
    }
    changed = {
        d: und.capacity(*d) * s
        for (u, v), s in und_scale.items()
        for d in ((u, v), (v, u))
        if d in cats.edge_capacity
    }
    patched, touched = patch_categories_capacity(cats, changed)
    patched_inc = patch_category_incidence(inc, patched, touched)
    ref = _scaled_reference(ov, und_scale)
    _assert_cats_bitwise(patched, ref)
    _assert_inc_bitwise(
        patched_inc, compile_category_incidence(ref, 6, KAPPA)
    )


def test_patch_rejects_unknown_and_nonpositive(roofnet_categories):
    with pytest.raises(ValueError, match="not member edges"):
        patch_categories_capacity(
            roofnet_categories, {(987, 986): 1.0}
        )
    e = sorted(roofnet_categories.edge_capacity)[0]
    with pytest.raises(ValueError, match="positive"):
        patch_categories_capacity(roofnet_categories, {e: 0.0})


def test_branch_incidence_capacity_patch_matches_scratch(
    roofnet_overlay, roofnet_categories, validate_mode
):
    """Patched BranchIncidence prices the in-flight round identically to
    a from-scratch compile on the mutated network."""
    from repro.net.demands import demands_from_links
    from repro.net.routing import route_direct

    m = roofnet_overlay.num_agents
    design = fmmd(
        m, 12, categories=roofnet_categories, kappa=KAPPA,
        priority=True,
    )
    sol = route_direct(
        demands_from_links(design.activated_links, KAPPA, m),
        roofnet_categories,
        KAPPA,
    )
    binc = compile_incidence(sol, roofnet_overlay)
    picked = sorted(
        {(u, v) if u < v else (v, u) for u, v in binc.edges}
    )[:4]
    changed = {
        d: roofnet_overlay.underlay.capacity(*d) * 0.35
        for (u, v) in picked
        for d in ((u, v), (v, u))
    }
    patched = binc.with_capacities(changed)
    und_scale = {e: 0.35 for e in picked}
    ref_ov = OverlayNetwork(
        underlay=roofnet_overlay.underlay.with_scaled_capacities(
            und_scale
        ),
        agents=roofnet_overlay.agents,
        paths=roofnet_overlay.paths,
    )
    ref = compile_incidence(sol, ref_ov)
    assert np.array_equal(patched.base_capacity, ref.base_capacity)
    sim_patch = simulate(sol, roofnet_overlay, incidence=patched)
    sim_ref = simulate(sol, ref_ov)
    assert sim_patch.makespan == sim_ref.makespan
    # unknown edges are ignored, non-positive rejected
    assert np.array_equal(
        binc.with_capacities({(991, 990): 5.0}).base_capacity,
        binc.base_capacity,
    )
    with pytest.raises(ValueError, match="positive"):
        binc.with_capacities({picked[0]: 0.0})


def test_simulate_rejects_incidence_with_reference_engine(
    roofnet_overlay, roofnet_categories
):
    from repro.net.demands import demands_from_links
    from repro.net.routing import route_direct

    m = roofnet_overlay.num_agents
    sol = route_direct(
        demands_from_links([(0, 1)], KAPPA, m),
        roofnet_categories,
        KAPPA,
    )
    binc = compile_incidence(sol, roofnet_overlay)
    with pytest.raises(ValueError, match="vectorized"):
        simulate(
            sol, roofnet_overlay, engine="reference", incidence=binc
        )


# ---------------------------------------------------------------------------
# Warm-started FMMD-P
# ---------------------------------------------------------------------------


def test_warm_fmmd_bitwise_equals_cold(
    roofnet_overlay, roofnet_categories
):
    m = roofnet_overlay.num_agents
    inc = compile_category_incidence(roofnet_categories, m, KAPPA)
    atoms = [(i, j) for i in range(m) for j in range(i + 1, m)]
    # Mutate the state with one run, then reset and compare to cold.
    state = _PriorityState(
        atoms, m, roofnet_categories, KAPPA, incidence=inc
    )
    fmmd(
        m, 8, categories=roofnet_categories, kappa=KAPPA,
        priority=True, incidence=inc, warm_state=state,
    )
    # Capacity patch + reset: warm run vs cold run on patched structures.
    e = sorted(roofnet_categories.edge_capacity)[0]
    patched, touched = patch_categories_capacity(
        roofnet_categories,
        {e: roofnet_categories.edge_capacity[e] * 0.3},
    )
    pinc = patch_category_incidence(inc, patched, touched)
    state.reset(pinc)
    warm = fmmd(
        m, 10, categories=patched, kappa=KAPPA,
        priority=True, incidence=pinc, warm_state=state,
    )
    cold = fmmd(
        m, 10, categories=patched, kappa=KAPPA,
        priority=True, incidence=pinc,
    )
    assert np.array_equal(warm.matrix, cold.matrix)
    assert warm.activated_links == cold.activated_links
    assert warm.rho == cold.rho


def test_warm_state_validation(roofnet_overlay, roofnet_categories):
    m = roofnet_overlay.num_agents
    inc = compile_category_incidence(roofnet_categories, m, KAPPA)
    atoms = [(i, j) for i in range(m) for j in range(i + 1, m)]
    state = _PriorityState(
        atoms, m, roofnet_categories, KAPPA, incidence=inc
    )
    with pytest.raises(ValueError, match="atoms"):
        fmmd(
            m, 4, categories=roofnet_categories, kappa=KAPPA,
            priority=True, allowed_links=[(0, 1)], warm_state=state,
        )
    with pytest.raises(ValueError, match="does not match"):
        fmmd(
            m, 4, categories=roofnet_categories, kappa=2.0,
            priority=True, warm_state=state,
        )
    with pytest.raises(ValueError, match="capacity-only"):
        state.reset(
            compile_category_incidence(roofnet_categories, m, 2.0)
        )


# ---------------------------------------------------------------------------
# Event sourcing
# ---------------------------------------------------------------------------


def _sto():
    und = roofnet_like(seed=0)
    edges = sorted(und.graph.edges)[:6]
    return StochasticScenario(
        links=(
            MarkovLinkModel(
                edges=tuple(edges[:3]),
                scales=(1.0, 0.3),
                transition=((0.6, 0.4), (0.5, 0.5)),
            ),
            MarkovLinkModel(
                edges=tuple(edges[3:]),
                scales=(1.0, 0.5),
                transition=((0.7, 0.3), (0.6, 0.4)),
            ),
        ),
        horizon=40.0,
        step=5.0,
        churn_hazard=0.02,
        churn_agents=(1, 3),
    )


def test_events_from_stochastic_deterministic_and_minimal():
    sto = _sto()
    a = events_from_stochastic(sto, key=5)
    b = events_from_stochastic(sto, key=5)
    assert a == b
    assert any(isinstance(e, LinkStateChange) for e in a)
    times = [e.time for e in a]
    assert times == sorted(times)
    # deltas only name edges whose scale moved
    scen = sto.sample(5)
    deltas = realization_deltas(scen)
    prev = {}
    for t, changed in deltas:
        assert changed  # minimal: empty deltas are dropped
        for e, s in changed.items():
            assert s != prev.get(e, 1.0)
        prev.update(changed)
    assert events_from_stochastic(sto, key=6) != a


def test_realization_deltas_rejects_scalar_phase():
    from repro.net.simulator import CapacityPhase, Scenario

    with pytest.raises(ValueError, match="per-edge"):
        realization_deltas(
            Scenario(capacity_phases=(CapacityPhase(1.0, 0.5),))
        )
    # scalar 1.0 (all-clear) is accepted and reverts prior scales
    deltas = realization_deltas(
        Scenario(
            capacity_phases=(
                CapacityPhase(1.0, {(0, 1): 0.5}),
                CapacityPhase(2.0, 1.0),
            )
        )
    )
    assert deltas == ((1.0, {(0, 1): 0.5}), (2.0, {(0, 1): 1.0}))


def test_malformed_reason():
    assert malformed_reason(LinkStateChange(1.0, {(0, 1): 0.5})) is None
    assert malformed_reason(
        LinkStateChange(1.0, {(0, 1): -0.5})
    ) is not None
    assert malformed_reason(
        LinkStateChange(float("nan"), {})
    ) is not None
    assert malformed_reason(AgentLeave(1.0, agent=-1)) is not None
    assert malformed_reason(AgentJoin(1.0, node=2)) is None
    assert malformed_reason(object()) is not None


# ---------------------------------------------------------------------------
# The service loop
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def service_overlay():
    und = roofnet_like(seed=0)
    return build_overlay(und, lowest_degree_nodes(und, 8))


def _service(service_overlay, **kw):
    cfg_kw = dict(design_iterations=12, drift_band=0.0)
    cfg_kw.update(kw.pop("config", {}))
    return DesignService(
        service_overlay, kappa=KAPPA, config=ServiceConfig(**cfg_kw),
        **kw,
    )


def test_absorb_untraversed_edge_is_noop(service_overlay):
    svc = _service(service_overlay)
    member = svc.categories.edge_capacity
    free = next(
        (u, v)
        for u, v in sorted(service_overlay.underlay.graph.edges)
        if (u, v) not in member and (v, u) not in member
    )
    inc_before, tau_before = svc._inc, svc.tau
    rec = svc.process(LinkStateChange(time=1.0, scales={free: 0.01}))
    assert rec.decision == "absorb"
    assert svc._inc is inc_before  # nothing recompiled, provably no-op
    assert svc.tau == tau_before


def test_adopt_and_defer_follow_transition_pricing(service_overlay):
    # Degrading edges the incumbent crosses: with a long horizon the
    # redesign's savings beat the transition bill -> adopt ...
    svc = _service(
        service_overlay,
        config=dict(horizon_rounds=1000.0, transition_rounds=0.0),
    )
    worst = sorted(svc._binc.edges)[:3]
    rec = svc.process(
        LinkStateChange(time=1.0, scales={e: 0.25 for e in worst})
    )
    assert rec.decision == "adopt"
    assert svc.tau < 0.9 * 32.0
    # ... with a zero horizon no savings can be projected -> defer.
    svc2 = _service(
        service_overlay,
        config=dict(horizon_rounds=0.0, transition_rounds=1.0),
    )
    rec2 = svc2.process(
        LinkStateChange(time=1.0, scales={e: 0.25 for e in worst})
    )
    assert rec2.decision == "defer"
    assert svc2.tau > svc.tau  # deferred: still paying the degraded τ


def test_patch_keeps_incumbent_within_band(service_overlay):
    svc = _service(service_overlay, config=dict(drift_band=10.0))
    e = sorted(svc.categories.edge_capacity)[0]
    key = (e[0], e[1]) if e[0] < e[1] else (e[1], e[0])
    w_before = svc.design
    rec = svc.process(LinkStateChange(time=1.0, scales={key: 0.5}))
    assert rec.decision in ("patch", "absorb")
    assert svc.design is w_before
    # patched capacities are live: C_F of touched families moved
    ref = _scaled_reference(svc._overlay, {key: 0.5})
    _assert_cats_bitwise(svc.categories, ref)


def test_leave_and_join_regroup_bitwise(service_overlay, validate_mode):
    svc = _service(service_overlay)
    und = service_overlay.underlay
    free_node = next(
        n
        for n in sorted(und.graph.nodes)
        if n not in set(service_overlay.agents)
    )
    log = svc.run(
        [
            AgentLeave(time=1.0, agent=3),
            AgentJoin(time=2.0, node=free_node),
        ]
    )
    assert [r.decision for r in log] == ["redesign", "redesign"]
    assert svc.members == (0, 1, 2, 4, 5, 6, 7, 8)
    ref_ov = build_overlay(
        und, [svc._node_of[h] for h in svc.members]
    )
    ref = compute_categories(ref_ov)
    _assert_cats_bitwise(svc.categories, ref)
    _assert_inc_bitwise(
        svc._inc,
        compile_category_incidence(ref, ref_ov.num_agents, KAPPA),
    )
    mixing.validate_mixing(svc.design)


def test_single_survivor(service_overlay):
    und = service_overlay.underlay
    ov2 = build_overlay(und, list(service_overlay.agents[:2]))
    svc = DesignService(
        ov2, kappa=KAPPA, config=ServiceConfig(design_iterations=4)
    )
    rec = svc.process(AgentLeave(time=1.0, agent=0))
    assert rec.decision == "redesign"
    assert "single survivor" in rec.detail
    assert svc.design.shape == (1, 1) and svc.design[0, 0] == 1.0
    assert svc.tau == 0.0
    # the last agent cannot leave
    rec2 = svc.process(AgentLeave(time=2.0, agent=1))
    assert rec2.decision == "reject"
    assert svc.members == (1,)


# ---------------------------------------------------------------------------
# Fault injection and degradation tiers (ServiceLog decision trail)
# ---------------------------------------------------------------------------


def test_incumbent_keep_after_retries_with_backoff(service_overlay):
    clock = VirtualClock()
    inj = FaultInjector(FaultPlan(seed=0, rate=1.0, modes=("raise",)))
    svc = _service(service_overlay, clock=clock, fault_injector=inj)
    w_before = svc.design
    worst = sorted(svc._binc.edges)[:3]
    rec = svc.process(
        LinkStateChange(time=3.0, scales={e: 0.25 for e in worst})
    )
    assert rec.decision == "incumbent-keep"
    assert rec.tier == "incumbent-keep"
    assert rec.retries == 2 and len(rec.faults) == 3
    assert svc.design is w_before
    # deterministic backoff on the virtual clock: 0.5 + 1.0 after t=3
    assert clock.now() == pytest.approx(4.5)
    # the patched capacities are still live despite the failed redesign
    assert svc.tau > 16.0


def test_timeout_faults_advance_virtual_clock(service_overlay):
    clock = VirtualClock()
    inj = FaultInjector(
        FaultPlan(
            seed=0, rate=1.0, modes=("timeout",), timeout_seconds=2.0
        )
    )
    svc = _service(
        service_overlay,
        clock=clock,
        fault_injector=inj,
        config=dict(max_retries=1),
    )
    worst = sorted(svc._binc.edges)[:3]
    rec = svc.process(
        LinkStateChange(time=0.0, scales={e: 0.25 for e in worst})
    )
    assert rec.decision == "incumbent-keep"
    assert [m for _, m in inj.injected] == ["timeout", "timeout"]
    # two timeouts (2s each) + one backoff (0.5s)
    assert clock.now() == pytest.approx(4.5)


def test_stale_candidate_detected_by_epoch(service_overlay):
    svc = _service(service_overlay, config=dict(max_retries=0))
    stale = svc._priced_candidate()
    inj = FaultInjector(FaultPlan(seed=0, rate=1.0, modes=("stale",)))
    inj._last_good, inj._has_last = stale, True
    inj._clock = svc.clock
    svc.injector = inj
    worst = sorted(svc._binc.edges)[:3]
    rec = svc.process(
        LinkStateChange(time=1.0, scales={e: 0.25 for e in worst})
    )
    assert rec.decision == "incumbent-keep"
    assert "stale candidate" in rec.faults[0]


def test_nan_poison_detected_and_retried(service_overlay):
    inj = FaultInjector(FaultPlan(seed=3, rate=1.0, modes=("nan",)))
    svc = _service(
        service_overlay, fault_injector=inj, config=dict(max_retries=1)
    )
    worst = sorted(svc._binc.edges)[:3]
    rec = svc.process(
        LinkStateChange(time=1.0, scales={e: 0.25 for e in worst})
    )
    assert rec.decision == "incumbent-keep"
    assert all("poisoned" in f for f in rec.faults)


def test_contract_violation_falls_back_to_scratch_rebuild(
    service_overlay, monkeypatch
):
    svc = _service(service_overlay)

    def tripped(*a, **k):
        raise ContractViolation(
            "CategoryIncidence", "entry_coef", "finite", "poisoned"
        )

    monkeypatch.setattr(ds, "patch_category_incidence", tripped)
    e = sorted(svc.categories.edge_capacity)[0]
    key = (e[0], e[1]) if e[0] < e[1] else (e[1], e[0])
    rec = svc.process(LinkStateChange(time=1.0, scales={key: 0.5}))
    assert rec.decision == "scratch-rebuild"
    assert rec.tier == "scratch-rebuild"
    monkeypatch.undo()
    # the rebuilt state matches the scratch reference bitwise
    ref = _scaled_reference(svc._overlay, {key: 0.5})
    _assert_cats_bitwise(svc.categories, ref)
    mixing.validate_mixing(svc.design)


def test_leave_fallback_renormalizes_incumbent(service_overlay):
    inj = FaultInjector(FaultPlan(seed=0, rate=1.0, modes=("raise",)))
    svc = _service(
        service_overlay, fault_injector=inj, config=dict(max_retries=0)
    )
    rec = svc.process(AgentLeave(time=1.0, agent=2))
    assert rec.decision == "incumbent-keep"
    assert rec.tier == "incumbent-keep"
    assert "renormalized" in rec.detail
    assert svc.num_agents == 7
    mixing.validate_mixing(svc.design)  # doubly stochastic fallback


def test_join_fallback_reverts_membership(service_overlay):
    inj = FaultInjector(FaultPlan(seed=0, rate=1.0, modes=("raise",)))
    svc = _service(
        service_overlay, fault_injector=inj, config=dict(max_retries=0)
    )
    members, w, epoch = svc.members, svc.design, svc.epoch
    free_node = next(
        n
        for n in sorted(service_overlay.underlay.graph.nodes)
        if n not in set(service_overlay.agents)
    )
    rec = svc.process(AgentJoin(time=1.0, node=free_node))
    assert rec.decision == "incumbent-keep"
    assert "reverted" in rec.detail
    assert svc.members == members
    assert svc.design is w
    assert svc.epoch > epoch  # revert invalidates in-flight candidates


def test_quarantine_then_drop(service_overlay):
    svc = _service(service_overlay)
    events = [
        LinkStateChange(time=1.0, scales={(0, 1): -2.0}, origin=4),
        LinkStateChange(time=2.0, scales={}, origin=4),
        AgentLeave(time=3.0, agent=99),  # semantic, no origin
        LinkStateChange(time=4.0, scales={(9876, 9875): 0.5}, origin=5),
    ]
    log = svc.run(events)
    assert [r.decision for r in log] == [
        "quarantine", "drop", "reject", "quarantine",
    ]
    assert all(r.tier == "quarantine" for r in log)
    assert svc.quarantined == (4, 5)
    assert len(log) == len(events)  # zero dropped events
    assert svc.members == tuple(range(8))  # membership untouched


def test_event_stream_zero_drops_and_replayable(service_overlay):
    """A mixed malformed/chaotic stream: every event gets exactly one
    record, and replaying the same stream on a fresh service reproduces
    the same decision trail bitwise."""

    def run_once():
        inj = FaultInjector(FaultPlan(seed=11, rate=0.5))
        svc = _service(service_overlay, fault_injector=inj)
        member = sorted(svc.categories.edge_capacity)
        events = [
            LinkStateChange(
                time=float(k),
                scales={member[(3 * k) % len(member)]: 0.3 + 0.05 * k},
            )
            for k in range(8)
        ]
        events.insert(
            3, LinkStateChange(time=2.5, scales={(0, 1): -1.0}, origin=2)
        )
        events.append(AgentLeave(time=9.0, agent=1))
        log = svc.run(events)
        assert len(log) == len(events)
        return [(r.event, r.decision, r.tier, r.tau) for r in log]

    assert run_once() == run_once()


def test_faultplan_validation():
    with pytest.raises(ValueError, match="rate"):
        FaultPlan(rate=1.5)
    with pytest.raises(ValueError, match="modes"):
        FaultPlan(modes=("explode",))
    inj = FaultInjector(FaultPlan(rate=0.0))
    assert inj.call(lambda: 42) == 42
    assert inj.injected == []


def test_service_config_validation():
    with pytest.raises(ValueError, match="drift_band"):
        ServiceConfig(drift_band=-0.1)
    with pytest.raises(ValueError, match="backoff"):
        ServiceConfig(backoff_factor=0.5)
    clock = VirtualClock()
    with pytest.raises(ValueError, match="backwards"):
        clock.advance(-1.0)
