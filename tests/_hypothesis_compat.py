"""Property-test front end: real ``hypothesis`` when installed (the
``test`` extra in pyproject.toml pins it), else a deterministic
mini-fallback so the suite still *runs* the property tests instead of
erroring at collection.

The fallback implements only what this repo's tests draw —
``st.integers``, ``st.sampled_from``, ``st.booleans``, ``st.floats`` —
with a per-test seeded RNG; unsupported strategies skip the test rather
than fail it (``pytest.skip``), mirroring ``pytest.importorskip``'s
graceful degradation at the granularity of a single test.
"""

from __future__ import annotations

import random
import zlib

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class _FallbackStrategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        def __getattr__(self, name):  # unsupported strategy → skip test
            def _skip(*args, **kwargs):
                return _Strategy(
                    lambda rng: pytest.skip(
                        f"hypothesis not installed and fallback lacks "
                        f"strategy {name!r}"
                    )
                )

            return _skip

    st = _FallbackStrategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: pytest must see a parameterless
            # signature, not the strategy args (it would hunt fixtures).
            def wrapper():
                n = getattr(
                    wrapper, "_fallback_max_examples",
                    getattr(fn, "_fallback_max_examples", _DEFAULT_EXAMPLES),
                )
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
