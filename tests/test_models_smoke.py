"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes + no NaNs, plus decode-vs-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as M


def _batch(cfg, b=2, s=32, key=0):
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(key), (b, s + 1), 0, cfg.vocab_size
        )
    }
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(key + 1), (b, cfg.num_patches, cfg.d_model),
            jnp.float32,
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, jax.random.key(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss(cfg, p, batch), has_aux=True
    )(params)
    assert jnp.isfinite(loss)
    assert np.isfinite(float(metrics["ce"]))
    for g in jax.tree.leaves(grads):
        assert jnp.all(jnp.isfinite(g))
    logits, _ = M.forward(cfg, params, {
        k: (v[:, :-1] if k == "tokens" else v) for k, v in batch.items()
    })
    s_out = batch["tokens"].shape[1] - 1
    if cfg.frontend == "vision_patches":
        s_out += cfg.num_patches
    assert logits.shape == (2, s_out, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    """Prefill S tokens then decode; logits must match the full forward
    pass at the same positions (cache correctness per block kind)."""
    cfg = get_config(arch, smoke=True)
    params = M.init(cfg, jax.random.key(1))
    b, s, extra = 2, 24, 4
    toks = jax.random.randint(jax.random.key(2), (b, s + extra), 0,
                              cfg.vocab_size)
    inputs_full = {"tokens": toks}
    if cfg.frontend == "vision_patches":
        pe = jax.random.normal(
            jax.random.key(3), (b, cfg.num_patches, cfg.d_model), jnp.float32
        )
        inputs_full["patch_embeds"] = pe
    ref_logits, _ = M.forward(cfg, params, inputs_full, remat=False)

    prefill_inputs = {"tokens": toks[:, :s]}
    if cfg.frontend == "vision_patches":
        prefill_inputs["patch_embeds"] = pe
    logits_p, caches = M.prefill(cfg, params, prefill_inputs,
                                 max_len=s + extra + cfg.num_patches
                                 if cfg.frontend == "vision_patches"
                                 else s + extra)
    offset = cfg.num_patches if cfg.frontend == "vision_patches" else 0
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]),
        np.asarray(ref_logits[:, offset + s - 1]),
        rtol=2e-2, atol=2e-2,
    )
    # decode the next `extra` tokens teacher-forced
    for t in range(extra - 1):
        logits_d, caches = M.decode_step(
            cfg, params, caches, toks[:, s + t : s + t + 1]
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(ref_logits[:, offset + s + t]),
            rtol=3e-2, atol=3e-2,
        )


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "gemma2-2b",
                                  "jamba-1.5-large-398b", "xlstm-125m"])
def test_full_config_shapes_via_eval(arch):
    """Full configs must build abstractly (no allocation)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: M.init(cfg, k), jax.random.key(0))
    n = sum(
        int(np.prod(l.shape)) if 0 not in l.shape else 0
        for l in jax.tree.leaves(shapes)
    )
    assert n > 0
