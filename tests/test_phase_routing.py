"""Phase-adaptive (time-expanded) routing: degeneracy, per-phase
categories, phased-simulation parity, and the designer wiring."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    CapacityPhase,
    ChurnEvent,
    Scenario,
    build_overlay,
    compute_categories,
    compile_category_incidence,
    demands_from_links,
    infer_categories,
    random_geometric_underlay,
    route,
    route_time_expanded,
    simulate,
    simulate_phased,
)
from repro.net.routing import PhasedRoutingSolution, _phase_segments


def _instance(seed: int, m: int):
    u = random_geometric_underlay(12, radius=0.5, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.6
    ] or [(0, 1)]
    demands = demands_from_links(links, 1e6, m)
    return u, ov, cats, demands


# ---------------------------------------------------------------------------
# Degeneracy: trivial scenario == static route(), bitwise
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 40), m=st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_trivial_scenario_is_static_route_bitwise(seed, m):
    """Property: with no capacity phases, route_time_expanded returns
    exactly the static route() answer (same trees, same τ)."""
    _, _, cats, demands = _instance(seed, m)
    static = route(demands, cats, 1e6, m, milp_var_budget=0, seed=seed)
    phased = route_time_expanded(
        demands, cats, Scenario(), 1e6, m, milp_var_budget=0, seed=seed
    )
    assert phased.num_segments == 1
    assert phased.boundaries == (0.0,)
    assert phased.solutions[0].trees == static.trees
    assert phased.solutions[0].completion_time == static.completion_time
    assert phased.is_static


# ---------------------------------------------------------------------------
# Per-phase categories == compute_categories on the phase-scaled underlay
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 30),
    m=st.integers(3, 6),
    scalar=st.booleans(),
)
@settings(max_examples=10, deadline=None)
def test_scaled_categories_match_scaled_underlay(seed, m, scalar):
    """Property: Categories.scaled(phase.scale) equals compute_categories
    on the same overlay atop the phase-scaled underlay — for scalar and
    per-edge scales (capacity scaling never re-routes paths)."""
    u, ov, cats, _ = _instance(seed, m)
    if scalar:
        scale = 0.25 + 0.5 * np.random.default_rng(seed).random()
    else:
        rng = np.random.default_rng(seed + 1)
        edges = list(u.graph.edges)
        picks = rng.choice(len(edges), size=min(8, len(edges)),
                           replace=False)
        scale = {edges[int(k)]: float(rng.uniform(0.05, 2.0))
                 for k in picks}
    scaled = cats.scaled(scale)
    truth = compute_categories(
        dataclasses.replace(ov, underlay=u.with_scaled_capacities(scale))
    )
    assert set(scaled.capacity) == set(truth.capacity)
    for F in truth.capacity:
        assert scaled.capacity[F] == truth.capacity[F]


def test_scaled_identity_and_rejections():
    _, _, cats, _ = _instance(0, 4)
    assert cats.scaled(1.0) is cats  # object identity on trivial phase
    with pytest.raises(ValueError, match="positive"):
        cats.scaled(0.0)
    inferred = infer_categories(
        build_overlay(
            random_geometric_underlay(12, radius=0.5, seed=0),
            list(range(4)),
        )
    )
    assert inferred.scaled(0.5).capacity  # scalar works without members
    with pytest.raises(ValueError, match="inferred"):
        inferred.scaled({(0, 1): 0.5})


def test_rescaled_incidence_matches_recompiled():
    _, _, cats, _ = _instance(3, 5)
    inc = compile_category_incidence(cats, 5, 1e6)
    scaled = cats.scaled(0.5)
    fast = inc.rescaled(scaled)
    slow = compile_category_incidence(scaled, 5, 1e6)
    assert np.array_equal(fast.capacity, slow.capacity)
    assert np.array_equal(fast.entry_coef, slow.entry_coef)
    assert np.array_equal(fast.entry_link, slow.entry_link)
    assert fast.matches(scaled)


def test_duplicate_phase_starts_accepted():
    """Regression: two phases sharing a start time are legal for
    simulate() (the last sorted one wins), so route_time_expanded must
    not crash on them — it keeps the winning phase per start."""
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=5.0, scale=0.5),
        CapacityPhase(start=5.0, scale=0.25),
    ))
    assert _phase_segments(sc) == [(0.0, 1.0), (5.0, 0.25)]
    _, _, cats, demands = _instance(0, 4)
    phased = route_time_expanded(
        demands, cats, sc, 1e6, 4, milp_var_budget=0
    )
    assert phased.boundaries == (0.0, 5.0)


def test_uniform_scale_never_swaps_trees():
    """A uniform capacity drop moves no bottleneck: every segment must
    keep segment 0's solution (trees-equal swap guard, including for
    segments served from the per-scale cache)."""
    _, _, cats, demands = _instance(2, 5)
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=3.0, scale=0.5),
        CapacityPhase(start=9.0, scale=1.0),
    ))
    phased = route_time_expanded(
        demands, cats, sc, 1e6, 5, milp_var_budget=0, seed=2
    )
    assert phased.num_segments == 3
    assert phased.is_static
    assert phased.solutions[1] is phased.solutions[0]
    assert phased.solutions[2] is phased.solutions[0]


def test_abandoned_branch_progress_is_lost():
    """Regression: a branch dropped by one re-route and restored by a
    later one restarts from full κ — mid-flight data on abandoned links
    is lost, not parked. Hand-computed on the 3-agent line."""
    from repro.net import line_underlay
    from repro.net.routing import RoutingSolution

    u = line_underlay(3)  # C = 125 kB/s per edge
    ov = build_overlay(u, [0, 1, 2])
    demands = tuple(demands_from_links([(0, 1)], 1e6, 3))[:1]
    direct = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 1)}),),
        completion_time=8.0, method="direct", solve_seconds=0.0,
    )
    relay = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 2), (2, 1)}),),
        completion_time=16.0, method="direct", solve_seconds=0.0,
    )
    phased = PhasedRoutingSolution(
        demands=demands, boundaries=(0.0, 2.0, 4.0),
        solutions=(direct, relay, direct),
        completion_time=8.0, method="time_expanded", solve_seconds=0.0,
    )
    r = simulate_phased(phased, ov)
    # [0,2): direct ships 250 kB. [2,4): relay branches restart at 1 MB
    # and ship 250 kB each. [4,·): the direct branch was abandoned at
    # t=2, so it restarts at the FULL 1 MB -> 8 s -> done at t=12 (a
    # stale resume of its 750 kB leftover would finish at t=10).
    assert r.makespan == pytest.approx(12.0)
    assert r.flow_completion == (pytest.approx(12.0),)


def test_earlier_delivery_survives_final_segment_churn():
    """Regression: a flow whose final-segment branches are all
    churn-cancelled still reports the finite completion time of the
    branch it delivered in an earlier segment (NaN is reserved for
    unfinished flows and flows that never delivered anything)."""
    import networkx as nx

    from repro.net import ChurnEvent, MulticastDemand, Scenario
    from repro.net.routing import RoutingSolution
    from repro.net.topology import Underlay

    g = nx.Graph()
    g.add_edge(0, 1, capacity=125_000.0)
    g.add_edge(1, 2, capacity=62_500.0)
    ov = build_overlay(Underlay(graph=g), [0, 1, 2])
    demands = (MulticastDemand(0, frozenset({1, 2}), 1e6),)
    # Segment 0: direct tree — branch (0,1) finishes at 8 s, branch
    # (1,2) is still in flight at the t=10 boundary.
    tree_a = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 1), (1, 2)}),),
        completion_time=16.0, method="direct", solve_seconds=0.0,
    )
    # Segment 1: re-route drops the finished (0,1) branch entirely.
    tree_b = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 2), (2, 1)}),),
        completion_time=16.0, method="direct", solve_seconds=0.0,
    )
    phased = PhasedRoutingSolution(
        demands=demands, boundaries=(0.0, 10.0),
        solutions=(tree_a, tree_b),
        completion_time=16.0, method="time_expanded", solve_seconds=0.0,
    )
    # Agent 0 churns at t=12: every final-segment branch of the flow is
    # cancelled — but 1 already received the payload at t=8.
    r = simulate_phased(
        phased, ov,
        scenario=Scenario(churn=(ChurnEvent(agent=0, time=12.0),)),
    )
    assert r.flow_completion == (pytest.approx(8.0),)
    assert r.makespan == pytest.approx(8.0)
    assert r.cancelled_branches == 2


def test_later_segment_revives_churn_emptied_flow():
    """Regression: when churn cancels every active branch mid-segment,
    the phased loop must still enter later segments — a re-route can
    avoid the departed relay and deliver for unfinished flows."""
    from repro.net import ChurnEvent, MulticastDemand, Scenario, line_underlay
    from repro.net.routing import RoutingSolution

    u = line_underlay(3)  # C = 125 kB/s per edge
    ov = build_overlay(u, [0, 1, 2])
    demands = (MulticastDemand(0, frozenset({2}), 1e6),)
    # Segment 0 relays through agent 1; agent 1 departs at t=2, which
    # cancels both branches and empties the active set.
    relay = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 1), (1, 2)}),),
        completion_time=8.0, method="direct", solve_seconds=0.0,
    )
    # Segment 1 (t>=4) routes 0->2 on the direct overlay link, which
    # touches no departed agent and must deliver.
    direct = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 2)}),),
        completion_time=8.0, method="direct", solve_seconds=0.0,
    )
    phased = PhasedRoutingSolution(
        demands=demands, boundaries=(0.0, 4.0),
        solutions=(relay, direct),
        completion_time=8.0, method="time_expanded", solve_seconds=0.0,
    )
    r = simulate_phased(
        phased, ov,
        scenario=Scenario(churn=(ChurnEvent(agent=1, time=2.0),)),
    )
    # Fresh branch (0,2) starts at t=4 with the full 1 MB over the
    # 2-hop path (bottleneck 125 kB/s) -> done at t=12.
    assert r.flow_completion == (pytest.approx(12.0),)
    assert r.makespan == pytest.approx(12.0)
    assert r.cancelled_branches == 2
    assert r.unfinished_branches == 0


def test_base_solution_reused_for_unscaled_segments():
    """Callers holding the static route() pass it as base_solution so
    the unscaled segment is not re-solved bitwise-identically."""
    _, _, cats, demands = _instance(1, 5)
    static = route(demands, cats, 1e6, 5, milp_var_budget=0, seed=1)
    sc = Scenario(capacity_phases=(CapacityPhase(start=4.0, scale=0.5),))
    phased = route_time_expanded(
        demands, cats, sc, 1e6, 5, milp_var_budget=0, seed=1,
        base_solution=static,
    )
    assert phased.solutions[0] is static
    assert phased.metadata["routed_segments"] <= 1


def test_phase_segments_merge_and_order():
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=8.0, scale=0.5),
        CapacityPhase(start=2.0, scale=0.5),
        CapacityPhase(start=0.0, scale=1.0),
        CapacityPhase(start=12.0, scale=1.0),
    ))
    segs = _phase_segments(sc)
    # start<=0 folds into segment 0; 2.0 and 8.0 share a scale and merge;
    # 12.0 recovers to the base scale.
    assert segs == [(0.0, 1.0), (2.0, 0.5), (12.0, 1.0)]


def test_phased_solution_validation():
    _, _, cats, demands = _instance(0, 4)
    sol = route(demands, cats, 1e6, 4, milp_var_budget=0)
    with pytest.raises(ValueError, match="start at t=0"):
        PhasedRoutingSolution(
            demands=tuple(demands), boundaries=(1.0,), solutions=(sol,),
            completion_time=sol.completion_time, method="time_expanded",
            solve_seconds=0.0,
        )
    with pytest.raises(ValueError, match="strictly increasing"):
        PhasedRoutingSolution(
            demands=tuple(demands), boundaries=(0.0, 5.0, 5.0),
            solutions=(sol, sol, sol),
            completion_time=sol.completion_time, method="time_expanded",
            solve_seconds=0.0,
        )


# ---------------------------------------------------------------------------
# Phased simulation parity: shared-tree schedule == single incidence
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 40), m=st.integers(3, 6), churn=st.booleans())
@settings(max_examples=10, deadline=None)
def test_shared_tree_phased_matches_single_incidence(seed, m, churn):
    """Property: a phased solution whose segments all share one tree
    reproduces the single-incidence simulation to rtol=1e-9 — boundary
    swaps are pure bookkeeping when nothing changes."""
    _, ov, cats, demands = _instance(seed, m)
    sol = route(demands, cats, 1e6, m, milp_var_budget=0, seed=seed)
    tau = sol.completion_time
    events = [CapacityPhase(start=0.35 * tau, scale=0.5)]
    sc = Scenario(
        capacity_phases=tuple(events),
        churn=(ChurnEvent(agent=0, time=0.2 * tau),) if churn else (),
    )
    # Boundaries deliberately off the capacity-phase breakpoints: the
    # swap itself becomes an extra event, which must not move totals
    # beyond fp tolerance.
    phased = PhasedRoutingSolution(
        demands=tuple(demands),
        boundaries=(0.0, 0.27 * tau, 0.61 * tau),
        solutions=(sol, sol, sol),
        completion_time=tau,
        method="time_expanded",
        solve_seconds=0.0,
    )
    single = simulate(sol, ov, scenario=sc)
    multi = simulate_phased(phased, ov, scenario=sc)
    assert multi.makespan == pytest.approx(single.makespan, rel=1e-9)
    assert multi.cancelled_branches == single.cancelled_branches
    np.testing.assert_allclose(
        np.asarray(multi.flow_completion),
        np.asarray(single.flow_completion),
        rtol=1e-9,
    )


def test_phased_never_loses_on_degraded_scenario():
    """The benchmark gate in miniature: degrading the mid-path hops of
    several ring links 20× mid-round, the phase-adaptive schedule's
    simulated makespan is <= the static-optimal schedule's."""
    u = random_geometric_underlay(25, radius=0.35, seed=2)
    m = 6
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    links = sorted({(min(i, (i + 1) % m), max(i, (i + 1) % m))
                    for i in range(m)})
    demands = demands_from_links(links, 1e6, m)
    static = route(demands, cats, 1e6, m, milp_var_budget=0, seed=0)
    drop = {}
    for (i, j) in links[:3]:
        for e in ov.path_edges(i, j)[1:-1]:
            drop[(min(e), max(e))] = 0.05
    if not drop:
        pytest.skip("degenerate instance: no mid-path hops to degrade")
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=0.15 * static.completion_time, scale=drop),
    ))
    phased = route_time_expanded(
        demands, cats, sc, 1e6, m, milp_var_budget=0, seed=0
    )
    s_static = simulate(static, ov, scenario=sc)
    s_phased = simulate_phased(phased, ov, scenario=sc)
    assert s_phased.makespan <= s_static.makespan + 1e-9


def test_phased_cache_avoids_rerouting():
    _, _, cats, demands = _instance(1, 5)
    sc = Scenario(capacity_phases=(CapacityPhase(start=3.0, scale=0.5),))
    cache: dict = {}
    first = route_time_expanded(
        demands, cats, sc, 1e6, 5, milp_var_budget=0,
        routing_cache=cache, cache_key="k",
    )
    assert first.metadata["routed_segments"] == 2
    again = route_time_expanded(
        demands, cats, sc, 1e6, 5, milp_var_budget=0,
        routing_cache=cache, cache_key="k",
    )
    assert again.metadata["routed_segments"] == 0
    assert again.solutions == first.solutions


# ---------------------------------------------------------------------------
# Designer wiring
# ---------------------------------------------------------------------------


def test_designer_prices_both_schedules(roofnet_overlay, roofnet_categories):
    from repro.core import ConvergenceConstants, design

    ov = roofnet_overlay
    drop = {}
    for (i, j) in [(0, 1), (1, 2), (2, 3)]:
        for e in ov.path_edges(i, j)[1:-1]:
            drop[(min(e), max(e))] = 0.05
    sc = Scenario(capacity_phases=(CapacityPhase(start=200.0, scale=drop),))
    out = design(
        "ring", roofnet_categories, 94.47e6, 10, overlay=ov, scenario=sc,
        constants=ConvergenceConstants(epsilon=0.05),
        milp_time_limit=5.0, reroute_per_phase=True,
    )
    assert out.phased_routing is not None and out.sim_phased is not None
    assert np.isfinite(out.tau_static_sched)
    assert np.isfinite(out.tau_phased)
    assert out.tau == min(out.tau_static_sched, out.tau_phased)
    assert out.total_time == out.tau * out.iterations_to_eps


def test_designer_reroute_requires_routing_optimizer(roofnet_categories):
    from repro.core import design

    with pytest.raises(ValueError, match="optimize_routing"):
        design(
            "ring", roofnet_categories, 1e6, 10, optimize_routing=False,
            reroute_per_phase=True,
        )
