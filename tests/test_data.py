import numpy as np
import pytest

from repro.data import DataConfig, SyntheticTokenStream


def test_deterministic_batches():
    cfg = DataConfig(vocab_size=128, seq_len=16, num_agents=3, seed=7)
    s1, s2 = SyntheticTokenStream(cfg), SyntheticTokenStream(cfg)
    np.testing.assert_array_equal(s1.batch(1, 5, 4), s2.batch(1, 5, 4))
    assert not np.array_equal(s1.batch(1, 5, 4), s1.batch(1, 6, 4))
    assert not np.array_equal(s1.batch(0, 5, 4), s1.batch(2, 5, 4))


def test_heterogeneity_monotone_in_alpha():
    lo = SyntheticTokenStream(
        DataConfig(vocab_size=256, seq_len=8, num_agents=8,
                   dirichlet_alpha=0.05, seed=1)
    ).heterogeneity()
    hi = SyntheticTokenStream(
        DataConfig(vocab_size=256, seq_len=8, num_agents=8,
                   dirichlet_alpha=50.0, seed=1)
    ).heterogeneity()
    assert lo > hi


def test_stacked_shapes_and_range():
    cfg = DataConfig(vocab_size=64, seq_len=12, num_agents=4)
    s = SyntheticTokenStream(cfg)
    b = s.stacked_batch(0, per_agent_batch=3)
    assert b.shape == (4, 3, 13)
    assert b.min() >= 0 and b.max() < 64
