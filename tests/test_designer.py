import numpy as np
import pytest

from repro.core import ConvergenceConstants, design, sweep_iterations
from repro.net import PAPER_MODEL_BYTES


CONSTS = ConvergenceConstants(epsilon=0.05)


@pytest.mark.parametrize("method", ["clique", "ring", "prim", "fmmd-wp"])
def test_design_methods_produce_valid_outcomes(
    method, roofnet_overlay, roofnet_categories
):
    out = design(
        method, roofnet_categories, PAPER_MODEL_BYTES, 10,
        overlay=roofnet_overlay, iterations=12, constants=CONSTS,
        optimize_routing=False,
    )
    assert 0 <= out.rho < 1
    assert out.tau_bar > 0
    assert np.isfinite(out.total_time)


def test_fmmd_beats_clique_total_time(roofnet_overlay, roofnet_categories):
    """The paper's headline: sparse designed mixing cuts total time."""
    clique = design("clique", roofnet_categories, PAPER_MODEL_BYTES, 10,
                    constants=CONSTS, optimize_routing=False)
    fmmd = design("fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, 10,
                  iterations=12, constants=CONSTS, optimize_routing=False)
    assert fmmd.total_time < clique.total_time


def test_sweep_iterations_returns_finite(roofnet_categories):
    out = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(8, 12), constants=CONSTS,
    )
    assert np.isfinite(out.total_time)
