import numpy as np
import pytest

from repro.core import ConvergenceConstants, design, sweep_iterations
from repro.net import PAPER_MODEL_BYTES


CONSTS = ConvergenceConstants(epsilon=0.05)


@pytest.mark.parametrize("method", ["clique", "ring", "prim", "fmmd-wp"])
def test_design_methods_produce_valid_outcomes(
    method, roofnet_overlay, roofnet_categories
):
    out = design(
        method, roofnet_categories, PAPER_MODEL_BYTES, 10,
        overlay=roofnet_overlay, iterations=12, constants=CONSTS,
        optimize_routing=False,
    )
    assert 0 <= out.rho < 1
    assert out.tau_bar > 0
    assert np.isfinite(out.total_time)


def test_fmmd_beats_clique_total_time(roofnet_overlay, roofnet_categories):
    """The paper's headline: sparse designed mixing cuts total time."""
    clique = design("clique", roofnet_categories, PAPER_MODEL_BYTES, 10,
                    constants=CONSTS, optimize_routing=False)
    fmmd = design("fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, 10,
                  iterations=12, constants=CONSTS, optimize_routing=False)
    assert fmmd.total_time < clique.total_time


def test_sweep_iterations_returns_finite(roofnet_categories):
    out = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(8, 12), constants=CONSTS,
    )
    assert np.isfinite(out.total_time)


def test_sweep_iterations_forwards_scenario_and_routing_flags(
    roofnet_overlay, roofnet_categories
):
    """Satellite: the sweep can price the T grid under a scenario and
    skip the routing optimizer / cap the MILP."""
    from repro.net import CapacityPhase, Scenario

    plain = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(12,), constants=CONSTS, overlay=roofnet_overlay,
        optimize_routing=False,
    )
    degraded = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(12,), constants=CONSTS, overlay=roofnet_overlay,
        optimize_routing=False,
        scenario=Scenario(
            capacity_phases=(CapacityPhase(start=0.0, scale=0.5),)
        ),
    )
    assert plain.sim is None and degraded.sim is not None
    assert degraded.tau == pytest.approx(2 * plain.tau)
    assert degraded.total_time == pytest.approx(2 * plain.total_time)
    capped = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(12,), constants=CONSTS, milp_time_limit=5.0,
    )
    assert np.isfinite(capped.total_time)


def test_sweep_routing_cache_reuses_solutions(roofnet_categories):
    """Grid points activating the same link set are routed once."""
    from repro.core.designer import evaluate_design
    from repro.core.fmmd import fmmd

    d = fmmd(10, 8)
    cache: dict = {}
    a = evaluate_design(
        d, roofnet_categories, PAPER_MODEL_BYTES, 10, constants=CONSTS,
        optimize_routing=False, routing_cache=cache,
    )
    assert len(cache) == 1
    b = evaluate_design(
        d, roofnet_categories, PAPER_MODEL_BYTES, 10, constants=CONSTS,
        optimize_routing=False, routing_cache=cache,
    )
    assert b.routing is a.routing  # same object: served from the cache


def test_sweep_method_parameter(roofnet_overlay, roofnet_categories):
    out = sweep_iterations(
        roofnet_categories, PAPER_MODEL_BYTES, 10,
        iteration_grid=(12,), constants=CONSTS, method="fmmd-p",
        optimize_routing=False,
    )
    assert out.design.variant == "FMMD-P"
    assert np.isfinite(out.total_time)


def test_per_edge_phases_with_inferred_categories_fail_fast(
    roofnet_overlay,
):
    """Regression: ``evaluate_design(scenario=...)`` with per-edge
    ``CapacityPhase`` scales and *inferred* categories used to crash
    with a deep ``ValueError`` from ``Categories.scaled`` inside the
    routing stack; the designer now raises an actionable error naming
    the fix before any routing work."""
    from repro.core.designer import evaluate_design
    from repro.core.fmmd import fmmd
    from repro.net import (
        CapacityPhase,
        Scenario,
        compute_categories,
        infer_categories,
    )

    inferred = infer_categories(roofnet_overlay)
    d = fmmd(10, 6)
    edge = next(iter(compute_categories(roofnet_overlay).edge_capacity))
    scen = Scenario(
        capacity_phases=(CapacityPhase(start=10.0, scale={edge: 0.5}),)
    )
    with pytest.raises(ValueError, match="compute_categories"):
        evaluate_design(
            d, inferred, PAPER_MODEL_BYTES, 10, overlay=roofnet_overlay,
            scenario=scen, reroute_per_phase=True, milp_time_limit=1.0,
        )
    # Scalar phases on inferred categories keep working.
    out = evaluate_design(
        d, inferred, PAPER_MODEL_BYTES, 10, overlay=roofnet_overlay,
        scenario=Scenario(
            capacity_phases=(CapacityPhase(start=10.0, scale=0.5),)
        ),
        reroute_per_phase=True, milp_time_limit=1.0,
    )
    assert np.isfinite(out.tau)
    # Ground-truth categories accept per-edge phases.
    truth = compute_categories(roofnet_overlay)
    out = evaluate_design(
        d, truth, PAPER_MODEL_BYTES, 10, overlay=roofnet_overlay,
        scenario=scen, reroute_per_phase=True, milp_time_limit=1.0,
    )
    assert np.isfinite(out.tau)
