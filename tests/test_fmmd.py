import numpy as np
import pytest

from repro.core import mixing
from repro.core.fmmd import _tau_bar, fmmd, fmmd_wp, theorem35_bound


def test_activated_links_bounded_by_iterations(roofnet_categories):
    for t in (4, 8, 16):
        res = fmmd(10, t)
        assert len(res.activated_links) <= t
        mixing.validate_mixing(res.matrix)


def test_theorem35_rho_bound(roofnet_categories):
    """ρ(W^(T)) ≤ (m−3)/m + 16/(T+2) for m>3, T>16m/3−2 (eq. 34)."""
    m = 10
    t = 64  # > 16·10/3 − 2 ≈ 51.3
    res = fmmd(m, t)
    bound = (m - 3) / m + 16 / (t + 2)
    assert res.rho <= bound + 1e-9


def test_priority_reduces_tau_bar(roofnet_categories):
    kappa = 1e6
    plain = fmmd(10, 12)
    prio = fmmd(10, 12, categories=roofnet_categories, kappa=kappa,
                priority=True)
    tb = lambda r: _tau_bar(frozenset(r.activated_links),
                            roofnet_categories, kappa)
    assert tb(prio) <= tb(plain) + 1e-9


def test_weight_opt_improves_rho(roofnet_categories):
    plain = fmmd(10, 16)
    wopt = fmmd(10, 16, weight_opt=True)
    assert wopt.rho <= plain.rho + 1e-9


def test_fmmd_wp_runs_and_returns_valid(roofnet_categories):
    res = fmmd_wp(10, 12, roofnet_categories, 1e6)
    mixing.validate_mixing(res.matrix)
    assert res.variant == "FMMD-WP"
    assert 0 <= res.rho < 1.0


def test_theorem35_bound_requires_regime():
    with pytest.raises(ValueError):
        theorem35_bound(m=3, iterations=100, c_min=1.0, kappa=1.0)
    with pytest.raises(ValueError):
        theorem35_bound(m=10, iterations=10, c_min=1.0, kappa=1.0)
    b = theorem35_bound(m=10, iterations=60, c_min=125000.0, kappa=1e6)
    assert np.isfinite(b) and b > 0
