import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing
from repro.core.fmmd import (
    _PriorityState,
    _tau_bar,
    fmmd,
    fmmd_wp,
    theorem35_bound,
)


def test_activated_links_bounded_by_iterations(roofnet_categories):
    for t in (4, 8, 16):
        res = fmmd(10, t)
        assert len(res.activated_links) <= t
        mixing.validate_mixing(res.matrix)


def test_theorem35_rho_bound(roofnet_categories):
    """ρ(W^(T)) ≤ (m−3)/m + 16/(T+2) for m>3, T>16m/3−2 (eq. 34)."""
    m = 10
    t = 64  # > 16·10/3 − 2 ≈ 51.3
    res = fmmd(m, t)
    bound = (m - 3) / m + 16 / (t + 2)
    assert res.rho <= bound + 1e-9


def test_priority_reduces_tau_bar(roofnet_categories):
    kappa = 1e6
    plain = fmmd(10, 12)
    prio = fmmd(10, 12, categories=roofnet_categories, kappa=kappa,
                priority=True)
    tb = lambda r: _tau_bar(frozenset(r.activated_links),
                            roofnet_categories, kappa)
    assert tb(prio) <= tb(plain) + 1e-9


def _categories_for_priority_tests():
    """Module-cached 10-agent roofnet categories (the @given fallback
    wrapper cannot inject pytest fixtures)."""
    global _PRIO_CATS
    try:
        return _PRIO_CATS
    except NameError:
        from repro.net import (
            build_overlay, compute_categories, lowest_degree_nodes,
            roofnet_like,
        )

        u = roofnet_like(seed=0)
        _PRIO_CATS = compute_categories(
            build_overlay(u, lowest_degree_nodes(u, 10))
        )
        return _PRIO_CATS


@given(seed=st.integers(0, 50), picks=st.integers(0, 6))
@settings(max_examples=10, deadline=None)
def test_priority_state_matches_tau_bar(seed, picks):
    """The vectorized FMMD-P filter's candidate τ̄ table is bitwise equal
    to the reference per-atom ``_tau_bar`` rebuild, at any loads state."""
    cats = _categories_for_priority_tests()
    m, kappa = 10, 1e6
    atoms = [(i, j) for i in range(m) for j in range(i + 1, m)]
    state = _PriorityState(atoms, m, cats, kappa)
    rng = np.random.default_rng(seed)
    selected: set = set()
    for _ in range(picks):
        a = atoms[int(rng.integers(len(atoms)))]
        if a not in selected:
            selected.add(a)
            state.select(a)
    assert state.current_tau() == _tau_bar(frozenset(selected), cats, kappa)
    taus = state.candidate_taus(len(atoms))
    for q, a in enumerate(atoms):
        if a in selected:
            continue
        assert taus[q] == _tau_bar(frozenset(selected | {a}), cats, kappa)


def test_weight_opt_improves_rho(roofnet_categories):
    plain = fmmd(10, 16)
    wopt = fmmd(10, 16, weight_opt=True)
    assert wopt.rho <= plain.rho + 1e-9


def test_fmmd_wp_runs_and_returns_valid(roofnet_categories):
    res = fmmd_wp(10, 12, roofnet_categories, 1e6)
    mixing.validate_mixing(res.matrix)
    assert res.variant == "FMMD-WP"
    assert 0 <= res.rho < 1.0


def test_theorem35_bound_requires_regime():
    with pytest.raises(ValueError):
        theorem35_bound(m=3, iterations=100, c_min=1.0, kappa=1.0)
    with pytest.raises(ValueError):
        theorem35_bound(m=10, iterations=10, c_min=1.0, kappa=1.0)
    b = theorem35_bound(m=10, iterations=60, c_min=125000.0, kappa=1e6)
    assert np.isfinite(b) and b > 0
