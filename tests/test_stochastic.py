"""Stochastic scenario layer: sampling determinism, realization
structure, online re-routing degeneracy/carryover, and the designer's
seeded-expectation pricing."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CorrelatedOutages,
    MarkovLinkModel,
    Scenario,
    StochasticScenario,
    build_overlay,
    carryover_state,
    compute_categories,
    demands_from_links,
    mid_path_edges,
    random_geometric_underlay,
    route,
    route_time_expanded,
    simulate,
    simulate_phased,
)
from repro.net.routing import (
    PhasedRoutingSolution,
    _carryover_completion_time,
)


def _instance(seed: int, m: int):
    u = random_geometric_underlay(12, radius=0.5, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.6
    ] or [(0, 1)]
    demands = demands_from_links(links, 1e6, m)
    return u, ov, cats, demands


_mid_path_edges = mid_path_edges  # the canonical helper, short alias


def _two_state(edges, stay_good=0.5, stay_bad=0.75, drop=0.05, initial=0):
    return MarkovLinkModel(
        edges=edges, scales=(1.0, drop),
        transition=(
            (stay_good, 1.0 - stay_good),
            (1.0 - stay_bad, stay_bad),
        ),
        initial=initial,
    )


# ---------------------------------------------------------------------------
# Sampling determinism and structure
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 40), m=st.integers(3, 6), key=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_same_key_bitwise_identical_realization_and_makespan(seed, m, key):
    """Property: the same key draws a bitwise-identical realization, and
    simulating the same schedule under both draws gives the *identical*
    makespan — stochastic pricing is a seeded expectation, not a flaky
    number."""
    _, ov, cats, demands = _instance(seed, m)
    sol = route(demands, cats, 1e6, m, milp_var_budget=0, seed=seed)
    tau = sol.completion_time
    edges = _mid_path_edges(ov, [(i, (i + 1) % m) for i in range(m - 1)])
    if not edges:
        edges = ((0, 1),)
    sto = StochasticScenario(
        links=(_two_state(edges),),
        outages=CorrelatedOutages(
            groups=(edges[:1], edges[-1:]), shock_prob=0.3,
            group_prob=0.8, duration_steps=2, scale=0.1,
        ),
        step=0.4 * tau, horizon=6 * tau,
        churn_agents=(0,), churn_hazard=0.05,
    )
    r1, r2 = sto.sample(key), sto.sample(key)
    assert r1 == r2  # dataclass equality over phases/churn: bitwise draw
    assert r1.capacity_phases == r2.capacity_phases
    assert r1.churn == r2.churn
    churned = {c.agent for c in r1.churn}
    phased = PhasedRoutingSolution(
        demands=tuple(demands), boundaries=(0.0,), solutions=(sol,),
        completion_time=tau, method="static", solve_seconds=0.0,
    )
    s1 = simulate_phased(phased, ov, scenario=r1)
    s2 = simulate_phased(phased, ov, scenario=r2)
    if not churned:  # churn can cancel everything; makespan 0 == 0 then
        assert s1.makespan > 0
    assert s1.makespan == s2.makespan
    assert s1.flow_completion == s2.flow_completion


@given(seed=st.integers(0, 30), m=st.integers(3, 5))
@settings(max_examples=10, deadline=None)
def test_different_keys_draw_distinct_schedules(seed, m):
    """Property: distinct keys give distinct phase schedules (with a
    fair-coin chain over 30+ steps, collisions are ~2^-29)."""
    _, ov, cats, _ = _instance(seed, m)
    edges = _mid_path_edges(ov, [(0, 1)]) or ((0, 1),)
    sto = StochasticScenario(
        links=(_two_state(edges, stay_good=0.5, stay_bad=0.5),),
        step=1.0, horizon=40.0,
    )
    assert sto.sample(seed) != sto.sample(seed + 1)
    assert (
        sto.sample(seed).capacity_phases
        != sto.sample(seed + 1).capacity_phases
    )


def test_realizations_are_minimal_piecewise_constant():
    """Consecutive boundaries with an unchanged scale map emit no phase,
    recovery to base capacity emits a scalar 1.0 phase, and a chain
    starting degraded emits its phase at t=0."""
    edges = ((0, 1), (1, 2))
    # Deterministic chain: degraded at t=0, recovers at step 1, stays.
    model = MarkovLinkModel(
        edges=edges, scales=(1.0, 0.25),
        transition=((1.0, 0.0), (1.0, 0.0)), initial=1,
    )
    sto = StochasticScenario(links=(model,), step=10.0, horizon=50.0)
    r = sto.sample(123)
    assert r.capacity_phases == (
        CapacityPhase(start=0.0, scale={(0, 1): 0.25, (1, 2): 0.25}),
        CapacityPhase(start=10.0, scale=1.0),
    )


def test_degenerate_one_state_realization_is_trivial():
    model = MarkovLinkModel(
        edges=((0, 1),), scales=(1.0,), transition=((1.0,),)
    )
    sto = StochasticScenario(links=(model,), step=5.0, horizon=50.0)
    assert sto.is_trivial
    for key in (0, 7, 123):
        assert sto.sample(key).is_trivial


def test_correlated_outages_share_the_shock():
    """With group_prob=1, every group sags at the same boundaries —
    outages are correlated, not independent."""
    g1, g2 = ((0, 1),), ((2, 3),)
    sto = StochasticScenario(
        outages=CorrelatedOutages(
            groups=(g1, g2), shock_prob=0.5, group_prob=1.0,
            duration_steps=1, scale=0.1,
        ),
        step=1.0, horizon=20.0,
    )
    r = sto.sample(3)
    assert r.capacity_phases  # some shock fired in 20 fair coin flips
    for ph in r.capacity_phases:
        if isinstance(ph.scale, dict):
            # Both groups always sag together.
            assert set(ph.scale) == {(0, 1), (2, 3)}


def test_base_scenario_events_ride_along_and_phases_rejected():
    base = Scenario(churn=(ChurnEvent(agent=1, time=7.0),))
    sto = StochasticScenario(
        links=(MarkovLinkModel(
            edges=((0, 1),), scales=(1.0,), transition=((1.0,),)
        ),),
        step=5.0, horizon=20.0, base=base,
    )
    assert sto.sample(0).churn == base.churn
    with pytest.raises(ValueError, match="capacity phases"):
        StochasticScenario(
            links=(), step=5.0, horizon=20.0,
            base=Scenario(capacity_phases=(
                CapacityPhase(start=1.0, scale=0.5),
            )),
        ).sample(0)


def test_validation_rejects_bad_models():
    with pytest.raises(ValueError, match="sum to 1"):
        MarkovLinkModel(
            edges=((0, 1),), scales=(1.0, 0.5),
            transition=((0.5, 0.4), (0.5, 0.5)),
        ).validate()
    with pytest.raises(ValueError, match="positive"):
        MarkovLinkModel(
            edges=((0, 1),), scales=(0.0,), transition=((1.0,),)
        ).validate()
    with pytest.raises(ValueError, match="initial"):
        MarkovLinkModel(
            edges=((0, 1),), scales=(1.0,), transition=((1.0,),), initial=2
        ).validate()
    with pytest.raises(ValueError, match="shock_prob"):
        CorrelatedOutages(groups=(((0, 1),),), shock_prob=1.5).validate()
    with pytest.raises(ValueError, match="horizon"):
        StochasticScenario(step=10.0, horizon=5.0).sample(0)
    with pytest.raises(ValueError, match="churn_agents"):
        StochasticScenario(
            step=1.0, horizon=10.0, churn_hazard=0.5
        ).sample(0)


# ---------------------------------------------------------------------------
# Online re-routing: degeneracy and carryover awareness
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 40), m=st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_online_degenerate_one_state_is_static_route_bitwise(seed, m):
    """Regression/property: online re-routing under a degenerate
    one-state Markov process is bitwise-identical to static route() —
    the stochastic mirror of PR 3's trivial-scenario property."""
    _, ov, cats, demands = _instance(seed, m)
    static = route(demands, cats, 1e6, m, milp_var_budget=0, seed=seed)
    sto = StochasticScenario(
        links=(MarkovLinkModel(
            edges=((0, 1),), scales=(1.0,), transition=((1.0,),)
        ),),
        step=5.0, horizon=50.0,
    )
    realization = sto.sample(seed)
    online = route_time_expanded(
        demands, cats, realization, 1e6, m, milp_var_budget=0, seed=seed,
        online=True, overlay=ov,
    )
    assert online.num_segments == 1
    assert online.boundaries == (0.0,)
    assert online.solutions[0].trees == static.trees
    assert online.solutions[0].completion_time == static.completion_time
    assert online.metadata["reroutes"] == 0


def test_online_requires_overlay():
    _, _, cats, demands = _instance(0, 4)
    with pytest.raises(ValueError, match="overlay"):
        route_time_expanded(
            demands, cats, Scenario(), 1e6, 4, milp_var_budget=0,
            online=True,
        )


def test_online_keeps_nearly_finished_transfer():
    """Carryover awareness: when a late degradation arrives after most
    volume has shipped, the online router must NOT abandon the in-flight
    tree — the restart cost exceeds the remaining-volume cost — even
    though the full-volume closed form (the offline swap guard's
    objective) prefers the re-route. Hand-computed on a triangle."""
    import networkx as nx

    from repro.net import MulticastDemand
    from repro.net.topology import Underlay

    g = nx.Graph()
    for e in ((0, 1), (1, 2), (0, 2)):
        g.add_edge(*e, capacity=125_000.0)
    ov = build_overlay(Underlay(graph=g), [0, 1, 2])
    cats = compute_categories(ov)
    demands = (MulticastDemand(0, frozenset({1}), 1e6),)
    static = route(demands, cats, 1e6, 3, milp_var_budget=0)
    assert static.trees == (frozenset({(0, 1)}),)  # direct: 8 s
    # At t=6 (75% shipped, 250 kB left) the 0-1 edge sags 3×.
    #   keep:   250 kB at 41.67 kB/s  → 6 s more  (finish t=12)
    #   switch: full 1 MB restart via 0→2→1 → 8 s (finish t=14)
    # Full-volume closed form says switch (8 s < 24 s); carryover says
    # keep (6 s < 8 s) — and keep is what actually wins.
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=6.0, scale={(0, 1): 1 / 3}),
    ))
    online = route_time_expanded(
        demands, cats, sc, 1e6, 3, milp_var_budget=0, online=True,
        overlay=ov, base_solution=static,
    )
    assert online.num_segments == 2
    assert online.solutions[1].trees == static.trees, (
        "online router abandoned a 75%-complete transfer"
    )
    assert online.metadata["reroutes"] == 0
    offline = route_time_expanded(
        demands, cats, sc, 1e6, 3, milp_var_budget=0,
        base_solution=static,
    )
    assert offline.metadata["reroutes"] == 1  # the myopic guard swaps
    s_online = simulate_phased(online, ov, scenario=sc)
    s_offline = simulate_phased(offline, ov, scenario=sc)
    assert s_online.makespan == pytest.approx(12.0)
    assert s_offline.makespan == pytest.approx(14.0)
    assert s_online.makespan < s_offline.makespan


def test_online_never_loses_to_static_on_persistent_markov():
    """The benchmark gate in miniature: persistent Markov degradation of
    mid-path hops; the online schedule's simulated makespan is <= the
    static schedule's on every sampled realization."""
    u = random_geometric_underlay(25, radius=0.35, seed=2)
    m = 6
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    links = sorted({(min(i, (i + 1) % m), max(i, (i + 1) % m))
                    for i in range(m)})
    demands = demands_from_links(links, 1e6, m)
    static = route(demands, cats, 1e6, m, milp_var_budget=0, seed=0)
    edges = _mid_path_edges(ov, links[:3])
    if not edges:
        pytest.skip("degenerate instance: no mid-path hops to degrade")
    tau = static.completion_time
    sto = StochasticScenario(
        links=(_two_state(edges, stay_good=0.8, stay_bad=0.95),),
        step=0.5 * tau, horizon=8 * tau,
    )
    for key in range(4):
        realization = sto.sample(key)
        s_static = simulate(static, ov, scenario=realization)
        online = route_time_expanded(
            demands, cats, realization, 1e6, m, milp_var_budget=0,
            seed=0, online=True, overlay=ov, base_solution=static,
        )
        s_online = simulate_phased(online, ov, scenario=realization)
        assert s_online.makespan <= s_static.makespan + 1e-9


# ---------------------------------------------------------------------------
# Carryover snapshots (what the online router observes)
# ---------------------------------------------------------------------------


def test_carryover_state_exact_on_line():
    """Hand-computed snapshot: 1 MB over a 125 kB/s link, stopped at
    t=3 → 625 kB remaining; at t=10 → done at 8 s."""
    from repro.net import line_underlay, route_direct

    u = line_underlay(2)
    ov = build_overlay(u, [0, 1])
    cats = compute_categories(ov)
    demands = demands_from_links([(0, 1)], 1e6, 2)[:1]
    sol = route_direct(demands, cats, 1e6)
    phased = PhasedRoutingSolution(
        demands=tuple(demands), boundaries=(0.0,), solutions=(sol,),
        completion_time=8.0, method="static", solve_seconds=0.0,
    )
    mid = carryover_state(phased, ov, 3.0)
    assert mid.time == pytest.approx(3.0)
    assert mid.remaining == {(0, 0, 1): pytest.approx(625_000.0)}
    assert mid.done == {}
    assert math.isnan(mid.flow_done[0])
    end = carryover_state(phased, ov, 10.0)
    assert end.remaining == {}
    assert end.done == {(0, 0, 1): pytest.approx(8.0)}
    assert end.flow_done[0] == pytest.approx(8.0)
    fresh = carryover_state(phased, ov, 0.0)
    assert fresh.remaining == {} and fresh.done == {}
    assert math.isnan(fresh.flow_done[0])


def test_carryover_snapshot_applies_no_future_conditions():
    """No lookahead: a capacity phase starting exactly at the snapshot
    instant (or later) must not affect the observed state."""
    from repro.net import line_underlay, route_direct

    u = line_underlay(2)
    ov = build_overlay(u, [0, 1])
    cats = compute_categories(ov)
    demands = demands_from_links([(0, 1)], 1e6, 2)[:1]
    sol = route_direct(demands, cats, 1e6)
    phased = PhasedRoutingSolution(
        demands=tuple(demands), boundaries=(0.0,), solutions=(sol,),
        completion_time=8.0, method="static", solve_seconds=0.0,
    )
    future = Scenario(capacity_phases=(
        CapacityPhase(start=4.0, scale=0.01),
    ))
    snap = carryover_state(phased, ov, 4.0, scenario=future)
    clean = carryover_state(phased, ov, 4.0)
    assert snap.remaining == clean.remaining


def test_carryover_objective_charges_restart():
    """_carryover_completion_time: keeping in-flight trees prices the
    remainder; switching to fresh links prices the full restart."""
    from repro.net import line_underlay, route_direct

    u = line_underlay(3)
    ov = build_overlay(u, [0, 1, 2])
    cats = compute_categories(ov)
    demands = demands_from_links([(0, 1)], 1e6, 3)[:1]
    sol = route_direct(demands, cats, 1e6)
    phased = PhasedRoutingSolution(
        demands=tuple(demands), boundaries=(0.0,), solutions=(sol,),
        completion_time=8.0, method="static", solve_seconds=0.0,
    )
    state = carryover_state(phased, ov, 6.0)  # 250 kB left of 1 MB
    keep = _carryover_completion_time(
        (frozenset({(0, 1)}),), demands, cats, state
    )
    switch = _carryover_completion_time(
        (frozenset({(0, 2), (2, 1)}),), demands, cats, state
    )
    assert keep == pytest.approx(2.0)  # 250 kB at 125 kB/s
    assert switch == pytest.approx(8.0)  # full 1 MB restart
    # A finished flow carries nothing on any trees.
    done = carryover_state(phased, ov, 20.0)
    assert _carryover_completion_time(
        (frozenset({(0, 2), (2, 1)}),), demands, cats, done
    ) == 0.0


# ---------------------------------------------------------------------------
# Designer wiring (seeded expectation)
# ---------------------------------------------------------------------------


def test_designer_stochastic_expectation(roofnet_overlay, roofnet_categories):
    from repro.core import ConvergenceConstants, design

    ov = roofnet_overlay
    edges = _mid_path_edges(ov, [(0, 1), (1, 2), (2, 3)])
    sto = StochasticScenario(
        links=(_two_state(edges, stay_good=0.8, stay_bad=0.95),),
        step=700.0, horizon=10_000.0,
    )
    kwargs = dict(
        overlay=ov, constants=ConvergenceConstants(epsilon=0.05),
        stochastic=sto, stochastic_rollouts=3, milp_time_limit=5.0,
        reroute_per_phase=True,
    )
    out = design("ring", roofnet_categories, 94.47e6, 10, **kwargs)
    assert len(out.tau_samples) == 3
    assert out.tau == out.tau_mean == pytest.approx(
        float(np.mean(out.tau_samples))
    )
    assert out.tau_p95 == pytest.approx(
        float(np.percentile(out.tau_samples, 95.0))
    )
    assert out.total_time == out.tau_mean * out.iterations_to_eps
    # Online deployment never loses to the static schedule in expectation
    # on the persistent regime.
    assert out.tau_phased <= out.tau_static_sched + 1e-9
    # Same seed => identical samples (reproducible expectation).
    again = design("ring", roofnet_categories, 94.47e6, 10, **kwargs)
    assert again.tau_samples == out.tau_samples


def test_designer_rejects_scenario_plus_stochastic(
    roofnet_overlay, roofnet_categories
):
    from repro.core import design

    sto = StochasticScenario(step=1.0, horizon=10.0)
    with pytest.raises(ValueError, match="not both"):
        design(
            "ring", roofnet_categories, 1e6, 10, overlay=roofnet_overlay,
            scenario=Scenario(), stochastic=sto,
        )
    with pytest.raises(ValueError, match="overlay"):
        design("ring", roofnet_categories, 1e6, 10, stochastic=sto)
