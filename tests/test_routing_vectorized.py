"""Vectorized congestion-aware router: parity with the retained
reference engine, CategoryIncidence consistency, the MILP-skip front
door, and heuristic quality vs. the exact MILP."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    build_overlay,
    compile_category_incidence,
    compute_categories,
    demands_from_links,
    random_geometric_underlay,
    route,
    route_congestion_aware,
    route_direct,
    route_milp,
)
from repro.net.routing import (
    _route_congestion_aware_reference,
    validate_solution,
)


def _random_instance(seed: int, m: int, kappa: float = 1e6):
    u = random_geometric_underlay(14, radius=0.45, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.5
    ] or [(0, 1)]
    return demands_from_links(links, kappa, m), cats


@given(seed=st.integers(0, 80), m=st.integers(4, 8))
@settings(max_examples=15, deadline=None)
def test_vectorized_router_matches_reference(seed, m):
    """Property: same seed → identical trees, hence τ_vec ≤ τ_ref (with
    equality) and never worse than direct routing."""
    demands, cats = _random_instance(seed, m)
    ref = _route_congestion_aware_reference(demands, cats, 1e6, m, seed=seed)
    vec = route_congestion_aware(demands, cats, 1e6, m, seed=seed)
    assert vec.trees == ref.trees
    assert vec.completion_time == ref.completion_time
    assert vec.completion_time <= ref.completion_time + 1e-12
    direct = route_direct(demands, cats, 1e6)
    assert vec.completion_time <= direct.completion_time + 1e-9
    validate_solution(vec, m)


@given(seed=st.integers(0, 50), m=st.integers(4, 7))
@settings(max_examples=10, deadline=None)
def test_precompiled_incidence_is_equivalent(seed, m):
    """Passing a precompiled CategoryIncidence must not change results."""
    demands, cats = _random_instance(seed, m)
    inc = compile_category_incidence(cats, m, 1e6)
    a = route_congestion_aware(demands, cats, 1e6, m, seed=seed)
    b = route_congestion_aware(
        demands, cats, 1e6, m, seed=seed, incidence=inc
    )
    assert a.trees == b.trees
    assert a.completion_time == b.completion_time


@given(seed=st.integers(0, 60), m=st.integers(4, 7))
@settings(max_examples=10, deadline=None)
def test_incidence_loads_match_dict_path(seed, m):
    """CategoryIncidence load/completion arithmetic ≡ the Categories
    dict implementation on arbitrary link-use maps."""
    demands, cats = _random_instance(seed, m)
    inc = compile_category_incidence(cats, m, 1e6)
    sol = route_direct(demands, cats, 1e6)
    uses = sol.link_uses()
    loads = inc.loads_from_uses(uses)
    t = cats.load_vector(uses)
    for fi, F in enumerate(cats.families):
        assert loads[fi] == t[F]
    assert inc.completion_time(loads) == cats.completion_time(uses, 1e6)


def test_incidence_rejects_mismatched_instance():
    demands, cats = _random_instance(0, 5)
    inc = compile_category_incidence(cats, 5, 1e6)
    with pytest.raises(ValueError, match="incidence compiled"):
        route_congestion_aware(demands, cats, 2e6, 5, incidence=inc)
    _, other = _random_instance(7, 5)  # same m/κ, different categories
    with pytest.raises(ValueError, match="different categories"):
        route_congestion_aware(demands, other, 1e6, 5, incidence=inc)


def test_route_empty_demands_has_metadata():
    _, cats = _random_instance(0, 5)
    sol = route([], cats, 1e6, 5)
    assert sol.method == "empty"
    assert sol.metadata["candidate_times"] == {}


def test_route_records_candidate_times(roofnet_categories):
    kappa = 1e6
    demands = demands_from_links([(0, 1), (2, 3)], kappa, 10)
    best = route(demands, roofnet_categories, kappa, 10, time_limit=30)
    times = best.metadata["candidate_times"]
    assert "direct" in times
    assert times[best.method] == best.completion_time
    assert all(best.completion_time <= t + 1e-12 for t in times.values())


def test_route_skips_heuristic_when_milp_optimal(roofnet_categories):
    """Satellite: a proven-optimal MILP makes the heuristic redundant."""
    kappa = 1e6
    demands = demands_from_links([(0, 1), (2, 3)], kappa, 10)
    milp = route_milp(demands, roofnet_categories, kappa, 10, time_limit=30)
    assert milp is not None and milp.metadata["milp_status"] == 0
    best = route(demands, roofnet_categories, kappa, 10, time_limit=30)
    times = best.metadata["candidate_times"]
    assert "milp" in times and "congestion_aware" not in times


def test_route_runs_heuristic_when_milp_out_of_budget(roofnet_categories):
    kappa = 1e6
    demands = demands_from_links([(0, 1), (2, 3)], kappa, 10)
    best = route(
        demands, roofnet_categories, kappa, 10, milp_var_budget=0,
        time_limit=30,
    )
    times = best.metadata["candidate_times"]
    assert "congestion_aware" in times and "milp" not in times


@given(seed=st.integers(0, 30), m=st.integers(5, 7))
@settings(max_examples=6, deadline=None)
def test_heuristic_within_factor_of_milp(seed, m):
    """Satellite: congestion-aware τ ≤ 1.5 × MILP τ on small instances."""
    rng = np.random.default_rng(seed)
    u = random_geometric_underlay(14, radius=0.45, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.35
    ][:4] or [(0, 1)]
    demands = demands_from_links(links, 1e6, m)
    milp = route_milp(demands, cats, 1e6, m, time_limit=20)
    if milp is None or milp.metadata["milp_status"] != 0:
        pytest.skip("MILP did not prove optimality in time")
    heur = route_congestion_aware(demands, cats, 1e6, m, seed=seed)
    assert heur.completion_time <= 1.5 * milp.completion_time + 1e-9
