"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 device;
multi-device coverage lives in test_multidevice.py via subprocesses."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def roofnet_overlay():
    from repro.net import build_overlay, lowest_degree_nodes, roofnet_like

    u = roofnet_like(seed=0)
    return build_overlay(u, lowest_degree_nodes(u, 10))


@pytest.fixture(scope="session")
def roofnet_categories(roofnet_overlay):
    from repro.net import compute_categories

    return compute_categories(roofnet_overlay)
