"""Vectorized simulator: parity with the reference engine, scenario
semantics, and the gossip traffic bound."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mixing
from repro.core.gossip import build_schedule, gossip_collective_bytes
from repro.core.topology_baselines import clique_design
from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CrossTraffic,
    MulticastDemand,
    Scenario,
    StragglerEvent,
    build_overlay,
    compile_incidence,
    compute_categories,
    demands_from_links,
    line_underlay,
    random_geometric_underlay,
    route_congestion_aware,
    route_direct,
    simulate,
)
from repro.net.routing import RoutingSolution
from repro.net.simulator import _maxmin_rates, _maxmin_rates_vec


def _random_instance(seed: int, m: int, relay: bool = False):
    u = random_geometric_underlay(12, radius=0.5, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.6
    ] or [(0, 1)]
    demands = demands_from_links(links, 1e6, m)
    if relay:
        sol = route_congestion_aware(demands, cats, 1e6, m, rounds=2)
    else:
        sol = route_direct(demands, cats, 1e6)
    return sol, ov


@given(seed=st.integers(0, 60), m=st.integers(3, 7))
@settings(max_examples=15, deadline=None)
def test_vectorized_engine_matches_reference(seed, m):
    """Property: both engines agree bitwise on random direct routings,
    for both fairness models."""
    sol, ov = _random_instance(seed, m)
    for fairness in ("maxmin", "equal"):
        ref = simulate(sol, ov, fairness=fairness, engine="reference")
        vec = simulate(sol, ov, fairness=fairness, engine="vectorized")
        assert vec.makespan == ref.makespan
        assert vec.flow_completion == ref.flow_completion
        assert vec.num_events == ref.num_events


@given(seed=st.integers(0, 40), m=st.integers(3, 6))
@settings(max_examples=8, deadline=None)
def test_vectorized_engine_matches_reference_relayed(seed, m):
    """Same parity on relayed (congestion-aware) routings, whose branches
    traverse longer multi-overlay-hop underlay paths."""
    sol, ov = _random_instance(seed, m, relay=True)
    ref = simulate(sol, ov, engine="reference")
    vec = simulate(sol, ov, engine="vectorized")
    assert vec.makespan == ref.makespan
    assert vec.flow_completion == ref.flow_completion


@given(seed=st.integers(0, 50), m=st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_maxmin_rate_vectors_match(seed, m):
    """The allocators themselves agree rate-by-rate on the full set."""
    sol, ov = _random_instance(seed, m)
    inc = compile_incidence(sol, ov)
    branches = sol.unicast_branches(ov)
    capacity = ov.underlay.directed_capacities()
    ref = _maxmin_rates(
        list(range(len(branches))),
        [edges for _, _, edges in branches],
        capacity,
    )
    vec = _maxmin_rates_vec(
        np.ones(len(branches), dtype=bool), inc, inc.base_capacity
    )
    assert np.array_equal(ref, vec)


@given(seed=st.integers(0, 80), m=st.integers(3, 7), relay=st.booleans())
@settings(max_examples=15, deadline=None)
def test_batched_engine_makespan_parity(seed, m, relay):
    """Satellite: the opt-in batched water-filling engine (freeze all
    tied bottlenecks per round) agrees with the default engine on the
    makespan to rtol=1e-9, and never takes more allocation rounds."""
    sol, ov = _random_instance(seed, m, relay=relay)
    vec = simulate(sol, ov, engine="vectorized")
    bat = simulate(sol, ov, engine="batched")
    assert bat.makespan == pytest.approx(vec.makespan, rel=1e-9)
    assert np.allclose(
        bat.flow_completion, vec.flow_completion, rtol=1e-9
    )


def test_batched_engine_scenario_parity():
    """Batched engine consumes scenarios like the default one."""
    sol, ov = _line_instance()
    sc = Scenario(capacity_phases=(CapacityPhase(start=4.0, scale=0.5),))
    assert simulate(sol, ov, scenario=sc, engine="batched").makespan == (
        pytest.approx(12.0)
    )


def test_unknown_engine_rejected():
    """The rejection names every valid engine, so the fix for a typo'd
    engine= is in the message itself."""
    sol, ov = _line_instance()
    with pytest.raises(ValueError, match="unknown engine 'turbo'") as ei:
        simulate(sol, ov, engine="turbo")
    msg = str(ei.value)
    for name in ("'batched'", "'vectorized'", "'reference'", "'jax'"):
        assert name in msg, msg


@given(m=st.integers(3, 9), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_gossip_bytes_bounded_by_clique(m, seed):
    """gossip_collective_bytes(schedule, κ) ≤ m(m−1)κ, with equality
    exactly for the clique design."""
    kappa = 1e6
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.5
    ]
    alpha = rng.uniform(0.05, 0.4, len(links))
    w = mixing.matrix_from_weights(m, links, alpha)
    sched = build_schedule(w)
    got = gossip_collective_bytes(sched, kappa)
    bound = m * (m - 1) * kappa
    assert got <= bound + 1e-6
    if len(links) < m * (m - 1) // 2:
        assert got < bound
    clique = build_schedule(clique_design(m).matrix)
    assert gossip_collective_bytes(clique, kappa) == pytest.approx(bound)


# ---------------------------------------------------------------------------
# Scenario semantics (deterministic 2-agent line: one link, capacity C)
# ---------------------------------------------------------------------------


def _line_instance(kappa=1e6, capacity=125_000.0):
    u = line_underlay(2, capacity=capacity)
    ov = build_overlay(u, [0, 1])
    cats = compute_categories(ov)
    demands = demands_from_links([(0, 1)], kappa, 2)
    return route_direct(demands, cats, kappa), ov


def test_capacity_phase_exact():
    # κ=1e6, C=125k → τ=8s static. Halving C at t=4 doubles the rest:
    # 4s at full rate ships half, the other half at C/2 takes 8s → 12s.
    sol, ov = _line_instance()
    sc = Scenario(capacity_phases=(CapacityPhase(start=4.0, scale=0.5),))
    r = simulate(sol, ov, scenario=sc)
    assert r.makespan == pytest.approx(12.0)


def test_capacity_phase_recovery():
    sol, ov = _line_instance()
    sc = Scenario(
        capacity_phases=(
            CapacityPhase(start=2.0, scale=0.5),
            CapacityPhase(start=6.0, scale=1.0),
        )
    )
    # 2s full (2/8 done), 4s half (2/8 more), rest (4/8) full → 4s more.
    r = simulate(sol, ov, scenario=sc)
    assert r.makespan == pytest.approx(10.0)


def test_cross_traffic_exact():
    # Background flow eats 20% of the link for the whole transfer:
    # τ = κ / (0.8 C) = 10s.
    sol, ov = _line_instance()
    sc = Scenario(
        cross_traffic=(CrossTraffic(src=0, dst=1, rate=0.2 * 125_000.0),)
    )
    r = simulate(sol, ov, scenario=sc)
    assert r.makespan == pytest.approx(10.0)


def test_straggler_throttles_rate():
    sol, ov = _line_instance()
    sc = Scenario(stragglers=(StragglerEvent(agent=0, slowdown=4.0),))
    r = simulate(sol, ov, scenario=sc)
    assert r.makespan == pytest.approx(32.0)  # 4× the 8s static time


def test_fully_cancelled_flow_reports_nan():
    """Regression: a flow whose branches were ALL churn-cancelled must
    report NaN, not 0.0 — "nothing delivered" must be distinguishable
    from "finished instantly"."""
    u = random_geometric_underlay(12, radius=0.5, seed=0)
    ov = build_overlay(u, list(u.graph.nodes)[:3])
    cats = compute_categories(ov)
    sol = route_direct(demands_from_links([(0, 1), (1, 2)], 1e6, 3),
                       cats, 1e6)
    # Agent 0 departs mid-run: its sourced multicast (flow 0) loses every
    # branch; flows 1 and 2 keep their surviving exchanges.
    r = simulate(
        sol, ov, scenario=Scenario(churn=(ChurnEvent(agent=0, time=0.5),))
    )
    assert np.isnan(r.flow_completion[0])
    assert np.isfinite(r.flow_completion[1])
    assert np.isfinite(r.flow_completion[2])
    assert r.makespan > 0  # survivors still finished

    # The designer's undelivered check keys off the NaN signal: a
    # partially-churned round still prices at the survivors' makespan.
    from repro.core.designer import evaluate_design
    from repro.core.topology_baselines import ring_design

    out = evaluate_design(
        ring_design(3), cats, 1e6, 3, overlay=ov,
        optimize_routing=False,
        scenario=Scenario(churn=(ChurnEvent(agent=0, time=0.5),)),
    )
    assert np.isfinite(out.tau) and out.tau > 0


def test_churn_cancels_branches():
    # Both agents multicast over the single link; agent 1 leaving kills
    # both directions (its own flow and the branch targeting it).
    sol, ov = _line_instance()
    sc = Scenario(churn=(ChurnEvent(agent=1, time=1.0),))
    r = simulate(sol, ov, scenario=sc)
    assert r.cancelled_branches == 2
    assert r.makespan == 0.0  # nothing completed

    # 3-agent line: the far agent leaving spares the 0↔1 exchange.
    u = line_underlay(3)
    ov3 = build_overlay(u, [0, 1, 2])
    cats = compute_categories(ov3)
    sol3 = route_direct(
        demands_from_links([(0, 1), (1, 2)], 1e6, 3), cats, 1e6
    )
    r3 = simulate(
        sol3, ov3, scenario=Scenario(churn=(ChurnEvent(agent=2, time=1.0),))
    )
    assert r3.cancelled_branches == 2
    assert r3.makespan == pytest.approx(8.0)  # 0↔1 finishes alone


def test_out_of_range_agent_rejected():
    sol, ov = _line_instance()
    for sc in (
        Scenario(churn=(ChurnEvent(agent=7, time=1.0),)),
        Scenario(stragglers=(StragglerEvent(agent=-1, slowdown=2.0),)),
    ):
        with pytest.raises(ValueError, match="agent"):
            simulate(sol, ov, scenario=sc)


def test_all_churned_design_prices_as_inf():
    from repro.core.designer import design

    u = random_geometric_underlay(12, radius=0.5, seed=0)
    ov = build_overlay(u, list(u.graph.nodes)[:5])
    cats = compute_categories(ov)
    dead = design(
        "ring", cats, 1e6, 5, overlay=ov, optimize_routing=False,
        scenario=Scenario(
            churn=tuple(ChurnEvent(agent=a, time=0.0) for a in range(5))
        ),
    )
    assert dead.tau == np.inf and dead.total_time == np.inf


def test_trivial_scenario_is_static():
    sol, ov = _line_instance()
    assert (
        simulate(sol, ov, scenario=Scenario()).makespan
        == simulate(sol, ov).makespan
    )


def test_scenario_rejected_by_reference_engine():
    sol, ov = _line_instance()
    sc = Scenario(capacity_phases=(CapacityPhase(start=1.0, scale=0.5),))
    with pytest.raises(ValueError, match="vectorized"):
        simulate(sol, ov, scenario=sc, engine="reference")


def test_empty_tree_raises():
    demand = MulticastDemand(source=0, destinations=frozenset({1}), size=1e6)
    sol = RoutingSolution(
        demands=(demand,), trees=(frozenset(),), completion_time=0.0,
        method="direct", solve_seconds=0.0,
    )
    _, ov = _line_instance()
    with pytest.raises(ValueError, match="empty routing tree"):
        simulate(sol, ov)


def test_integer_demand_sizes_are_safe():
    """Satellite fix: int κ must not truncate the remaining-bytes array."""
    u = line_underlay(3)
    ov = build_overlay(u, [0, 1, 2])
    cats = compute_categories(ov)
    for engine in ("vectorized", "reference"):
        ints = simulate(
            route_direct(demands_from_links([(0, 1), (1, 2)], 10**6, 3),
                         cats, 10**6),
            ov, engine=engine,
        )
        floats = simulate(
            route_direct(demands_from_links([(0, 1), (1, 2)], 1e6, 3),
                         cats, 1e6),
            ov, engine=engine,
        )
        assert ints.makespan == pytest.approx(floats.makespan)


def test_runtime_scenario_bridges():
    """stragglers/fault_tolerance helpers produce consumable scenarios."""
    from repro.runtime.fault_tolerance import failure_scenario
    from repro.runtime.stragglers import StragglerSimulator

    sol, ov = _line_instance()
    events = StragglerSimulator(
        num_agents=2, prob=1.0, severity=3.0, seed=0
    ).scenario_events(horizon=100.0, round_time=50.0)
    assert events and all(e.slowdown == 3.0 for e in events)
    r = simulate(sol, ov, scenario=Scenario(stragglers=events))
    assert r.makespan == pytest.approx(24.0)  # 3× the 8s static time

    sc = failure_scenario(
        {1: 4.0}, pre_failure_slowdown=2.0, slowdown_window=2.0
    )
    assert sc.churn[0].time == 4.0
    assert sc.stragglers[0].start == pytest.approx(2.0)
    r2 = simulate(sol, ov, scenario=sc)
    # 2s at C, 2s limping, then the peer churns away → both cancelled.
    assert r2.cancelled_branches == 2


def test_designer_scenario_pricing():
    from repro.core.designer import design
    from repro.core import mixing as mixing_lib

    u = random_geometric_underlay(12, radius=0.5, seed=0)
    ov = build_overlay(u, list(u.graph.nodes)[:5])
    cats = compute_categories(ov)
    static = design(
        "ring", cats, 1e6, 5, overlay=ov, optimize_routing=False,
    )
    degraded = design(
        "ring", cats, 1e6, 5, overlay=ov, optimize_routing=False,
        scenario=Scenario(
            capacity_phases=(CapacityPhase(start=0.0, scale=0.5),)
        ),
    )
    assert degraded.sim is not None
    assert degraded.tau == pytest.approx(2 * static.tau)
    assert degraded.total_time == pytest.approx(2 * static.total_time)
