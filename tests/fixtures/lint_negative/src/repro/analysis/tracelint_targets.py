"""Planted tracelint targets — one per jaxpr-level sub-check: an f32
promotion inside the trace, a host callback inside the "one launch",
and an entry split across two jitted calls (the companion manifest
additionally budgets a target that does not exist)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.tracelint import TraceCase, TraceTarget

jax.config.update("jax_enable_x64", True)


@jax.jit
def _promote(x):
    # planted: narrow-float-in-trace (+ narrow-float-literal)
    return x.astype(jnp.float32) * jnp.float32(3.0)


@jax.jit
def _with_callback(x):
    # planted: host-callback
    y = jax.pure_callback(
        lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x
    )
    return y + 1.0


@jax.jit
def _half1(x):
    return x * 2.0


@jax.jit
def _half2(x):
    return x + 1.0


def _split(x):
    return _half2(_half1(x))  # planted: multiple-launches


def _args():
    return (np.arange(4, dtype=np.float64),)


TARGETS = (
    TraceTarget(
        name="planted-f32",
        path="src/repro/net/bad_dtype.py",
        scope="price",
        cases=(TraceCase("f32", lambda: (_promote, _args())),),
    ),
    TraceTarget(
        name="planted-callback",
        path="src/repro/net/bad_retrace.py",
        scope="with_callback",
        cases=(TraceCase("cb", lambda: (_with_callback, _args())),),
    ),
    TraceTarget(
        name="planted-split",
        path="src/repro/net/bad_retrace.py",
        scope="split",
        cases=(TraceCase("split", lambda: (_split, _args())),),
    ),
)
