"""Planted retrace violations for the tracelint AST pass: a Python
branch on a traced value, a closure-captured module-level array, and
an unhashable static argument at a jit call site."""

import jax
import jax.numpy as jnp
import numpy as np

_LOOKUP = np.array([1.0, 2.0, 4.0], dtype=np.float64)


@jax.jit
def clip_positive(x):
    if x > 0:  # planted: traced-python-branch
        return x
    return -x


@jax.jit
def lookup_scale(x):
    return x * jnp.asarray(_LOOKUP)  # planted: closure-captured-array


def _scale_impl(x, mode):
    return x * len(mode)


scale = jax.jit(_scale_impl, static_argnames=("mode",))


def run(x):
    return scale(x, mode=[1, 2])  # planted: unhashable-static-arg
