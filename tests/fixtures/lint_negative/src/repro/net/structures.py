"""Planted contracts violation: one CSR structure lost its hook.

All registered contract classes are defined so the only contracts
finding is the planted one: ``CategoryIncidence`` has no
``__post_init__`` -> ``maybe_validate`` wiring.
"""

import dataclasses

from repro.analysis.contracts import maybe_validate


@dataclasses.dataclass(frozen=True)
class BranchIncidence:
    flows: object

    def __post_init__(self):
        maybe_validate(self)


@dataclasses.dataclass(frozen=True)
class CategoryIncidence:  # planted: missing-contract-hook
    capacity: object


@dataclasses.dataclass(frozen=True)
class DeviceIncidence:
    source: object

    def __post_init__(self):
        maybe_validate(self)


@dataclasses.dataclass(frozen=True)
class _FlatCategories:
    entry_link: object

    def __post_init__(self):
        maybe_validate(self)
