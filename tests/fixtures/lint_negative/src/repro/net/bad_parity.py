"""Planted parity violation: a reference with no manifest entry."""


def _planted_reference(x):  # planted: unregistered-reference
    return sorted(x)


def planted_fast(x):
    return sorted(x)
