"""Planted dtype violation: float32 on a pricing path."""

import numpy as np


def price(loads, capacity):
    return (loads / capacity).astype(np.float32)  # planted: narrow-float
