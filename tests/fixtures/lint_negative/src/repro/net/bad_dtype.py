"""Planted dtype violations: float32 and an implicit jnp dtype on a
pricing path."""

import jax.numpy as jnp
import numpy as np


def price(loads, capacity):
    return (loads / capacity).astype(np.float32)  # planted: narrow-float


def pad(n):
    return jnp.zeros(n)  # planted: implicit-jnp-dtype
