"""Planted dtype violations: float32 casts (attribute, string, and
method spellings) and an implicit jnp dtype on a pricing path."""

import jax.numpy as jnp
import numpy as np


def price(loads, capacity):
    return (loads / capacity).astype(np.float32)  # planted: narrow-float


def pad(n):
    return jnp.zeros(n)  # planted: implicit-jnp-dtype


def reinterpret(x):
    return x.view("float32")  # planted: narrow-dtype-string (method)


def shrink(x):
    return x.astype("single")  # planted: narrow-dtype-string (alias)
