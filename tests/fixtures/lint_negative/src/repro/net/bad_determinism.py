"""Planted determinism violation: OS-entropy-seeded generator."""

import numpy as np


def sample_capacities(n):
    rng = np.random.default_rng()  # planted: unseeded-default-rng
    return rng.random(n)
