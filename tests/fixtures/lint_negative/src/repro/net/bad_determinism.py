"""Planted determinism violations: OS-entropy-seeded generator and a
literal-minted jax PRNG key in library code."""

import jax
import numpy as np


def sample_capacities(n):
    rng = np.random.default_rng()  # planted: unseeded-default-rng
    return rng.random(n)


def sample_mask(n):
    key = jax.random.PRNGKey(0)  # planted: fresh-prng-key
    return jax.random.bernoulli(key, 0.5, (n,))
