import numpy as np
import pytest

from repro.configs.base import TRAIN_4K, DECODE_32K, get_config, get_train_config
from repro.roofline import analysis
from repro.roofline import analytic

HLO = """
HloModule test

%body (p: (s32[], f32[16,8])) -> (s32[], f32[16,8]) {
  %p = (s32[], f32[16,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,8] get-tuple-element(%p), index=1
  %ar = f32[16,8] all-reduce(%x), to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[16,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[16,8])) -> pred[] {
  %p = (s32[], f32[16,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[16,8]) -> f32[16,8] {
  %a = f32[16,8] parameter(0)
  %cp = f32[16,8] collective-permute(%a), source_target_pairs={{0,1}}
  %init = (s32[], f32[16,8]) tuple(s32[] constant(0), %cp)
  %w = (s32[], f32[16,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[16,8] get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_flat():
    st = analysis.parse_collectives(HLO)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.count_by_kind["collective-permute"] == 1
    assert st.bytes_by_kind["collective-permute"] == 16 * 8 * 4


def test_parse_collectives_nested_multiplies_trip_count():
    st = analysis.parse_collectives_nested(HLO)
    # all-reduce sits in a while body with trip count 24
    assert st.bytes_by_kind["all-reduce"] == 24 * 16 * 8 * 4
    assert st.bytes_by_kind["collective-permute"] == 16 * 8 * 4


def test_analytic_train_model_scales_with_tokens():
    cfg = get_config("qwen2-0.5b")
    tcfg = get_train_config("qwen2-0.5b")
    mesh = {"data": 16, "model": 16}
    m1 = analytic.train_model(cfg, TRAIN_4K, tcfg, mesh, 16, 64)
    import dataclasses

    half = dataclasses.replace(TRAIN_4K, global_batch=128)
    m2 = analytic.train_model(cfg, half, tcfg, mesh, 16, 64)
    assert m1.flops_global == pytest.approx(2 * m2.flops_global, rel=1e-6)
    assert m1.collective_bytes_per_chip > 0


def test_analytic_decode_memory_dominated_by_params_plus_cache():
    cfg = get_config("mistral-large-123b")
    mesh = {"data": 16, "model": 16}
    m = analytic.serve_model(cfg, DECODE_32K, mesh)
    from repro.models import model as M

    assert m.hbm_bytes_global > M.parameter_count(cfg) * 2


def test_model_flops_moe_counts_active_only():
    dense = get_config("mistral-large-123b")
    moe = get_config("mixtral-8x22b")
    f_moe = analysis.model_flops(moe, TRAIN_4K)
    # 39B active of 141B total
    from repro.models import model as M

    ratio = f_moe / (6.0 * M.parameter_count(moe) * TRAIN_4K.global_batch
                     * TRAIN_4K.seq_len)
    assert 0.2 < ratio < 0.35
