"""Bitwise parity of the vectorized category pipeline against the
retained references: ``compute_categories`` vs
``_compute_categories_reference`` (same family keys in the same order,
same member-edge order, same capacities) and
``compile_category_incidence`` vs ``_compile_category_incidence_reference``
(same CSR entry order and dtypes), plus the batched path-edge extraction
and the τ̄-via-incidence fast path."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.fmmd import _tau_bar
from repro.net import (
    build_overlay,
    compile_category_incidence,
    compute_categories,
    dumbbell_underlay,
    infer_categories,
    random_geometric_underlay,
    roofnet_like,
)
from repro.net.categories import (
    _compile_category_incidence_reference,
    _compute_categories_reference,
)


def _random_overlay(seed: int, m: int):
    u = random_geometric_underlay(25, radius=0.35, seed=seed)
    rng = np.random.default_rng(seed + 1000)
    for _, _, data in u.graph.edges(data=True):
        data["capacity"] = 125_000.0 * rng.uniform(0.3, 3.0)
    return build_overlay(u, list(u.graph.nodes)[:m])


def _assert_categories_bitwise(vec, ref):
    # list() compares keys AND insertion order; values bitwise.
    assert list(vec.members.items()) == list(ref.members.items())
    assert list(vec.capacity.items()) == list(ref.capacity.items())
    assert list(vec.edge_capacity.items()) == list(ref.edge_capacity.items())


def _assert_incidence_bitwise(fast, slow):
    assert fast.num_agents == slow.num_agents
    assert fast.kappa == slow.kappa
    for name in ("capacity", "entry_link", "entry_cat", "entry_coef",
                 "link_ptr"):
        a, b = getattr(fast, name), getattr(slow, name)
        assert a.dtype == b.dtype, name
        assert np.array_equal(a, b), name


@given(seed=st.integers(0, 40), m=st.integers(2, 8))
@settings(max_examples=12, deadline=None)
def test_compute_categories_bitwise_matches_reference(seed, m):
    ov = _random_overlay(seed, m)
    _assert_categories_bitwise(
        compute_categories(ov), _compute_categories_reference(ov)
    )


def test_compute_categories_bitwise_on_paper_instances(roofnet_overlay):
    _assert_categories_bitwise(
        compute_categories(roofnet_overlay),
        _compute_categories_reference(roofnet_overlay),
    )
    ov = build_overlay(dumbbell_underlay(), [0, 1, 2, 3])
    _assert_categories_bitwise(
        compute_categories(ov), _compute_categories_reference(ov)
    )


@given(seed=st.integers(0, 40), m=st.integers(2, 8))
@settings(max_examples=12, deadline=None)
def test_compile_incidence_bitwise_matches_reference(seed, m):
    """Both on the flat-payload-carrying Categories and on the
    payload-free reference output (fallback path)."""
    ov = _random_overlay(seed, m)
    kappa = 1e6
    vec = compute_categories(ov)
    ref = _compute_categories_reference(ov)
    assert vec.flat is not None and ref.flat is None
    fast = compile_category_incidence(vec, m, kappa)
    slow = _compile_category_incidence_reference(ref, m, kappa)
    _assert_incidence_bitwise(fast, slow)
    # Fallback path (no payload) is the reference bitwise as well.
    _assert_incidence_bitwise(
        compile_category_incidence(ref, m, kappa), slow
    )


@given(seed=st.integers(0, 30), scale=st.floats(0.1, 4.0))
@settings(max_examples=10, deadline=None)
def test_scaled_categories_keep_payload_and_compile_bitwise(seed, scale):
    """``Categories.scaled`` propagates the CSR payload; compiling the
    scaled categories stays bitwise vs the reference compiler."""
    ov = _random_overlay(seed, 6)
    vec = compute_categories(ov).scaled(scale)
    assert vec.flat is not None
    _assert_incidence_bitwise(
        compile_category_incidence(vec, 6, 2e6),
        _compile_category_incidence_reference(vec, 6, 2e6),
    )


def test_inferred_categories_carry_payload_and_compile_bitwise(
    roofnet_overlay,
):
    m = roofnet_overlay.num_agents
    inf = infer_categories(roofnet_overlay, capacity_noise=0.2, seed=3)
    assert inf.flat is not None
    _assert_incidence_bitwise(
        compile_category_incidence(inf, m, 1e6),
        _compile_category_incidence_reference(inf, m, 1e6),
    )


@given(seed=st.integers(0, 40), m=st.integers(2, 7))
@settings(max_examples=10, deadline=None)
def test_batched_path_edges_matches_per_link_loop(seed, m):
    """argsort(rank) recovers exactly the reference double loop's
    (link, edge) traversal sequence."""
    ov = _random_overlay(seed, m)
    link, eu, ev, rank = ov.batched_path_edges()
    order = np.argsort(rank)
    got = list(zip(link[order], eu[order], ev[order]))
    expected = []
    for li, (i, j) in enumerate(ov.directed_overlay_links):
        for (u, v) in ov.path_edges(i, j):
            expected.append((li, u, v))
    assert got == expected


def test_batched_path_edges_empty_overlay():
    u = dumbbell_underlay()
    ov = build_overlay(u, [0])
    link, eu, ev, rank = ov.batched_path_edges()
    assert link.size == ev.size == eu.size == rank.size == 0
    cats = compute_categories(ov)
    assert cats.members == {} and cats.capacity == {}


@given(seed=st.integers(0, 30), nlinks=st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_tau_bar_incidence_path_bitwise(seed, nlinks):
    ov = _random_overlay(seed, 7)
    cats = compute_categories(ov)
    kappa = 1e6
    inc = compile_category_incidence(cats, 7, kappa)
    rng = np.random.default_rng(seed)
    links = frozenset(
        tuple(sorted(rng.choice(7, 2, replace=False).tolist()))
        for _ in range(nlinks)
    )
    assert _tau_bar(links, cats, kappa, incidence=inc) == _tau_bar(
        links, cats, kappa
    )


def test_nonconsecutive_node_ids_still_bitwise():
    """Node ids need not be 0..N-1; the edge-code encoding only assumes
    nonnegative ints."""
    import networkx as nx

    from repro.net import Underlay

    g = nx.Graph()
    for a, b in [(5, 17), (17, 40), (40, 5), (17, 99), (99, 40)]:
        g.add_edge(a, b, capacity=1000.0 + a + b)
    u = Underlay(graph=g)
    ov = build_overlay(u, [5, 99, 40])
    _assert_categories_bitwise(
        compute_categories(ov), _compute_categories_reference(ov)
    )


@pytest.mark.parametrize(
    "nodes",
    [
        (0.5, 1.5, 2.5),  # float ids: int64 cast would truncate silently
        (4_000_000_000, 4_000_000_001, 4_000_000_002),  # id² overflows
    ],
)
def test_unencodable_node_ids_fall_back_to_reference(nodes):
    """Node ids the int64 edge-code encoding cannot represent take the
    reference path instead of crashing on a bogus decoded edge (or
    silently mis-grouping on a truncation collision)."""
    import networkx as nx

    from repro.net import Underlay

    g = nx.Graph()
    a, b, c = nodes
    g.add_edge(a, b, capacity=1000.0)
    g.add_edge(b, c, capacity=2000.0)
    u = Underlay(graph=g)
    ov = build_overlay(u, [a, c])
    _assert_categories_bitwise(
        compute_categories(ov), _compute_categories_reference(ov)
    )
