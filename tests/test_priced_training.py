"""Per-round τ accounting for the network-priced training loop.

Covers the PR-9 contracts: charged wall-clock is the *bitwise* running
sum of per-round simulated τ on a deterministic scenario; a mid-run
redesign switches the charged τ to the new design's on the correct
round; stochastic pricing reuses the designer's seeded samples; the
replayable log round-trips through JSON; and the gossip-strategy /
heterogeneity plug points (multi-round gossip, FedProx, FedDyn) ride
the same pricing path.
"""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConvergenceConstants,
    GossipStrategy,
    PhasedTau,
    PricedTrainLog,
    RoundRecord,
    StaticTau,
    StochasticTau,
    consensus_distance,
    design,
    feddyn_init,
    make_dpsgd_step,
    make_feddyn_step,
    pricer_for,
    train_priced,
)
from repro.core.gossip import effective_mixing_matrix
from repro.core.weight_opt import optimize_weights
from repro.net import (
    CapacityPhase,
    CrossTraffic,
    MarkovLinkModel,
    PAPER_MODEL_BYTES,
    Scenario,
    StochasticScenario,
    mid_path_edges,
)
from repro.net.simulator import simulate

CONSTS = ConvergenceConstants(epsilon=0.05)


def _quadratic(m=6):
    """Heterogeneous quadratic: agent i pulls toward target i."""
    targets = jnp.arange(m, dtype=jnp.float32)[:, None]
    loss_fn = lambda p, b: jnp.mean((p["x"] - b) ** 2)
    params = {"x": jnp.zeros((m, 1))}
    ring = [(min(i, (i + 1) % m), max(i, (i + 1) % m)) for i in range(m)]
    w = jnp.asarray(
        optimize_weights(m, ring, steps=200).matrix, jnp.float32
    )
    return params, targets, loss_fn, w


# ---------------------------------------------------------------------------
# Scenario.shifted
# ---------------------------------------------------------------------------


def test_shifted_zero_is_identity_and_negative_raises():
    sc = Scenario(capacity_phases=(CapacityPhase(start=5.0, scale=0.5),))
    assert sc.shifted(0.0) is sc
    with pytest.raises(ValueError):
        sc.shifted(-1.0)


def test_shifted_reanchors_active_capacity_phase():
    sc = Scenario(
        capacity_phases=(
            CapacityPhase(start=0.0, scale=1.0),
            CapacityPhase(start=10.0, scale=0.5),
            CapacityPhase(start=20.0, scale=0.25),
        )
    )
    sh = sc.shifted(12.0)
    # phase active at t0=12 (scale 0.5) becomes the t=0 phase; the
    # later breakpoint slides to 20-12=8.
    assert sh.capacity_phases[0] == CapacityPhase(start=0.0, scale=0.5)
    assert sh.capacity_phases[1] == CapacityPhase(start=8.0, scale=0.25)


def test_shifted_clips_windows_and_reemits_past_churn():
    from repro.net.simulator import ChurnEvent

    sc = Scenario(
        cross_traffic=(
            CrossTraffic(src=0, dst=1, rate=1e6, start=5.0, stop=8.0),
            CrossTraffic(src=1, dst=2, rate=1e6, start=20.0, stop=30.0),
        ),
        churn=(ChurnEvent(agent=3, time=4.0), ChurnEvent(agent=4, time=15.0)),
    )
    sh = sc.shifted(10.0)
    # the 5-8s window is entirely in the past -> dropped; the 20-30s
    # window slides to 10-20s.
    assert len(sh.cross_traffic) == 1
    ct = sh.cross_traffic[0]
    assert (ct.start, ct.stop) == (10.0, 20.0)
    # departures are absorbing: the past churn re-emits at t=0, the
    # future one slides.
    assert [(c.agent, c.time) for c in sh.churn] == [(3, 0.0), (4, 5.0)]


# ---------------------------------------------------------------------------
# Bitwise wall-clock accounting (deterministic scenario)
# ---------------------------------------------------------------------------


def test_wall_clock_is_bitwise_sum_of_simulated_tau(
    roofnet_overlay, roofnet_categories
):
    """Tentpole contract: on a deterministic (phased) scenario every
    step's charged τ is the simulated makespan under the phase active
    at the round's wall-clock start, and the logged wall-clock is the
    bitwise running float sum of those τ."""
    out = design(
        "fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, 10,
        overlay=roofnet_overlay, iterations=12, constants=CONSTS,
        optimize_routing=False,
    )
    # capacity halves globally partway through round 3
    t_sag = 2.5 * out.tau
    sc = Scenario(capacity_phases=(CapacityPhase(start=t_sag, scale=0.5),))
    pricer = pricer_for(out, mode="phased", overlay=roofnet_overlay,
                        scenario=sc)

    params, targets, loss_fn, _ = _quadratic(10)
    targets = targets[:10]
    step = make_dpsgd_step(loss_fn, learning_rate=0.05)
    w = jnp.asarray(out.design.matrix, jnp.float32)
    params, log = train_priced(
        params, step, lambda k: targets, w, pricer, num_steps=6,
        design_label=out.name,
    )
    log.validate()

    # independent bitwise replay of the accounting
    wall = 0.0
    for r in log.records:
        ref = simulate(
            out.routing, roofnet_overlay,
            scenario=(None if (sh := sc.shifted(wall)).is_trivial else sh),
        ).makespan
        assert r.tau == float(ref)  # exact, same pricing path
        wall += r.tau
        assert r.wall_clock == wall  # bitwise, same accumulation order
    # the sag engaged: early rounds cost τ, late rounds cost more
    assert log.records[0].tau == pytest.approx(out.tau)
    assert log.records[-1].tau > 1.5 * log.records[0].tau
    assert all(r.pricing == "phased" for r in log.records)


def test_redesign_switches_charged_tau_on_correct_round():
    params, targets, loss_fn, w = _quadratic(6)
    step = make_dpsgd_step(loss_fn, learning_rate=0.05)
    w2 = jnp.asarray(np.full((6, 6), 1.0 / 6.0, np.float64))
    params, log = train_priced(
        params, step, lambda k: targets, w,
        StaticTau(16.0, label="old"), num_steps=8,
        design_label="old",
        redesigns={4: ("new", w2, StaticTau(8.0, label="new"))},
    )
    log.validate()
    assert [r.tau for r in log.records] == [16.0] * 4 + [8.0] * 4
    assert [r.design for r in log.records] == ["old"] * 4 + ["new"] * 4
    # bitwise: the switch lands exactly at the redesign step
    assert log.records[3].wall_clock == 64.0
    assert log.records[4].wall_clock == 72.0


# ---------------------------------------------------------------------------
# Stochastic pricing
# ---------------------------------------------------------------------------


def test_stochastic_from_outcome_reuses_designer_samples(
    roofnet_overlay, roofnet_categories
):
    out = design(
        "fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, 10,
        overlay=roofnet_overlay, iterations=12, constants=CONSTS,
        optimize_routing=False,
    )
    hops = mid_path_edges(roofnet_overlay, out.design.activated_links)
    sto = StochasticScenario(
        links=(MarkovLinkModel(
            edges=tuple(hops), scales=(1.0, 0.2),
            transition=((0.8, 0.2), (0.3, 0.7)),
        ),),
        step=max(out.tau / 2, 1.0), horizon=4 * max(out.tau, 1.0),
    )
    priced = design(
        "fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, 10,
        overlay=roofnet_overlay, iterations=12, constants=CONSTS,
        optimize_routing=False, stochastic=sto, stochastic_rollouts=8,
    )
    reuse = StochasticTau.from_outcome(priced)
    assert reuse.samples == priced.tau_samples
    assert reuse.tau_for(0, 0.0) == pytest.approx(np.mean(priced.tau_samples))

    # pricer_for with stochastic=None falls back to the donated samples
    via_factory = pricer_for(priced, mode="stochastic")
    assert via_factory.samples == priced.tau_samples

    # jax one-launch pricing matches the numpy simulate loop exactly
    cache: dict = {}
    jax_p = StochasticTau.price(
        out, roofnet_overlay, sto, rollouts=8, seed=3, engine="jax",
        routing_cache=cache,
    )
    np_p = StochasticTau.price(
        out, roofnet_overlay, sto, rollouts=8, seed=3, engine="batched",
    )
    np.testing.assert_allclose(jax_p.samples, np_p.samples, rtol=1e-9)
    assert (
        "jax-device-incidence",
        frozenset(out.design.activated_links),
    ) in cache

    # sample mode cycles the seeded samples -> replayable per-round τ
    s = StochasticTau(samples=(1.0, 2.0, 3.0), reduce="sample")
    assert [s.tau_for(k, 0.0) for k in range(5)] == [1.0, 2.0, 3.0, 1.0, 2.0]
    assert StochasticTau(samples=(1.0, 2.0, 3.0), reduce="p95").tau_for(
        7, 0.0
    ) == pytest.approx(np.percentile([1.0, 2.0, 3.0], 95))


# ---------------------------------------------------------------------------
# Replayable log
# ---------------------------------------------------------------------------


def test_log_json_roundtrip_preserves_bitwise_accounting():
    params, targets, loss_fn, w = _quadratic(6)
    step = make_dpsgd_step(loss_fn, learning_rate=0.05)
    _, log = train_priced(
        params, step, lambda k: targets, w, StaticTau(7.3), num_steps=9,
        log_every=4,
    )
    log2 = PricedTrainLog.from_json(log.to_json())
    log2.validate()
    assert len(log2.records) == len(log.records)
    for a, b in zip(log.records, log2.records):
        for f in ("step", "design", "pricing", "gossip_rounds"):
            assert getattr(a, f) == getattr(b, f)
        for f in ("tau", "wall_clock", "loss"):
            assert getattr(a, f) == getattr(b, f)  # bitwise through repr
        assert (a.consensus == b.consensus) or (
            math.isnan(a.consensus) and math.isnan(b.consensus)
        )
    # consensus is logged on the log_every grid + final step only
    logged = [r.step for r in log.records if not math.isnan(r.consensus)]
    assert logged == [0, 4, 8]


def test_time_to_loss():
    recs = [
        RoundRecord(step=k, design="d", pricing="static", gossip_rounds=1,
                    tau=2.0, wall_clock=2.0 * (k + 1), loss=1.0 - 0.1 * k)
        for k in range(5)
    ]
    log = PricedTrainLog(records=recs)
    assert log.time_to_loss(0.85) == 6.0  # first step with loss <= 0.85
    assert log.time_to_loss(-1.0) == float("inf")
    assert log.total_wall == 10.0


# ---------------------------------------------------------------------------
# Strategy / heterogeneity plug points
# ---------------------------------------------------------------------------


def test_multi_round_gossip_charges_r_rounds_and_mixes_w_pow_r():
    m = 6
    params, targets, loss_fn, w = _quadratic(m)
    np.testing.assert_allclose(
        effective_mixing_matrix(np.asarray(w), 3),
        np.linalg.matrix_power(np.asarray(w, np.float64), 3),
    )
    step = make_dpsgd_step(loss_fn, learning_rate=0.05)
    runs = {}
    for r in (1, 3):
        p = jax.tree.map(jnp.copy, params)
        p, log = train_priced(
            p, step, lambda k: targets, w, StaticTau(10.0),
            num_steps=40, strategy=GossipStrategy(rounds=r),
        )
        log.validate()
        assert all(rec.tau == 10.0 * r for rec in log.records)
        assert all(rec.gossip_rounds == r for rec in log.records)
        runs[r] = float(consensus_distance(p))
    # Wʳ contracts ρʳ: more gossip per update -> tighter consensus
    assert runs[3] < runs[1]


def test_prox_mu_damps_heterogeneous_drift():
    m = 6
    params, targets, loss_fn, w = _quadratic(m)
    step_plain = make_dpsgd_step(loss_fn, learning_rate=0.05)
    step_prox = make_dpsgd_step(loss_fn, learning_rate=0.05, prox_mu=0.5)
    outs = {}
    for name, step in (("plain", step_plain), ("prox", step_prox)):
        p = jax.tree.map(jnp.copy, params)
        p, log = train_priced(
            p, step, lambda k: targets, w, StaticTau(1.0), num_steps=300,
        )
        outs[name] = float(consensus_distance(p))
    assert outs["prox"] < outs["plain"]


def test_feddyn_carry_trains_with_extract_params():
    m = 6
    params, targets, loss_fn, w = _quadratic(m)
    step = make_feddyn_step(loss_fn, learning_rate=0.05, alpha=0.05)
    carry = (params, feddyn_init(params))
    carry, log = train_priced(
        carry, step, lambda k: targets, w, StaticTau(1.0), num_steps=200,
        extract_params=lambda c: c[0],
    )
    log.validate()
    assert log.records[-1].loss < log.records[0].loss
    assert not math.isnan(log.records[-1].consensus)
    x = np.asarray(carry[0]["x"]).ravel()
    assert abs(x.mean() - float(np.asarray(targets).mean())) < 1.0


# ---------------------------------------------------------------------------
# Validation errors
# ---------------------------------------------------------------------------


def test_validation_errors():
    with pytest.raises(ValueError):
        GossipStrategy(rounds=0)
    with pytest.raises(ValueError):
        StochasticTau(samples=())
    with pytest.raises(ValueError):
        StochasticTau(samples=(1.0,), reduce="median")
    with pytest.raises(ValueError, match="phased pricing needs"):
        pricer_for(object(), mode="phased")
    with pytest.raises(ValueError, match="unknown pricing mode"):
        pricer_for(object(), mode="oracle")
    with pytest.raises(ValueError, match="nonnegative"):
        train_priced(
            None, lambda *a: (None, 0.0), lambda k: None,
            np.eye(2), StaticTau(1.0), num_steps=-1,
        )
    bad = PricedTrainLog(records=[
        RoundRecord(step=0, design="d", pricing="static", gossip_rounds=1,
                    tau=1.0, wall_clock=2.0, loss=0.0)
    ])
    with pytest.raises(ValueError, match="running"):
        bad.validate()
