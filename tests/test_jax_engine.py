"""JAX rollout engine: parity with the numpy batched engine, vmap
bitwise-determinism, the padded device-CSR contract, the x64 guard,
and the designer/service plumbing that selects ``engine="jax"``."""

import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
from repro import compat
from repro.analysis.contracts import ContractViolation
from repro.net import (
    CapacityPhase,
    ChurnEvent,
    CrossTraffic,
    MarkovLinkModel,
    Scenario,
    StochasticScenario,
    StragglerEvent,
    build_overlay,
    compile_incidence,
    compute_categories,
    demands_from_links,
    line_underlay,
    random_geometric_underlay,
    route_congestion_aware,
    route_direct,
    simulate,
    simulate_phased,
)
from repro.net.jax_engine import (
    DeviceIncidence,
    _rollout_batch_reference,
    device_incidence,
    rollout_batch_results,
    simulate_jax,
    simulate_rollout_batch,
)
from repro.net.routing import PhasedRoutingSolution
from repro.net.simulator import _phase_capacity_array
from repro.net.stochastic import densify_realizations


def _random_instance(seed: int, m: int, relay: bool = False):
    u = random_geometric_underlay(12, radius=0.5, seed=seed)
    ov = build_overlay(u, list(u.graph.nodes)[:m])
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j) for i in range(m) for j in range(i + 1, m)
        if rng.random() < 0.6
    ] or [(0, 1)]
    demands = demands_from_links(links, 1e6, m)
    if relay:
        sol = route_congestion_aware(demands, cats, 1e6, m, rounds=2)
    else:
        sol = route_direct(demands, cats, 1e6)
    return sol, ov


def _line_instance(kappa=1e6, capacity=125_000.0):
    u = line_underlay(2, capacity=capacity)
    ov = build_overlay(u, [0, 1])
    cats = compute_categories(ov)
    demands = demands_from_links([(0, 1)], kappa, 2)
    return route_direct(demands, cats, kappa), ov


def _two_state(edges, stay_good=0.5, stay_bad=0.75, drop=0.1):
    return MarkovLinkModel(
        edges=edges, scales=(1.0, drop),
        transition=(
            (stay_good, 1.0 - stay_good),
            (1.0 - stay_bad, stay_bad),
        ),
    )


def _stochastic_for(ov, tau, churn=False):
    edges = tuple(ov.underlay.graph.edges)[:4] or ((0, 1),)
    return StochasticScenario(
        links=(_two_state(edges),),
        step=0.4 * tau, horizon=4 * tau,
        churn_agents=(0,) if churn else (),
        churn_hazard=0.15 if churn else 0.0,
    )


def _assert_parity(jax_res, ref_res):
    if np.isnan(ref_res.makespan):
        assert np.isnan(jax_res.makespan)
    else:
        assert jax_res.makespan == pytest.approx(
            ref_res.makespan, rel=1e-9
        )
    assert len(jax_res.flow_completion) == len(ref_res.flow_completion)
    for a, b in zip(jax_res.flow_completion, ref_res.flow_completion):
        if np.isnan(b):
            assert np.isnan(a)  # NaN semantics must survive the device
        else:
            assert a == pytest.approx(b, rel=1e-9)
    assert jax_res.cancelled_branches == ref_res.cancelled_branches


# ---------------------------------------------------------------------------
# Parity: simulate(engine="jax") vs engine="batched"
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 60), m=st.integers(3, 7), relay=st.booleans())
@settings(max_examples=12, deadline=None)
def test_jax_engine_matches_batched_static(seed, m, relay):
    """Property: the device engine reproduces the numpy batched
    engine's makespan and flow completions to rtol=1e-9 on random
    direct and relayed routings."""
    sol, ov = _random_instance(seed, m, relay=relay)
    _assert_parity(
        simulate(sol, ov, engine="jax"),
        simulate(sol, ov, engine="batched"),
    )


@given(seed=st.integers(0, 40), m=st.integers(3, 6))
@settings(max_examples=10, deadline=None)
def test_jax_engine_matches_batched_scenarios(seed, m):
    """Property: capacity phases and churn (including all-branch
    cancellation NaNs) price identically on the device."""
    sol, ov = _random_instance(seed, m)
    tau = max(float(sol.completion_time), 1.0)
    rng = np.random.default_rng(seed + 7)
    sc = Scenario(
        capacity_phases=(
            CapacityPhase(start=0.3 * tau, scale=0.5),
            CapacityPhase(start=0.9 * tau, scale=1.5),
        ),
        churn=(
            (ChurnEvent(agent=int(rng.integers(m)), time=0.5 * tau),)
            if rng.random() < 0.6 else ()
        ),
    )
    _assert_parity(
        simulate(sol, ov, scenario=sc, engine="jax"),
        simulate(sol, ov, scenario=sc, engine="batched"),
    )


def test_jax_capacity_phase_exact():
    # Same closed form the numpy engines are pinned to: halving C at
    # t=4 doubles the remaining 4s -> 12s.
    sol, ov = _line_instance()
    sc = Scenario(capacity_phases=(CapacityPhase(start=4.0, scale=0.5),))
    r = simulate(sol, ov, scenario=sc, engine="jax")
    assert r.makespan == pytest.approx(12.0)


def test_jax_rejects_unsupported_surface():
    sol, ov = _line_instance()
    with pytest.raises(ValueError, match="batched"):
        simulate(
            sol, ov, engine="jax",
            scenario=Scenario(
                cross_traffic=(CrossTraffic(src=0, dst=1, rate=1.0),)
            ),
        )
    with pytest.raises(ValueError, match="batched"):
        simulate(
            sol, ov, engine="jax",
            scenario=Scenario(
                stragglers=(StragglerEvent(agent=0, slowdown=2.0),)
            ),
        )
    with pytest.raises(ValueError, match="maxmin"):
        simulate(sol, ov, engine="jax", fairness="equal")
    with pytest.raises(ValueError, match="agent"):
        simulate(
            sol, ov, engine="jax",
            scenario=Scenario(churn=(ChurnEvent(agent=9, time=1.0),)),
        )


# ---------------------------------------------------------------------------
# Phased schedules
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 30), m=st.integers(3, 6))
@settings(max_examples=8, deadline=None)
def test_jax_phased_single_tree_parity(seed, m):
    """A phased schedule whose segments share one tree set lowers to
    the device scan and matches the batched swap loop."""
    sol, ov = _random_instance(seed, m)
    tau = max(float(sol.completion_time), 1.0)
    phased = PhasedRoutingSolution(
        demands=sol.demands, boundaries=(0.0, 0.5 * tau),
        solutions=(sol, sol), completion_time=tau,
        method="static", solve_seconds=0.0,
    )
    sc = Scenario(
        capacity_phases=(CapacityPhase(start=0.4 * tau, scale=0.5),)
    )
    _assert_parity(
        simulate_phased(phased, ov, scenario=sc, engine="jax"),
        simulate_phased(phased, ov, scenario=sc, engine="batched"),
    )


def test_jax_phased_rejects_rerouting_segments():
    """Segments with different trees re-route mid-run; volume carryover
    is host-side, so the device engine refuses rather than mispricing."""
    from repro.net.routing import RoutingSolution

    u = line_underlay(3)
    ov = build_overlay(u, [0, 1, 2])
    demands = tuple(demands_from_links([(0, 1)], 1e6, 3))[:1]
    direct = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 1)}),),
        completion_time=8.0, method="direct", solve_seconds=0.0,
    )
    relay = RoutingSolution(
        demands=demands, trees=(frozenset({(0, 2), (2, 1)}),),
        completion_time=16.0, method="direct", solve_seconds=0.0,
    )
    phased = PhasedRoutingSolution(
        demands=demands, boundaries=(0.0, 2.0),
        solutions=(direct, relay), completion_time=8.0,
        method="time_expanded", solve_seconds=0.0,
    )
    with pytest.raises(ValueError, match="re-rout"):
        simulate_phased(phased, ov, engine="jax")


# ---------------------------------------------------------------------------
# Rollout batches: one launch, per-rollout parity, vmap determinism
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 25), m=st.integers(3, 6), churn=st.booleans())
@settings(max_examples=6, deadline=None)
def test_rollout_batch_matches_reference(seed, m, churn):
    """Property: one vmapped launch over a RealizationBatch matches the
    numpy loop of engine="batched" per rollout, rtol=1e-9 (this is the
    parity_manifest.txt registration for _rollout_batch_reference)."""
    sol, ov = _random_instance(seed, m)
    sto = _stochastic_for(ov, max(float(sol.completion_time), 1.0),
                          churn=churn)
    inc = compile_incidence(sol, ov)
    batch = sto.realization_batch(seed, 6, inc)
    fast = simulate_rollout_batch(sol, ov, batch, incidence=inc)
    ref = _rollout_batch_reference(sol, ov, batch, incidence=inc)
    assert len(fast) == len(ref) == 6
    for f, r in zip(fast, ref):
        _assert_parity(f, r)


def test_vmapped_batch_bitwise_matches_one_at_a_time():
    """Batching must not change a single bit: pricing rollout r inside
    an R=8 launch gives bitwise the result of launching r alone on the
    same boundary grid."""
    sol, ov = _random_instance(3, 5)
    sto = _stochastic_for(ov, max(float(sol.completion_time), 1.0),
                          churn=True)
    inc = compile_incidence(sol, ov)
    flow_size = np.array([d.size for d in sol.demands], dtype=np.float64)
    dev = device_incidence(inc, flow_size)
    batch = sto.realization_batch(11, 8, inc)
    together = rollout_batch_results(sol, dev, batch)
    for r in range(batch.num_rollouts):
        sub = dataclasses.replace(
            batch,
            capacity=batch.capacity[r:r + 1],
            churn=(batch.churn[r],),
            realizations=(batch.realizations[r],),
        )
        alone = rollout_batch_results(sol, dev, sub)[0]
        assert together[r].makespan == alone.makespan  # bitwise
        assert together[r].flow_completion == alone.flow_completion
        assert together[r].num_events == alone.num_events


def test_dense_capacity_tensor_is_bitwise_phase_caps():
    """The [R, P, E] tensor rows are bitwise what the numpy event loop
    evaluates per phase — engines diverge in fp drain grouping only,
    never in inputs."""
    sol, ov = _random_instance(5, 5)
    sto = _stochastic_for(ov, max(float(sol.completion_time), 1.0))
    inc = compile_incidence(sol, ov)
    reals = sto.sample_many(2, 4)
    batch = densify_realizations(reals, inc)
    assert batch.starts[0] == 0.0
    for r, sc in enumerate(reals):
        phases = sorted(sc.capacity_phases, key=lambda p: p.start)
        for p, t in enumerate(batch.starts):
            live = [ph for ph in phases if ph.start <= t]
            expect = (
                _phase_capacity_array(inc, live[-1])
                if live else inc.base_capacity
            )
            assert np.array_equal(batch.capacity[r, p], expect)


def test_batch_rejects_unsupported_realizations():
    sol, ov = _random_instance(0, 4)
    inc = compile_incidence(sol, ov)
    sc = Scenario(
        cross_traffic=(CrossTraffic(src=0, dst=1, rate=1.0),)
    )
    with pytest.raises(ValueError, match="batched"):
        densify_realizations((sc,), inc)


# ---------------------------------------------------------------------------
# x64 guard
# ---------------------------------------------------------------------------


def test_require_x64_guards_pricing_entries():
    """Disabling x64 after import must raise the named error at every
    device entry rather than silently pricing in float32."""
    sol, ov = _line_instance()
    assert compat.x64_enabled()  # jax_engine import enabled it
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(compat.X64NotEnabledError):
            simulate_jax(sol, ov)
        with pytest.raises(compat.X64NotEnabledError):
            compat.require_x64()
    finally:
        compat.ensure_x64()
    assert simulate_jax(sol, ov).makespan == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# Device-CSR contract (REPRO_VALIDATE=1)
# ---------------------------------------------------------------------------


def _device(seed=1, m=5):
    sol, ov = _random_instance(seed, m)
    inc = compile_incidence(sol, ov)
    fs = np.array([d.size for d in sol.demands], dtype=np.float64)
    return device_incidence(inc, fs)


def test_device_incidence_contract(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE", "1")
    dev = _device()  # a fresh valid construction passes
    assert isinstance(dev, DeviceIncidence)
    nnz = dev.num_entries

    def corrupted(**kw):
        with pytest.raises(ContractViolation) as ei:
            dataclasses.replace(dev, **kw)
        return ei.value

    bad = dev.flat_branch.copy()
    bad[-1] = 0  # padding must point at the inert branch row
    assert corrupted(flat_branch=bad).invariant == "inert-padding"

    bad = dev.base_capacity.copy()
    bad[-1] = 2.0  # padding edge capacity must stay 1.0
    assert corrupted(base_capacity=bad).invariant == "inert-padding"

    bad = dev.flat_edge.copy()
    bad[0] = (bad[0] + 1) % dev.num_edges  # live prefix is bitwise
    assert corrupted(flat_edge=bad).invariant == "source-prefix"

    bad = dev.edge_edge.copy()
    bad[0] = dev.num_edges - 1  # breaks CSC ordering + prefix parity
    assert corrupted(edge_edge=bad).invariant == "source-prefix"

    assert corrupted(
        sizes=dev.sizes.astype(np.float32)
    ).invariant == "dtype"
    assert corrupted(
        num_entries=nnz + 1
    ).invariant == "source-extents"
    assert corrupted(
        sizes=dev.sizes[:dev.num_branches]  # bucket padding is required
    ).invariant == "padded-bucket"


def test_device_incidence_validation_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE", raising=False)
    dev = _device()
    bad = dev.flat_branch.copy()
    bad[-1] = 0
    dataclasses.replace(dev, flat_branch=bad)  # no validation, no raise


# ---------------------------------------------------------------------------
# Designer / service plumbing
# ---------------------------------------------------------------------------


def test_designer_jax_engine_prices_like_batched():
    from repro.core.designer import design

    u = random_geometric_underlay(12, radius=0.5, seed=4)
    ov = build_overlay(u, list(u.graph.nodes)[:6])
    cats = compute_categories(ov)
    sto = _stochastic_for(ov, 8.0)
    kw = dict(overlay=ov, iterations=6, stochastic=sto,
              stochastic_rollouts=16, stochastic_seed=3)
    a = design("fmmd-wp", cats, 1e6, 6, engine="batched", **kw)
    b = design("fmmd-wp", cats, 1e6, 6, engine="jax", **kw)
    assert np.allclose(
        np.asarray(a.tau_samples), np.asarray(b.tau_samples), rtol=1e-9
    )
    for field in ("tau_mean", "tau_p95", "tau_p99"):
        assert getattr(b, field) == pytest.approx(
            getattr(a, field), rel=1e-9
        )
    assert np.isfinite(b.tau_p99)
    assert b.tau_p99 >= b.tau_p95 - 1e-12  # percentiles are ordered


def test_designer_jax_rejects_online_rerouting():
    from repro.core.designer import evaluate_design
    from repro.core.topology_baselines import ring_design

    u = random_geometric_underlay(12, radius=0.5, seed=4)
    ov = build_overlay(u, list(u.graph.nodes)[:5])
    cats = compute_categories(ov)
    sto = _stochastic_for(ov, 8.0)
    with pytest.raises(ValueError, match="reroute_per_phase"):
        evaluate_design(
            ring_design(5), cats, 1e6, 5, overlay=ov,
            stochastic=sto, reroute_per_phase=True, engine="jax",
        )


def test_service_config_validates_engine():
    from repro.runtime.design_service import ServiceConfig

    assert ServiceConfig(engine="jax").engine == "jax"
    with pytest.raises(ValueError, match="unknown pricing engine"):
        ServiceConfig(engine="turbo")
