import numpy as np
import pytest

from repro.core import mixing
from repro.core.weight_opt import optimize_weights


def test_clique_reaches_ideal():
    m = 8
    links = [(i, j) for i in range(m) for j in range(i + 1, m)]
    res = optimize_weights(m, links)
    assert res.rho == pytest.approx(0.0, abs=1e-6)
    mixing.validate_mixing(res.matrix)


def test_ring_not_worse_than_best_uniform():
    m = 8
    ring = [(min(i, (i + 1) % m), max(i, (i + 1) % m)) for i in range(m)]
    res = optimize_weights(m, ring)
    best_uniform = min(
        mixing.rho(mixing.matrix_from_weights(m, ring, [a] * m))
        for a in np.linspace(0.01, 0.9, 2000)
    )
    assert res.rho <= best_uniform + 1e-6


def test_support_constraint_honored():
    m = 6
    links = [(0, 1), (2, 3), (4, 5)]
    res = optimize_weights(m, links, steps=200)
    w = res.matrix
    for i in range(m):
        for j in range(i + 1, m):
            if (i, j) not in links:
                assert abs(w[i, j]) < 1e-12


def test_empty_support_is_identity():
    res = optimize_weights(5, [])
    np.testing.assert_allclose(res.matrix, np.eye(5))
