import networkx as nx
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    build_overlay,
    dumbbell_underlay,
    grid_underlay,
    lowest_degree_nodes,
    roofnet_like,
)


def test_roofnet_like_stats():
    u = roofnet_like(seed=0)
    assert u.num_nodes == 38
    assert u.num_links == 219
    u.validate()


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_roofnet_like_deterministic_and_connected(seed):
    u1, u2 = roofnet_like(seed=seed), roofnet_like(seed=seed)
    assert nx.utils.graphs_equal(u1.graph, u2.graph)
    assert nx.is_connected(u1.graph)
    assert u1.num_links == 219


def test_overlay_paths_symmetric_and_endpointed(roofnet_overlay):
    ov = roofnet_overlay
    for i, j in ov.overlay_links:
        p, q = ov.path(i, j), ov.path(j, i)
        assert p == tuple(reversed(q))
        assert p[0] == ov.agents[i] and p[-1] == ov.agents[j]


def test_lowest_degree_selection():
    u = roofnet_like(seed=0)
    agents = lowest_degree_nodes(u, 10)
    degs = dict(u.graph.degree)
    maxdeg = max(degs[a] for a in agents)
    others = [n for n in u.graph.nodes if n not in agents]
    assert all(degs[o] >= maxdeg for o in others) or len(others) == 0


def test_grid_and_dumbbell():
    g = grid_underlay(3, 4)
    assert g.num_nodes == 12
    d = dumbbell_underlay(2, 2)
    ov = build_overlay(d, [0, 1, 2, 3])
    # every left-right path crosses the single bottleneck
    for i in (0, 1):
        for j in (2, 3):
            edges = ov.path_edges(i, j)
            assert (4, 5) in edges
