import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    build_overlay,
    compute_categories,
    demands_from_links,
    lemma31_time,
    random_geometric_underlay,
    route_direct,
    simulate,
)


@given(seed=st.integers(0, 30), m=st.integers(3, 6))
@settings(max_examples=12, deadline=None)
def test_lemma31_simulated_makespan_equals_closed_form(seed, m):
    """Lemma III.1: under equal-κ demands, the max-min fair fluid makespan
    equals max_e κ·t_e/C_e — validated on random topologies/demands."""
    u = random_geometric_underlay(12, radius=0.5, seed=seed)
    agents = list(u.graph.nodes)[:m]
    ov = build_overlay(u, agents)
    cats = compute_categories(ov)
    rng = np.random.default_rng(seed)
    links = [
        (i, j)
        for i in range(m)
        for j in range(i + 1, m)
        if rng.random() < 0.5
    ]
    if not links:
        links = [(0, 1)]
    kappa = 1e6
    demands = demands_from_links(links, kappa, m)
    sol = route_direct(demands, cats, kappa)
    closed = lemma31_time(sol, ov, kappa)
    for fairness in ("maxmin", "equal"):
        sim = simulate(sol, ov, fairness=fairness)
        assert sim.makespan == pytest.approx(closed, rel=1e-6)
    # category-level formula (Lemma III.2) agrees with link-level (III.1)
    assert sol.completion_time == pytest.approx(closed, rel=1e-9)
