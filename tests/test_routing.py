import networkx as nx
import numpy as np
import pytest

from repro.net import (
    MulticastDemand,
    build_overlay,
    compute_categories,
    demands_from_links,
    dumbbell_underlay,
    route,
    route_congestion_aware,
    route_direct,
    route_milp,
    simulate,
    lemma31_time,
)
from repro.net.routing import validate_solution
from repro.net.topology import Underlay


def _fig2_overlay():
    """Paper Fig. 2: relay through D bypasses the shared bottleneck."""
    g = nx.Graph()
    for e in [(0, 4), (4, 5), (5, 3), (1, 4), (5, 2), (1, 3)]:
        g.add_edge(*e, capacity=125000.0)
    return build_overlay(Underlay(graph=g), [0, 1, 2, 3])


def test_fig2_relay_halves_time():
    ov = _fig2_overlay()
    cats = compute_categories(ov)
    kappa = 1e6
    demands = demands_from_links([(0, 3), (1, 2)], kappa, 4)
    direct = route_direct(demands, cats, kappa)
    best = route(demands, cats, kappa, 4, time_limit=30)
    assert direct.completion_time == pytest.approx(16.0)
    assert best.completion_time == pytest.approx(8.0)
    validate_solution(best, 4)
    # simulator agrees with the closed form (Lemma III.1 consistency)
    sim = simulate(best, ov)
    assert sim.makespan == pytest.approx(best.completion_time, rel=1e-6)
    assert lemma31_time(best, ov, kappa) == pytest.approx(8.0)


def test_route_never_worse_than_direct(roofnet_overlay, roofnet_categories):
    kappa = 1e6
    m = roofnet_overlay.num_agents
    links = [(i, (i + 1) % m) for i in range(m)]
    links = [(min(a, b), max(a, b)) for a, b in links]
    demands = demands_from_links(links, kappa, m)
    direct = route_direct(demands, roofnet_categories, kappa)
    best = route(demands, roofnet_categories, kappa, m, time_limit=20)
    assert best.completion_time <= direct.completion_time + 1e-9


def test_milp_optimal_on_small(roofnet_categories):
    """Heuristic upper-bounds the MILP optimum; both span demands."""
    ov_cats = roofnet_categories
    kappa = 1e6
    demands = demands_from_links([(0, 1), (2, 3)], kappa, 10)
    milp = route_milp(demands, ov_cats, kappa, 10, time_limit=30)
    heur = route_congestion_aware(demands, ov_cats, kappa, 10)
    assert milp is not None
    validate_solution(milp, 10)
    assert milp.completion_time <= heur.completion_time + 1e-9


def test_flow_rate_consistency(roofnet_categories):
    kappa = 2e6
    demands = demands_from_links([(0, 1)], kappa, 10)
    sol = route_direct(demands, roofnet_categories, kappa)
    rate = sol.flow_rate(roofnet_categories)
    assert kappa / rate == pytest.approx(sol.completion_time)
