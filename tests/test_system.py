"""End-to-end system tests: design → route → simulate → train."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConvergenceConstants,
    design,
    make_dpsgd_step,
    replicate_for_agents,
)
from repro.core.dpsgd import train
from repro.data import DataConfig, SyntheticTokenStream
from repro.net import PAPER_MODEL_BYTES


def _tiny_lm_loss(vocab=64, d=16):
    """2-layer MLP LM for fast CPU system tests."""

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "emb": jax.random.normal(k1, (vocab, d)) * 0.1,
            "out": jax.random.normal(k2, (d, vocab)) * 0.1,
            "bias": jnp.zeros((vocab,)),
        }

    def loss_fn(params, batch):
        # the synthetic stream is i.i.d. per agent: the learnable signal
        # is the (non-IID, per-agent) unigram — the bias picks it up fast
        x = params["emb"][batch[:, :-1]]
        x = jnp.tanh(x)
        logits = x @ params["out"] + params["bias"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(
            logp, batch[:, 1:, None], axis=-1
        )
        return jnp.mean(nll)

    return init, loss_fn


def test_end_to_end_design_and_train(roofnet_overlay, roofnet_categories):
    """The whole pipeline: FMMD-WP design on the real overlay, routed τ,
    D-PSGD training on non-IID data; loss decreases and the modeled
    wall-clock uses the routed per-iteration time."""
    m = 10
    consts = ConvergenceConstants(epsilon=0.05)
    out = design(
        "fmmd-wp", roofnet_categories, PAPER_MODEL_BYTES, m,
        iterations=12, constants=consts, optimize_routing=False,
    )
    assert out.rho < 1.0

    init, loss_fn = _tiny_lm_loss()
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=64, seq_len=16, num_agents=m, seed=3)
    )
    params = replicate_for_agents(init(jax.random.key(0)), m)
    # lr must respect the stability bound: FMMD-WP matrices carry
    # eigenvalues near −ρ, so W − 2ηI must stay in the unit disk.
    step = make_dpsgd_step(loss_fn, learning_rate=0.5)

    def batcher(k):
        return jnp.asarray(stream.stacked_batch(k, per_agent_batch=8))

    params, log = train(
        params, step, batcher, out.design.matrix,
        num_steps=150, tau_per_iteration=out.tau_bar, log_every=10,
    )
    # i.i.d. tokens ⇒ only the (heterogeneous) unigram is learnable;
    # consensus caps the drop near the mean-distribution entropy
    assert log.losses[-1] < log.losses[0] - 0.02
    assert log.wall_time[-1] == pytest.approx(150 * out.tau_bar)


def test_gossip_schedule_equivalence_cpu():
    """build_schedule rounds reproduce dense mixing on CPU (single dev)."""
    from repro.core import gossip
    from repro.core.weight_opt import optimize_weights

    m = 6
    links = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5), (1, 4)]
    w = optimize_weights(m, links, steps=200).matrix
    sched = gossip.build_schedule(w)
    # emulate the ppermute rounds with numpy
    x = np.random.default_rng(0).standard_normal((m, 7))
    acc = x * np.asarray(sched.self_weight)[:, None]
    for perm, weights in zip(sched.rounds, sched.weights):
        recv = np.zeros_like(x)
        for src, dst in perm:
            recv[dst] = x[src]
        acc += recv * np.asarray(weights)[:, None]
    np.testing.assert_allclose(acc, w @ x, atol=1e-12)
    # every round is a partial permutation
    for perm in sched.rounds:
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
