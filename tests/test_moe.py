import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import moe


@given(
    b=st.integers(1, 3),
    s=st.sampled_from([8, 16]),
    e=st.sampled_from([2, 4]),
    k=st.integers(1, 2),
    seed=st.integers(0, 50),
)
@settings(max_examples=15, deadline=None)
def test_matches_dense_reference_without_dropping(b, s, e, k, seed):
    spec = moe.MoESpec(
        d_model=16, d_ff=32, num_experts=e, top_k=min(k, e),
        capacity_factor=float(e * 4),  # large: nothing dropped
    )
    params = moe.init(jax.random.key(seed), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(seed + 1), (b, s, 16))
    y, aux = moe.apply(params, x, spec, jnp.float32)
    yref = moe.apply_dense_reference(params, x, spec, jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               rtol=1e-4, atol=1e-5)
    assert np.isfinite(float(aux["load_balance_loss"]))
    # E·Σ(me·ce) == 1 iff the router is perfectly balanced AND me == ce;
    # with me (argmax counts) ≠ ce (mean probs) it can dip slightly below.
    assert float(aux["load_balance_loss"]) > 0.5


def test_capacity_drops_are_graceful():
    spec = moe.MoESpec(d_model=16, d_ff=32, num_experts=4, top_k=2,
                       capacity_factor=0.25)
    params = moe.init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, 16))
    y, _ = moe.apply(params, x, spec, jnp.float32)
    assert jnp.all(jnp.isfinite(y))
    # with tiny capacity, some outputs must be exactly zero (dropped)
    assert float(jnp.mean((jnp.abs(y).sum(-1) == 0))) > 0.0


def test_gradients_flow_through_dispatch():
    spec = moe.MoESpec(d_model=8, d_ff=16, num_experts=2, top_k=1,
                       capacity_factor=4.0)
    params = moe.init(jax.random.key(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 8))
    g = jax.grad(
        lambda p: jnp.sum(moe.apply(p, x, spec, jnp.float32)[0] ** 2)
    )(params)
    gn = sum(float(jnp.sum(v ** 2)) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
