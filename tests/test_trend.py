"""Nightly benchmark-trend gate (`benchmarks/trend.py`) on fabricated
JSON-lines files: regression detection, direction inference, the
looser wall-clock threshold, and the first-run (no baseline) pass."""

import importlib.util
import json
import pathlib
import sys

_TREND_PATH = (
    pathlib.Path(__file__).resolve().parents[1] / "benchmarks" / "trend.py"
)
_spec = importlib.util.spec_from_file_location("_trend", _TREND_PATH)
trend = importlib.util.module_from_spec(_spec)
sys.modules["_trend"] = trend  # dataclasses resolve via sys.modules
_spec.loader.exec_module(trend)


def _write(path, records):
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec) + "\n")
    return str(path)


def _rec(name, us=1000.0, derived=""):
    return {"name": name, "us_per_call": us, "derived": derived,
            "timestamp": "2026-07-29T00:00:00+00:00"}


def test_first_run_without_baseline_passes(tmp_path, capsys):
    cur = _write(tmp_path / "cur.jsonl", [_rec("sim_scale")])
    assert trend.main([str(tmp_path / "missing.jsonl"), cur]) == 0
    assert "no baseline" in capsys.readouterr().out


def test_derived_regression_over_10pct_fails(tmp_path, capsys):
    base = _write(tmp_path / "base.jsonl", [
        _rec("phase_routing", derived="makespan_phased_s=3136.0;win=4.20x"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [
        # makespan (lower-better) +12% and win (higher-better) -15%:
        # both are >10% regressions.
        _rec("phase_routing", derived="makespan_phased_s=3512.3;win=3.57x"),
    ])
    assert trend.main([base, cur]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "makespan_phased_s" in out
    assert "win" in out


def test_within_threshold_passes(tmp_path, capsys):
    base = _write(tmp_path / "base.jsonl", [
        _rec("phase_routing", derived="makespan_phased_s=3136.0;win=4.20x"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [
        # makespan +5%, win +2%: inside the 10% gate.
        _rec("phase_routing", derived="makespan_phased_s=3292.8;win=4.28x"),
    ])
    assert trend.main([base, cur]) == 0
    assert "no regressions" in capsys.readouterr().out


def test_improvements_never_fail(tmp_path):
    base = _write(tmp_path / "base.jsonl", [
        _rec("sim_scale", us=2000.0, derived="speedup=20.0x"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [
        # 2x faster wall-clock AND 3x better speedup.
        _rec("sim_scale", us=1000.0, derived="speedup=60.0x"),
    ])
    assert trend.main([base, cur]) == 0


def test_wallclock_uses_looser_threshold(tmp_path):
    base = [_rec("route_scale", us=1000.0)]
    # +30% wall clock: runner jitter, tolerated by the 50% time gate.
    cur_ok = [_rec("route_scale", us=1300.0)]
    # 10x wall clock: a real regression even for a noisy runner.
    cur_bad = [_rec("route_scale", us=10_000.0)]
    b = _write(tmp_path / "b.jsonl", base)
    assert trend.main([b, _write(tmp_path / "ok.jsonl", cur_ok)]) == 0
    assert trend.main([b, _write(tmp_path / "bad.jsonl", cur_bad)]) == 1


def test_new_and_removed_benchmarks_never_fail(tmp_path, capsys):
    base = _write(tmp_path / "base.jsonl", [_rec("old_bench")])
    cur = _write(tmp_path / "cur.jsonl", [_rec("brand_new")])
    assert trend.main([base, cur]) == 0
    out = capsys.readouterr().out
    assert "brand_new" in out and "old_bench" in out


def test_latest_record_per_name_wins(tmp_path):
    base = _write(tmp_path / "base.jsonl", [
        _rec("g", derived="tau_s=100.0"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [
        _rec("g", derived="tau_s=500.0"),  # superseded by the re-run
        _rec("g", derived="tau_s=101.0"),
    ])
    assert trend.main([base, cur]) == 0


def test_direction_inference():
    assert trend.higher_is_better("win")
    assert trend.higher_is_better("speedup")
    assert trend.higher_is_better("batched_speedup")
    assert not trend.higher_is_better("makespan_phased_s")
    assert not trend.higher_is_better("us_per_call")
    assert not trend.higher_is_better("rel_err")


def test_wallclock_classification():
    """Measured timings (and ratios of timings) get the loose gate;
    simulated durations ('_s'), counts, and wins stay on the tight one."""
    for key in ("us_per_call", "big_seconds", "sweep500_seconds",
                "speedup", "batched_speedup"):
        assert trend.is_wallclock(key), key
    for key in ("makespan_phased_s", "mean_online_s", "p95_online_s",
                "win", "reroutes", "rel_err", "branches"):
        assert not trend.is_wallclock(key), key


def test_wallclock_derived_metric_tolerates_jitter(tmp_path):
    """A measured-timing derived metric (e.g. sim_scale's wall-clock
    speedup) must not red the night on runner jitter — only collapses
    beyond the time threshold fail."""
    base = _write(tmp_path / "b.jsonl", [
        _rec("sim_scale", derived="speedup=35.0x;big_seconds=4.00"),
    ])
    jitter = _write(tmp_path / "j.jsonl", [
        # speedup -20%, big_seconds +30%: both inside the 50% time gate.
        _rec("sim_scale", derived="speedup=28.0x;big_seconds=5.20"),
    ])
    collapse = _write(tmp_path / "c.jsonl", [
        _rec("sim_scale", derived="speedup=10.0x;big_seconds=4.00"),
    ])
    assert trend.main([base, jitter]) == 0
    assert trend.main([base, collapse]) == 1


def test_vanished_metric_prints_notice(tmp_path, capsys):
    """Regression: a benchmark that stops emitting its gate metric must
    not pass *silently* — the vanished metric is listed (notice only,
    never a failure: removal is a code change, not a regression)."""
    base = _write(tmp_path / "base.jsonl", [
        _rec("phase_routing", derived="makespan_phased_s=3136.0;win=4.20x"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [
        _rec("phase_routing", derived="win=4.20x"),  # makespan gone
    ])
    assert trend.main([base, cur]) == 0
    out = capsys.readouterr().out
    assert "missing from this run" in out
    assert "phase_routing.makespan_phased_s" in out
    assert "phase_routing.win" not in out


def test_vanished_benchmark_notice_mentions_lost_gating(tmp_path, capsys):
    base = _write(tmp_path / "base.jsonl", [
        _rec("old_bench", derived="tau_s=5.0"), _rec("kept"),
    ])
    cur = _write(tmp_path / "cur.jsonl", [_rec("kept")])
    assert trend.main([base, cur]) == 0
    out = capsys.readouterr().out
    assert "old_bench" in out
    assert "no longer gated" in out


def test_vanished_metrics_helper():
    base = {
        "a": _rec("a", derived="x=1;y=2"),
        "b": _rec("b", derived="z=3"),
    }
    cur = {
        "a": _rec("a", derived="y=2"),  # lost a.x
        "c": _rec("c", derived="w=4"),  # new bench: not "vanished"
    }
    # b is absent entirely — reported by the benchmark-level notice,
    # not duplicated per metric here.
    assert trend.vanished_metrics(base, cur) == ["a.x"]


def test_unparseable_us_per_call_counts_as_vanished(tmp_path, capsys):
    """A record whose us_per_call stops being numeric loses that metric
    from the gate — it must show in the vanished notice."""
    base = _write(tmp_path / "base.jsonl", [_rec("g", us=1000.0)])
    cur = _write(
        tmp_path / "cur.jsonl",
        [{"name": "g", "us_per_call": None, "derived": "",
          "timestamp": "2026-07-29T00:00:00+00:00"}],
    )
    assert trend.main([base, cur]) == 0
    assert "g.us_per_call" in capsys.readouterr().out


def test_parse_derived_tolerates_junk():
    got = trend.parse_derived(
        "win=4.20x;label=heuristic;count=17;empty;=;x=1e-3"
    )
    assert got == {"win": 4.20, "count": 17.0, "x": 1e-3}


def test_torn_tail_line_tolerated(tmp_path):
    path = tmp_path / "torn.jsonl"
    with open(path, "w") as f:
        f.write(json.dumps(_rec("a")) + "\n")
        f.write('{"name": "b", "us_per')  # interrupted writer
    assert set(trend.load_records(str(path))) == {"a"}


def test_empty_current_with_bench_json_set_fails(tmp_path, capsys,
                                                 monkeypatch):
    # $BENCH_JSON set but zero records emitted: the benchmark job is
    # broken, not "nothing to gate" — the gate must say so and fail.
    base = _write(tmp_path / "base.jsonl", [_rec("sim_scale")])
    cur = _write(tmp_path / "cur.jsonl", [])
    monkeypatch.setenv("BENCH_JSON", "bench-results.jsonl")
    assert trend.main([base, cur]) == 1
    out = capsys.readouterr().out
    assert "$BENCH_JSON" in out and "no benchmark records" in out


def test_empty_current_without_bench_json_passes(tmp_path, capsys,
                                                 monkeypatch):
    base = _write(tmp_path / "base.jsonl", [_rec("sim_scale")])
    cur = _write(tmp_path / "cur.jsonl", [])
    monkeypatch.delenv("BENCH_JSON", raising=False)
    assert trend.main([base, cur]) == 0
    assert "nothing to gate" in capsys.readouterr().out


def test_missing_current_file_treated_as_empty(tmp_path, monkeypatch):
    base = _write(tmp_path / "base.jsonl", [_rec("sim_scale")])
    missing = str(tmp_path / "never_written.jsonl")
    monkeypatch.delenv("BENCH_JSON", raising=False)
    assert trend.main([base, missing]) == 0
    monkeypatch.setenv("BENCH_JSON", "bench-results.jsonl")
    assert trend.main([base, missing]) == 1
