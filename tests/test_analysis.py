"""Static invariant lint suite (`repro.analysis`).

Three layers of coverage:

* the **negative fixture tree** (`tests/fixtures/lint_negative`) —
  one planted violation per checker; the CLI must exit non-zero on
  it and name each violation;
* the **self-gate** — the suite must be clean on this repo (no
  unwaived findings, no unused waivers, manifest in sync). This is
  the same check CI runs, asserted here so a red lint fails the
  tier-1 suite too;
* **unit cases** on synthesized mini-trees for individual rules
  (waiver mechanics, manifest staleness, determinism/dtype rules).
"""

import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    common,
    contracts_static,
    determinism,
    docs_check,
    dtypes,
    parity,
)
from repro.analysis.__main__ import CHECKERS, main, run

REPO = Path(__file__).resolve().parents[1]
FIXTURE = REPO / "tests" / "fixtures" / "lint_negative"


def codes(findings):
    return {f.code for f in findings}


# ---------------------------------------------------------------------------
# Negative fixture tree: one planted violation per checker
# ---------------------------------------------------------------------------


def test_fixture_tree_trips_every_checker():
    expected = {
        "determinism": ["unseeded-default-rng", "fresh-prng-key"],
        "dtypes": [
            "narrow-float-dtype", "implicit-jnp-dtype",
            "narrow-dtype-string", "narrow-dtype-string",
        ],
        "parity": ["unregistered-reference"],
        "contracts": ["missing-contract-hook"],
        "docs": ["missing-architecture-doc"],
    }
    for name, expect in expected.items():
        findings = CHECKERS[name](FIXTURE)
        assert [f.code for f in findings] == expect, name


def test_fixture_tree_trips_every_tracelint_subcheck():
    """One planted violation per tracelint sub-check: the three AST
    retrace rules, the three jaxpr rules, and the manifest rule."""
    findings = CHECKERS["tracelint"](FIXTURE)
    assert codes(findings) == {
        "traced-python-branch",
        "closure-captured-array",
        "unhashable-static-arg",
        "narrow-float-in-trace",
        "narrow-float-literal",
        "host-callback",
        "multiple-launches",
        "stale-eqn-budget-entry",
    }
    by_code = {f.code: f for f in findings}
    # the two-jit split is what fails the one-launch assertion
    assert by_code["multiple-launches"].scope == "split"
    assert by_code["host-callback"].scope == "with_callback"


def test_cli_exits_nonzero_on_fixture_tree(capsys):
    assert main(["--all", "--root", str(FIXTURE)]) == 1
    out = capsys.readouterr().out
    assert "17 finding(s)" in out


def test_cli_checker_selection(capsys):
    assert main(["--dtypes", "--root", str(FIXTURE)]) == 1
    out = capsys.readouterr().out
    assert "narrow-float-dtype" in out
    assert "unseeded-default-rng" not in out


def test_cli_positional_checker_selection(capsys):
    """Checker names work as positional arguments too."""
    assert main(["determinism", "--root", str(FIXTURE)]) == 1
    out = capsys.readouterr().out
    assert "unseeded-default-rng" in out
    assert "narrow-float-dtype" not in out


def test_cli_unknown_checker_exits_2(capsys):
    assert main(["bogus", "--root", str(FIXTURE)]) == 2
    err = capsys.readouterr().err
    assert "unknown checker" in err
    for name in CHECKERS:  # usage error lists every valid name
        assert name in err


# ---------------------------------------------------------------------------
# Self-gate: this repo must be clean (and the waiver file live)
# ---------------------------------------------------------------------------


def test_repo_is_clean_under_all_checkers():
    unwaived, waived = run(REPO, list(CHECKERS))
    assert unwaived == [], "\n".join(f.render() for f in unwaived)
    # The shipped waiver file is exercised, and every waived finding
    # is one of the two reviewed exemption families: determinism
    # (telemetry timers) and dtypes (the jax kernel's bounded-value
    # device arrays — gather-table ids and crosser counts).
    assert waived, "waivers.txt should hold live exemptions"
    assert {f.checker for f in waived} == {"determinism", "dtypes"}
    assert all(
        f.path == "src/repro/net/jax_engine.py"
        for f in waived
        if f.checker == "dtypes"
    )


def test_cli_exits_zero_on_repo(capsys):
    assert main(["--all", "--root", str(REPO)]) == 0


# ---------------------------------------------------------------------------
# Waiver mechanics
# ---------------------------------------------------------------------------


def _mini_tree(tmp_path: Path, source: str,
               waivers: str | None = None) -> Path:
    net = tmp_path / "src" / "repro" / "net"
    net.mkdir(parents=True)
    (net / "mod.py").write_text(textwrap.dedent(source))
    if waivers is not None:
        adir = tmp_path / "src" / "repro" / "analysis"
        adir.mkdir(parents=True)
        (adir / common.WAIVERS_FILENAME).write_text(
            textwrap.dedent(waivers)
        )
    return tmp_path


def test_waiver_suppresses_matching_finding(tmp_path):
    root = _mini_tree(
        tmp_path,
        """
        import numpy as np
        def f():
            return np.random.default_rng()
        """,
        waivers="""
        determinism src/repro/net/mod.py f unseeded-default-rng -- test exemption
        """,
    )
    unwaived, waived = run(root, ["determinism"])
    assert unwaived == []
    assert [f.code for f in waived] == ["unseeded-default-rng"]


def test_waiver_is_scope_specific(tmp_path):
    """A waiver for one function never covers the same violation in
    another — each site is its own reviewed decision."""
    root = _mini_tree(
        tmp_path,
        """
        import numpy as np
        def f():
            return np.random.default_rng()
        def g():
            return np.random.default_rng()
        """,
        waivers="""
        determinism src/repro/net/mod.py f unseeded-default-rng -- only f
        """,
    )
    unwaived, _ = run(root, ["determinism"])
    assert [(f.scope, f.code) for f in unwaived] == [
        ("g", "unseeded-default-rng")
    ]


def test_unused_waiver_is_a_finding(tmp_path):
    root = _mini_tree(
        tmp_path,
        "x = 1\n",
        waivers="""
        determinism src/repro/net/mod.py f time-read -- fixed long ago
        """,
    )
    unwaived, _ = run(root, ["determinism"])
    assert codes(unwaived) == {"unused-waiver"}


def test_malformed_waiver_is_a_finding(tmp_path):
    root = _mini_tree(
        tmp_path,
        "x = 1\n",
        waivers="""
        determinism src/repro/net/mod.py f time-read
        """,  # no '-- reason'
    )
    unwaived, _ = run(root, ["determinism"])
    assert codes(unwaived) == {"malformed-waiver"}


# ---------------------------------------------------------------------------
# Determinism rules
# ---------------------------------------------------------------------------


def _determinism_codes(tmp_path, source):
    root = _mini_tree(tmp_path, source)
    return [f.code for f in determinism.check(root)]


def test_determinism_flags_global_and_stdlib_rng(tmp_path):
    got = _determinism_codes(tmp_path, """
        import random
        import numpy as np
        def f():
            a = np.random.rand(3)          # legacy global generator
            b = random.randint(0, 10)      # stdlib global generator
            return a, b
    """)
    assert got == ["global-numpy-rng", "stdlib-random"]


def test_determinism_flags_time_env_and_impure_seed(tmp_path):
    got = _determinism_codes(tmp_path, """
        import os, time
        import numpy as np
        import jax
        def f():
            t = time.time()
            e = os.environ["HOME"]
            k = jax.random.key(time.time_ns())
            return t, e, k
    """)
    assert "time-read" in got
    assert "env-read" in got
    assert "impure-prng-seed" in got


def test_determinism_flags_set_iteration_not_sorted(tmp_path):
    got = _determinism_codes(tmp_path, """
        def f(xs):
            for x in set(xs):              # hazard
                pass
            a = [y for y in {1, 2, 3}]     # hazard (set literal)
            b = list(frozenset(xs))        # hazard (materializes order)
            c = sorted(set(xs))            # fine: canonicalized
            d = {k: 1 for k in xs}         # fine: dict, insertion order
            return a, b, c, d
    """)
    assert got.count("set-iteration-order") == 3
    assert len(got) == 3


def test_determinism_flags_literal_key_but_not_threaded(tmp_path):
    """`PRNGKey(0)`-style literal keys are flagged; keys derived from a
    caller's seed parameter (or any non-literal expression) are the
    sanctioned pattern and stay green."""
    got = _determinism_codes(tmp_path, """
        import jax
        def f(seed):
            bad = jax.random.PRNGKey(0)
            bad2 = jax.random.key(7919 * 3)
            ok = jax.random.PRNGKey(seed)
            ok2 = jax.random.key(seed * 7919 + 3)
            return bad, bad2, ok, ok2
    """)
    assert got == ["fresh-prng-key", "fresh-prng-key"]


def test_determinism_accepts_seeded_rng(tmp_path):
    got = _determinism_codes(tmp_path, """
        import numpy as np
        def f(seed):
            rng = np.random.default_rng(seed)
            g = np.random.default_rng((seed, 7, 0xBEEF))
            return rng.random(3), g.standard_normal()
    """)
    assert got == []


# ---------------------------------------------------------------------------
# Dtype rules
# ---------------------------------------------------------------------------


def test_dtypes_flags_narrow_types_and_strings(tmp_path):
    root = _mini_tree(tmp_path, """
        import numpy as np
        def f(x):
            a = np.asarray(x, dtype=np.int32)
            b = np.zeros(3, dtype="float32")
            c = x.astype(np.float16)
            return a, b, c
    """)
    got = [f.code for f in dtypes.check(root)]
    assert got == [
        "narrow-int-dtype", "narrow-dtype-string", "narrow-float-dtype",
    ]


def test_dtypes_flags_method_string_casts(tmp_path):
    """`.view("float32")` / `.astype("single")` are the method
    spellings of a narrowing cast; wide strings stay green."""
    root = _mini_tree(tmp_path, """
        def f(x):
            a = x.view("float32")
            b = x.astype("single")
            c = x.astype("float64")
            return a, b, c
    """)
    got = [f.code for f in dtypes.check(root)]
    assert got == ["narrow-dtype-string", "narrow-dtype-string"]


def test_dtypes_accepts_wide_types(tmp_path):
    root = _mini_tree(tmp_path, """
        import numpy as np
        def f(x):
            return (np.asarray(x, dtype=np.float64),
                    np.zeros(3, dtype=np.int64),
                    np.arange(4, dtype="float64"))
    """)
    assert dtypes.check(root) == []


def test_dtypes_flags_implicit_jnp_builders(tmp_path):
    """Dtype-less jnp constructors narrow to float32/int32 whenever the
    x64 flag is off — flagged on pricing paths; explicit dtype= (or a
    positional dtype) and plain-numpy implicit defaults are fine."""
    root = _mini_tree(tmp_path, """
        import jax.numpy as jnp
        import numpy as np
        def f(n):
            bad = (jnp.zeros(n), jnp.arange(n), jnp.full((n, n), 0.5))
            ok = (jnp.zeros(n, dtype=jnp.float64),
                  jnp.ones(n, jnp.float64),
                  jnp.arange(n, dtype=jnp.int64),
                  np.zeros(n),  # numpy's implicit default IS float64
                  np.arange(n))
            return bad, ok
    """)
    got = [f.code for f in dtypes.check(root)]
    assert got == ["implicit-jnp-dtype"] * 3


def test_dtypes_ignores_learning_half(tmp_path):
    """float32 wire formats in gossip/compression are out of scope —
    only pricing paths are scanned."""
    core = tmp_path / "src" / "repro" / "core"
    core.mkdir(parents=True)
    (core / "gossip.py").write_text(
        "import jax.numpy as jnp\n"
        "def g(p):\n    return p.astype(jnp.float32)\n"
    )
    assert dtypes.check(tmp_path) == []


# ---------------------------------------------------------------------------
# Parity manifest rules
# ---------------------------------------------------------------------------


def _parity_tree(tmp_path, manifest: str | None, with_test=True,
                 test_body=None):
    net = tmp_path / "src" / "repro" / "net"
    net.mkdir(parents=True)
    (net / "mod.py").write_text(
        "def _slow_reference(x):\n    return x\n"
        "def fast(x):\n    return x\n"
    )
    if manifest is not None:
        adir = tmp_path / "src" / "repro" / "analysis"
        adir.mkdir(parents=True)
        (adir / parity.MANIFEST_FILENAME).write_text(
            textwrap.dedent(manifest)
        )
    if with_test:
        tdir = tmp_path / "tests"
        tdir.mkdir()
        (tdir / "test_mod.py").write_text(test_body or (
            "from repro.net.mod import _slow_reference, fast\n"
            "def test_parity():\n"
            "    assert fast(1) == _slow_reference(1)\n"
        ))
    return tmp_path


def test_parity_green_when_registered(tmp_path):
    root = _parity_tree(tmp_path, """
        src/repro/net/mod.py::_slow_reference fast tests/test_mod.py
    """)
    assert parity.check(root) == []


def test_parity_flags_unregistered_reference(tmp_path):
    root = _parity_tree(tmp_path, manifest=None)
    assert codes(parity.check(root)) == {"unregistered-reference"}


def test_parity_flags_stale_entry_and_missing_test(tmp_path):
    root = _parity_tree(tmp_path, """
        src/repro/net/mod.py::_slow_reference fast tests/test_mod.py
        src/repro/net/gone.py::_gone_reference fast tests/test_mod.py
        src/repro/net/mod.py::fast_reference fast tests/test_gone.py
    """)
    # Both bad entries are stale (missing file / missing def); the
    # good first entry stays green, so stale is the only code.
    findings = parity.check(root)
    assert codes(findings) == {"stale-manifest-entry"}
    assert len(findings) == 2


def test_parity_flags_test_without_symbols(tmp_path):
    root = _parity_tree(
        tmp_path,
        "src/repro/net/mod.py::_slow_reference fast tests/test_mod.py\n",
        test_body="def test_unrelated():\n    assert True\n",
    )
    assert codes(parity.check(root)) == {"parity-test-lacks-symbol"}


def test_parity_via_token_counts_as_mention(tmp_path):
    root = _parity_tree(
        tmp_path,
        "src/repro/net/mod.py::_slow_reference fast tests/test_mod.py "
        "via=slow\n",
        test_body=(
            "def test_engines():\n"
            "    assert run(engine='slow') == run(engine='fast')\n"
            "def run(engine):\n    return 0\n"
        ),
    )
    # 'slow' appears as an exact string constant; 'fast' as one too.
    assert parity.check(root) == []


def test_parity_manifest_registers_all_repo_references():
    """Seed audit: the five existing reference/fast-path pairs are
    registered and their tests still mention both symbols."""
    entries, malformed = parity.load_manifest(
        REPO / "src/repro/analysis" / parity.MANIFEST_FILENAME
    )
    assert malformed == []
    registered = {e.reference for e in entries}
    assert registered >= {
        "_simulate_reference",
        "_route_congestion_aware_reference",
        "_compute_categories_reference",
        "_compile_category_incidence_reference",
        "apply_dense_reference",
    }
    assert parity.check(REPO) == []


# ---------------------------------------------------------------------------
# Docs-gate rules
# ---------------------------------------------------------------------------


def _docs_tree(tmp_path, doc: str | None):
    net = tmp_path / "src" / "repro" / "net"
    net.mkdir(parents=True)
    (net / "pricing.py").write_text("x = 1\n")
    (net / "_private.py").write_text("x = 1\n")
    (net / "__init__.py").write_text("")
    if doc is not None:
        (tmp_path / "docs").mkdir()
        (tmp_path / "docs" / "architecture.md").write_text(doc)
    return tmp_path


def test_docs_missing_architecture_doc_is_one_finding(tmp_path):
    root = _docs_tree(tmp_path, doc=None)
    findings = docs_check.check(root)
    assert [f.code for f in findings] == ["missing-architecture-doc"]


def test_docs_flags_unlisted_module_only(tmp_path):
    """Private/dunder modules are exempt; a filename mention anywhere
    in the doc (prose, table, code span) satisfies the gate."""
    root = _docs_tree(tmp_path, doc="# map\n\nnothing here\n")
    findings = docs_check.check(root)
    assert [(f.code, f.path) for f in findings] == [
        ("undocumented-module", "src/repro/net/pricing.py")
    ]
    root2 = _docs_tree(tmp_path / "b", doc="| `pricing.py` | prices |\n")
    assert docs_check.check(root2) == []


def test_docs_green_on_empty_tree(tmp_path):
    assert docs_check.check(tmp_path) == []


def test_docs_gate_green_on_repo():
    """Self-gate: docs/architecture.md lists every public module."""
    assert docs_check.check(REPO) == []


# ---------------------------------------------------------------------------
# Contract-wiring rules
# ---------------------------------------------------------------------------


def test_contracts_static_flags_missing_class(tmp_path):
    (tmp_path / "src" / "repro" / "net").mkdir(parents=True)
    got = codes(contracts_static.check(tmp_path))
    assert got == {"contract-class-missing"}


def test_contracts_static_green_on_repo():
    assert contracts_static.check(REPO) == []
