"""Trace-lint (`repro.analysis.tracelint`) unit tests.

Four layers:

* **one-launch / IR sub-checks** on tiny traced functions — a
  `pure_callback` or a two-jit split must fail lint (the statically
  asserted half of the "one XLA launch per pricing call" claim);
* **eqn-budget manifest** mechanics (missing/exceeded/malformed);
* the **retrace contract** — the trace-counting harness proves the
  registered grid compiles exactly once per shape signature, and the
  AST pass's exemptions (static shape reads) stay green;
* **Pallas-readiness metrics** — carry/operand/round-pair bytes read
  statically off the water-fill loop's jaxpr, as emitted by
  `benchmarks/analysis_bench.py`.
"""

import textwrap
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.net import jax_engine  # noqa: E402  (ensures x64)
from repro.analysis import tracelint, tracelint_targets  # noqa: E402
from repro.analysis.tracelint import (  # noqa: E402
    BudgetEntry,
    TraceCase,
    TraceTarget,
    _Issues,
    _check_callbacks,
    _check_dtypes,
    _check_launch,
    _trace_target,
    count_compilations,
    count_eqns,
    load_manifest,
    waterfill_metrics,
)

REPO = Path(__file__).resolve().parents[1]


def _issues_for(fn, args):
    """(issues, closed) after running every IR sub-check on fn(*args)."""
    target = TraceTarget(
        name="t", path="src/x.py", scope="s",
        cases=(TraceCase("c", lambda: (fn, args)),),
    )
    issues = _Issues(target)
    closed = jax.make_jaxpr(fn)(*args)
    _check_launch(issues, "c", closed)
    _check_callbacks(issues, "c", closed)
    _check_dtypes(issues, "c", closed)
    return issues, closed


def _codes(findings):
    return {f.code for f in findings}


ARGS = (np.arange(4, dtype=np.float64),)


# ---------------------------------------------------------------------------
# One-launch / IR sub-checks
# ---------------------------------------------------------------------------


def test_single_jit_f64_entry_is_clean():
    @jax.jit
    def entry(x):
        return x * 2.0 + 1.0

    issues, closed = _issues_for(entry, ARGS)
    assert issues.findings() == []
    assert count_eqns(closed.jaxpr) >= 2


def test_two_jit_split_fails_one_launch():
    """Splitting the kernel into two jitted calls is exactly the
    regression the one-launch assertion exists to catch."""
    @jax.jit
    def half1(x):
        return x * 2.0

    @jax.jit
    def half2(x):
        return x + 1.0

    issues, _ = _issues_for(lambda x: half2(half1(x)), ARGS)
    assert _codes(issues.findings()) == {"multiple-launches"}


def test_unjitted_entry_fails_one_launch():
    issues, _ = _issues_for(lambda x: x * 2.0 + 1.0, ARGS)
    assert _codes(issues.findings()) == {"multiple-launches"}


def test_pure_callback_fails_lint():
    @jax.jit
    def entry(x):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )
        return y + 1.0

    issues, _ = _issues_for(entry, ARGS)
    assert "host-callback" in _codes(issues.findings())


def test_f32_promotion_fails_lint():
    import jax.numpy as jnp

    @jax.jit
    def entry(x):
        return x.astype(jnp.float32) * jnp.float32(3.0)

    got = _codes(_issues_for(entry, ARGS)[0].findings())
    assert "narrow-float-in-trace" in got


# ---------------------------------------------------------------------------
# Eqn-budget manifest
# ---------------------------------------------------------------------------


def _jit_double():
    @jax.jit
    def double(x):
        return x * 2.0

    return TraceTarget(
        name="double", path="src/x.py", scope="double",
        cases=(TraceCase("c", lambda: (double, ARGS)),),
    )


def test_missing_budget_entry_is_a_finding():
    findings = _trace_target(_jit_double(), {}, jax)
    assert _codes(findings) == {"missing-eqn-budget"}


def test_exceeded_budget_is_a_finding():
    budgets = {"double": BudgetEntry("double", 0, 1)}
    findings = _trace_target(_jit_double(), budgets, jax)
    assert _codes(findings) == {"eqn-budget-exceeded"}


def test_generous_budget_is_clean():
    budgets = {"double": BudgetEntry("double", 100, 1)}
    assert _trace_target(_jit_double(), budgets, jax) == []


def test_malformed_and_duplicate_manifest_lines(tmp_path):
    path = tmp_path / "tracelint_manifest.txt"
    path.write_text(
        "# comment\n"
        "good 100\n"
        "bad-no-count\n"
        "bad not-a-number\n"
        "good 200\n"  # duplicate
    )
    budgets, findings = load_manifest(path)
    assert list(budgets) == ["good"]
    assert budgets["good"].max_eqns == 100
    assert [f.code for f in findings] == ["malformed-eqn-budget"] * 3


# ---------------------------------------------------------------------------
# Retrace contract (harness + AST exemptions)
# ---------------------------------------------------------------------------


def test_one_compilation_per_shape_signature():
    """The retrace contract over the registered grid: compilations ==
    distinct shape signatures, never more. Identical args are a pure
    cache hit; a different seed may change the sampled segment-grid
    length (a *legitimate* new signature), and a new rollout width
    always does."""
    arg_sets = [
        tracelint_targets.rollout_batch_args(4),
        tracelint_targets.rollout_batch_args(4),  # cache hit
        tracelint_targets.rollout_batch_args(4, seed=1),
        tracelint_targets.rollout_batch_args(8),
    ]
    signatures = {
        tuple((a.shape, str(a.dtype)) for a in args)
        for args in arg_sets
    }
    assert len(signatures) >= 2  # the grid genuinely varies
    assert count_compilations(jax_engine._run_batch, arg_sets) \
        == len(signatures)


def _ast_findings(tmp_path, source):
    net = tmp_path / "src" / "repro" / "net"
    net.mkdir(parents=True)
    (net / "mod.py").write_text(textwrap.dedent(source))
    return tracelint.check(tmp_path)


def test_ast_pass_exempts_static_shape_reads(tmp_path):
    """Branching on shape/dtype metadata is how bucketed programs
    specialize — the `_waterfill` cdtype selection pattern must stay
    green; branching on the tracer's value must not."""
    findings = _ast_findings(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def kernel(edge_table, x):
            cdtype = jnp.int16 if edge_table.shape[1] < 2**15 \\
                else jnp.int32
            if len(x) > 3:
                pass
            if x.ndim > 1:
                pass
            return x.astype(cdtype)
    """)
    assert findings == []


def test_ast_pass_flags_traced_branch_in_call_closure(tmp_path):
    """Device scope is the transitive module-local call closure of the
    jitted entry, not just its body."""
    findings = _ast_findings(tmp_path, """
        import jax

        def _helper(y):
            if y > 0:
                return y
            return -y

        @jax.jit
        def entry(x):
            return _helper(x)
    """)
    assert [(f.scope, f.code) for f in findings] == [
        ("_helper", "traced-python-branch")
    ]


def test_ast_pass_flags_wrapper_alias_and_static_call_site(tmp_path):
    findings = _ast_findings(tmp_path, """
        import jax

        def _impl(x, mode):
            while x > 0:
                x = x - 1
            return x

        scale = jax.jit(_impl, static_argnames=("mode",))

        def run(x):
            return scale(x, mode=[1, 2])
    """)
    assert _codes(findings) == {
        "traced-python-branch", "unhashable-static-arg"
    }


def test_jax_absent_degrades_to_named_skip(tmp_path, monkeypatch):
    """Without jax the AST pass still runs and the jaxpr pass is a
    *named* skip (visible note), never a silent pass."""
    monkeypatch.setattr(tracelint, "_try_import_jax", lambda: None)
    findings = _ast_findings(tmp_path, """
        import jax

        @jax.jit
        def entry(x):
            if x > 0:
                return x
            return -x
    """)
    assert _codes(findings) == {"traced-python-branch"}
    assert tracelint.LAST_SKIP_NOTES
    assert "SKIPPED" in tracelint.LAST_SKIP_NOTES[0]


# ---------------------------------------------------------------------------
# Pallas-readiness metrics
# ---------------------------------------------------------------------------


def test_waterfill_metrics_from_registered_case():
    fn, args = tracelint_targets.TARGETS[0].cases[0].make()
    closed = jax.make_jaxpr(fn)(*args)
    metrics = waterfill_metrics(closed)
    assert set(metrics) == {
        "waterfill_carry_bytes",
        "waterfill_operand_bytes",
        "waterfill_roundpair_bytes",
    }
    assert all(v > 0 for v in metrics.values())
    # the round pair touches at least the carried state once
    assert metrics["waterfill_roundpair_bytes"] > \
        metrics["waterfill_carry_bytes"]


def test_waterfill_metrics_empty_without_loop():
    closed = jax.make_jaxpr(lambda x: x * 2.0)(*ARGS)
    assert waterfill_metrics(closed) == {}


def test_collect_metrics_covers_every_target():
    metrics = tracelint.collect_metrics(REPO)
    assert set(metrics) >= {
        "eqns_rollout_batch",
        "eqns_phased_scan",
        "eqns_stochastic_price",
        "waterfill_carry_bytes",
        "waterfill_operand_bytes",
        "waterfill_roundpair_bytes",
    }
    assert all(
        isinstance(v, int) and v > 0 for v in metrics.values()
    )


def test_registry_budgets_have_headroom():
    """Every registered target is budgeted, and measured counts sit
    under budget with real headroom (>=10%) so routine jax drift does
    not page the gate."""
    budgets, malformed = load_manifest(
        REPO / tracelint.MANIFEST_REL_PATH
    )
    assert malformed == []
    metrics = tracelint.collect_metrics(REPO)
    for target in tracelint_targets.TARGETS:
        entry = budgets[target.name]
        eqns = metrics["eqns_" + target.name.replace("-", "_")]
        assert eqns <= entry.max_eqns * 0.9, target.name
