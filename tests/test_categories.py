import dataclasses
from typing import Mapping

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.net import (
    Underlay,
    build_overlay,
    compute_categories,
    dumbbell_underlay,
    infer_categories,
    random_geometric_underlay,
)


def test_partition_property(roofnet_overlay, roofnet_categories):
    """Each traversed directed underlay edge is in exactly one category."""
    ov, cats = roofnet_overlay, roofnet_categories
    seen = {}
    for F, members in cats.members.items():
        for e in members:
            assert e not in seen, "edge in two categories"
            seen[e] = F
    # every edge on every overlay path is categorized, and its category
    # contains exactly the overlay links routed over it
    for i, j in ov.directed_overlay_links:
        for e in ov.path_edges(i, j):
            assert e in seen
            assert (i, j) in seen[e]


def test_category_completion_time_matches_linklevel(roofnet_overlay):
    ov = roofnet_overlay
    cats = compute_categories(ov)
    # direct routing of a ring: t_F computed two ways must agree
    uses = {}
    m = ov.num_agents
    for i in range(m):
        j = (i + 1) % m
        uses[(i, j)] = uses.get((i, j), 0) + 1
        uses[(j, i)] = uses.get((j, i), 0) + 1
    tau_cat = cats.completion_time(uses, kappa=1.0)
    # link-level: load per directed underlay edge
    loads = {}
    for (i, j), n in uses.items():
        for e in ov.path_edges(i, j):
            loads[e] = loads.get(e, 0) + n
    tau_link = max(
        n / ov.underlay.capacity(*e) for e, n in loads.items()
    )
    assert tau_cat == pytest.approx(tau_link, rel=1e-12)


def test_inferred_matches_truth(roofnet_overlay):
    truth = compute_categories(roofnet_overlay)
    inf = infer_categories(roofnet_overlay, capacity_noise=0.0)
    assert set(inf.capacity) == set(truth.capacity)
    for F in truth.capacity:
        assert inf.capacity[F] == pytest.approx(truth.capacity[F])


def test_capacity_noise_clamps_to_relative_floor(roofnet_overlay):
    """Large noise draws must not shrink a capacity to the old absolute
    1e-9 floor (a ~1e9× τ blowup that poisons sweeps): the clamp is 1%
    of the true C_F, so every noisy κ/C_F term stays within 100× of the
    truth and completion times stay finite and sane."""
    truth = compute_categories(roofnet_overlay)
    # σ = 50: most draws push c·(1 + 50·N(0,1)) far below zero.
    inf = infer_categories(roofnet_overlay, capacity_noise=50.0, seed=0)
    assert any(
        inf.capacity[F] == pytest.approx(0.01 * truth.capacity[F])
        for F in truth.capacity
    ), "expected at least one clamped draw at sigma=50"
    for F, c in truth.capacity.items():
        assert inf.capacity[F] >= 0.01 * c
        assert np.isfinite(inf.capacity[F]) and inf.capacity[F] > 0
    # Ring-load completion time under the noisy estimate is within the
    # 100× clamp of the truth, not 1e9× off.
    m = roofnet_overlay.num_agents
    uses = {}
    for i in range(m):
        j = (i + 1) % m
        uses[(i, j)] = 1
        uses[(j, i)] = 1
    tau_true = truth.completion_time(uses, kappa=1e6)
    tau_noisy = inf.completion_time(uses, kappa=1e6)
    assert np.isfinite(tau_noisy)
    assert tau_noisy <= 100.0 * tau_true * (1 + 1e-12)


def test_noisy_sweep_stays_finite(roofnet_overlay):
    from repro.core import ConvergenceConstants, sweep_iterations

    inf = infer_categories(roofnet_overlay, capacity_noise=50.0, seed=0)
    best = sweep_iterations(
        inf, 1e6, roofnet_overlay.num_agents, iteration_grid=(12,),
        constants=ConvergenceConstants(epsilon=0.05),
        optimize_routing=False,
    )
    assert np.isfinite(best.total_time)
    assert np.isfinite(best.tau_bar) and best.tau_bar > 0


@dataclasses.dataclass(frozen=True)
class _DirectionalUnderlay(Underlay):
    """Underlay whose capacity is direction-dependent: the base graph
    capacity times a per-directed-edge factor, with the same
    direction-first lookup rule ``Categories.scaled`` uses."""

    factors: Mapping = dataclasses.field(default_factory=dict)

    def capacity(self, u: int, v: int) -> float:
        f = self.factors.get((u, v), self.factors.get((v, u), 1.0))
        return float(self.graph.edges[u, v]["capacity"]) * float(f)


@given(seed=st.integers(0, 40))
@settings(max_examples=10, deadline=None)
def test_scaled_directional_asymmetry_matches_mutated_underlay(seed):
    """Property: ``Categories.scaled`` with a per-edge mapping carrying
    *different* factors for the two directions of an underlay edge
    equals ``compute_categories`` on the overlay atop an underlay with
    the correspondingly direction-scaled capacities — bitwise, including
    family order."""
    u = random_geometric_underlay(20, radius=0.4, seed=seed)
    rng = np.random.default_rng(seed + 77)
    for _, _, data in u.graph.edges(data=True):
        data["capacity"] = 125_000.0 * rng.uniform(0.3, 3.0)
    ov = build_overlay(u, list(u.graph.nodes)[:5])
    cats = compute_categories(ov)
    directed_edges = list(cats.edge_capacity)
    picks = rng.choice(
        len(directed_edges),
        size=min(4, len(directed_edges)),
        replace=False,
    )
    scale: dict = {}
    for p in picks:
        e = directed_edges[p]
        # Distinct factors per direction of the same underlay edge.
        scale[e] = float(rng.uniform(0.2, 2.0))
        scale[(e[1], e[0])] = float(rng.uniform(0.2, 2.0))
    scaled = cats.scaled(scale)
    mutated = dataclasses.replace(
        ov, underlay=_DirectionalUnderlay(graph=u.graph, factors=scale)
    )
    truth = compute_categories(mutated)
    assert list(scaled.members.items()) == list(truth.members.items())
    assert list(scaled.capacity.items()) == list(truth.capacity.items())
    assert list(scaled.edge_capacity.items()) == list(
        truth.edge_capacity.items()
    )
