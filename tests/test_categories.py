import numpy as np
import pytest

from repro.net import (
    build_overlay,
    compute_categories,
    dumbbell_underlay,
    infer_categories,
)


def test_partition_property(roofnet_overlay, roofnet_categories):
    """Each traversed directed underlay edge is in exactly one category."""
    ov, cats = roofnet_overlay, roofnet_categories
    seen = {}
    for F, members in cats.members.items():
        for e in members:
            assert e not in seen, "edge in two categories"
            seen[e] = F
    # every edge on every overlay path is categorized, and its category
    # contains exactly the overlay links routed over it
    for i, j in ov.directed_overlay_links:
        for e in ov.path_edges(i, j):
            assert e in seen
            assert (i, j) in seen[e]


def test_category_completion_time_matches_linklevel(roofnet_overlay):
    ov = roofnet_overlay
    cats = compute_categories(ov)
    # direct routing of a ring: t_F computed two ways must agree
    uses = {}
    m = ov.num_agents
    for i in range(m):
        j = (i + 1) % m
        uses[(i, j)] = uses.get((i, j), 0) + 1
        uses[(j, i)] = uses.get((j, i), 0) + 1
    tau_cat = cats.completion_time(uses, kappa=1.0)
    # link-level: load per directed underlay edge
    loads = {}
    for (i, j), n in uses.items():
        for e in ov.path_edges(i, j):
            loads[e] = loads.get(e, 0) + n
    tau_link = max(
        n / ov.underlay.capacity(*e) for e, n in loads.items()
    )
    assert tau_cat == pytest.approx(tau_link, rel=1e-12)


def test_inferred_matches_truth(roofnet_overlay):
    truth = compute_categories(roofnet_overlay)
    inf = infer_categories(roofnet_overlay, capacity_noise=0.0)
    assert set(inf.capacity) == set(truth.capacity)
    for F in truth.capacity:
        assert inf.capacity[F] == pytest.approx(truth.capacity[F])
