"""Fig. 5: training quality vs epochs AND vs wall-clock under each design.

The paper trains ResNet-50/CIFAR-10; the framework's workload is LM
training, so this benchmark trains a small transformer LM (same D-PSGD
machinery) on non-IID synthetic data and reports loss vs (a) steps and
(b) modeled wall-clock (steps × τ for routed and default-path schemes).
Reproduced headline: sparse designs (FMMD/SCA) reach the same loss as
Clique at a fraction of the wall-clock; FMMD ≈ SCA.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CONSTANTS, KAPPA, NUM_AGENTS, emit, paper_scenario
from repro.configs.base import ModelConfig
from repro.core import design, make_dpsgd_step, replicate_for_agents
from repro.core.dpsgd import train
from repro.data import DataConfig, SyntheticTokenStream
from repro.models import model as M

SMALL_LM = ModelConfig(
    name="bench-lm",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    rope_theta=1e4,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)


def run(steps: int = 120) -> dict:
    _, ov, cats = paper_scenario()
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=SMALL_LM.vocab_size, seq_len=32,
                   num_agents=NUM_AGENTS, dirichlet_alpha=0.3, seed=5)
    )
    loss_fn = lambda p, b: M.loss(SMALL_LM, p, {"tokens": b}, remat=False)[0]
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.1)

    results = {}
    for method in ("clique", "ring", "prim", "fmmd-wp", "sca"):
        out = design(method, cats, KAPPA, NUM_AGENTS, overlay=ov,
                     iterations=12, constants=CONSTANTS)
        params = replicate_for_agents(
            M.init(SMALL_LM, jax.random.key(0)), NUM_AGENTS
        )

        def batcher(k):
            return jnp.asarray(stream.stacked_batch(k, per_agent_batch=4))

        _, log = train(
            params, step_fn, batcher, out.design.matrix,
            num_steps=steps, tau_per_iteration=out.tau, log_every=10,
        )
        results[method] = dict(
            losses=log.losses, steps=log.steps,
            tau=out.tau, tau_bar=out.tau_bar, rho=out.rho,
            final_loss=log.losses[-1],
            time_to_final=log.steps[-1] * out.tau,
        )
    return results


def main() -> None:
    t0 = time.perf_counter()
    res = run()
    dt = time.perf_counter() - t0
    base = res["clique"]
    fm = res["fmmd-wp"]
    # wall-clock to reach clique's final loss under each design
    def time_to(loss_target, r):
        for s, l in zip(r["steps"], r["losses"]):
            if l <= loss_target:
                return (s + 1) * r["tau"]
        return (r["steps"][-1] + 1) * r["tau"]

    target = max(base["final_loss"], fm["final_loss"]) + 0.01
    t_clique = time_to(target, base)
    t_fmmd = time_to(target, fm)
    emit(
        "fig5_training",
        1e6 * dt,
        f"time_reduction_vs_clique={100*(1 - t_fmmd/max(t_clique,1e-9)):.0f}%;"
        f"final_loss_fmmd={fm['final_loss']:.3f};final_loss_clique={base['final_loss']:.3f}",
    )
    for k, v in res.items():
        print(
            f"  {k:8s} tau={v['tau']:8.1f}s rho={v['rho']:.3f} "
            f"final_loss={v['final_loss']:.4f} "
            f"modeled_time={v['time_to_final']/3600:.1f}h"
        )


if __name__ == "__main__":
    main()
