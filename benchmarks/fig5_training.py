"""Fig. 5: training quality vs epochs AND vs wall-clock under each design.

The paper trains ResNet-50/CIFAR-10; the framework's workload is LM
training, so this benchmark trains a small transformer LM (same D-PSGD
machinery) on non-IID synthetic data and reports loss vs (a) steps and
(b) modeled wall-clock. Reproduced headline: sparse designs (FMMD/SCA)
reach the same loss as Clique at a fraction of the wall-clock;
FMMD ≈ SCA.

Each scheme's per-round τ comes from the same ``evaluate_design``
pricing path the designer uses — the routed static τ by default, the
scenario-simulated τ when ``run(scenario=...)`` is set (charged per
round under the phase active at the round's wall-clock start via
``PhasedTau``), or the seeded expectation when ``run(stochastic=...)``
is set — never a hand-picked constant. The wall-clock axis is labeled
with the τ model that produced it (``tau_model`` in the results and
the emitted derived metrics).
"""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import CONSTANTS, KAPPA, NUM_AGENTS, emit, paper_scenario
from repro.configs.base import ModelConfig
from repro.core import design, make_dpsgd_step, replicate_for_agents
from repro.core.priced_training import pricer_for, train_priced
from repro.data import DataConfig, SyntheticTokenStream
from repro.models import model as M

SMALL_LM = ModelConfig(
    name="bench-lm",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    rope_theta=1e4,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)

SCHEMES = ("clique", "ring", "prim", "fmmd-wp", "sca")


def run(steps: int = 120, scenario=None, stochastic=None,
        stochastic_rollouts: int = 8, engine: str = "batched") -> dict:
    _, ov, cats = paper_scenario()
    mode = (
        "phased" if scenario is not None
        else "stochastic" if stochastic is not None
        else "static"
    )
    stream = SyntheticTokenStream(
        DataConfig(vocab_size=SMALL_LM.vocab_size, seq_len=32,
                   num_agents=NUM_AGENTS, dirichlet_alpha=0.3, seed=5)
    )
    loss_fn = lambda p, b: M.loss(SMALL_LM, p, {"tokens": b}, remat=False)[0]
    step_fn = make_dpsgd_step(loss_fn, learning_rate=0.1)

    results = {}
    for method in SCHEMES:
        out = design(method, cats, KAPPA, NUM_AGENTS, overlay=ov,
                     iterations=12, constants=CONSTANTS,
                     scenario=scenario, stochastic=stochastic,
                     stochastic_rollouts=stochastic_rollouts,
                     engine=engine)
        pricer = pricer_for(out, mode=mode, overlay=ov,
                            scenario=scenario, stochastic=stochastic,
                            engine=engine)
        params = replicate_for_agents(
            M.init(SMALL_LM, jax.random.key(0)), NUM_AGENTS
        )

        def batcher(k):
            return jnp.asarray(stream.stacked_batch(k, per_agent_batch=4))

        _, log = train_priced(
            params, step_fn, batcher, out.design.matrix, pricer,
            num_steps=steps, design_label=out.name, log_every=10,
        )
        log.validate()
        results[method] = dict(
            losses=log.losses, steps=log.steps, wall_clock=log.wall_clock,
            tau=out.tau, tau_bar=out.tau_bar, rho=out.rho,
            tau_model=pricer.kind,
            final_loss=log.losses[-1],
            time_to_final=log.total_wall,
            log=log,
        )
    return results


def main() -> None:
    t0 = time.perf_counter()
    res = run()
    dt = time.perf_counter() - t0
    base = res["clique"]
    fm = res["fmmd-wp"]
    # wall-clock to reach clique's final loss under each design, read
    # off the per-round charged wall-clock (not steps × one constant).
    target = max(base["final_loss"], fm["final_loss"]) + 0.01
    t_clique = min(base["log"].time_to_loss(target), base["time_to_final"])
    t_fmmd = min(fm["log"].time_to_loss(target), fm["time_to_final"])
    emit(
        "fig5_training",
        1e6 * dt,
        f"time_reduction_vs_clique={100*(1 - t_fmmd/max(t_clique,1e-9)):.0f}%;"
        f"final_loss_fmmd={fm['final_loss']:.3f};"
        f"final_loss_clique={base['final_loss']:.3f};"
        f"tau_model={fm['tau_model']}",
    )
    for k, v in res.items():
        print(
            f"  {k:8s} tau={v['tau']:8.1f}s rho={v['rho']:.3f} "
            f"final_loss={v['final_loss']:.4f} "
            f"modeled_time[{v['tau_model']}]={v['time_to_final']/3600:.1f}h"
        )


if __name__ == "__main__":
    main()
