"""Stochastic online re-routing gate.

Markov-modulated Roofnet-like instance (§IV-A statistics): the mid-path
underlay hops of five ring links are modulated by a two-state Markov
chain (good ↔ 20×-degraded, persistent degradation — the diurnal-sag
regime), sampled at fixed boundaries. Three checks:

  1. *Determinism*: the same key draws a bitwise-identical realization
     (the contract that makes stochastic pricing a seeded expectation).
  2. *Degenerate case*: a one-state Markov process at base capacity
     realizes a trivial scenario, and online ``route_time_expanded``
     on it returns the static ``route()`` answer bitwise.
  3. *Online gate*: across seeded realizations, the online schedule —
     deciding at every boundary from the realized state only, with the
     carryover-aware objective — has simulated makespan ≤ the
     oracle-static schedule's (the static optimum simulated under the
     same realization) on every rollout.
"""

import time

import numpy as np

from repro.net import (
    MarkovLinkModel,
    StochasticScenario,
    build_overlay,
    compute_categories,
    demands_from_links,
    lowest_degree_nodes,
    mid_path_edges,
    roofnet_like,
    route,
    route_time_expanded,
    simulate,
    simulate_phased,
)
from benchmarks.common import KAPPA, NUM_AGENTS, emit

DEGRADATION = 0.05   # 20x capacity drop in the degraded Markov state
NUM_ROLLOUTS = 5
# Persistent degradation: once a region sags it stays sagged for
# ~1/0.05 = 20 boundaries on average — re-routing around it pays for
# the restart of the abandoned in-flight volume.
TRANSITION = ((0.8, 0.2), (0.05, 0.95))


def make_instance():
    u = roofnet_like(seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, NUM_AGENTS))
    cats = compute_categories(ov)
    m = NUM_AGENTS
    links = sorted({(min(i, (i + 1) % m), max(i, (i + 1) % m))
                    for i in range(m)})
    demands = demands_from_links(links, KAPPA, m)
    return ov, cats, demands


def modulated_edges(ov, links=5):
    """Mid-path hops of the first ``links`` ring links' default paths —
    the hops an online re-route can actually avoid."""
    return mid_path_edges(ov, [(k, k + 1) for k in range(links)])


def run() -> dict:
    ov, cats, demands = make_instance()
    m = NUM_AGENTS
    static = route(demands, cats, KAPPA, m, milp_var_budget=0, seed=0)
    tau = static.completion_time
    edges = modulated_edges(ov)
    sto = StochasticScenario(
        links=(MarkovLinkModel(
            edges=edges, scales=(1.0, DEGRADATION),
            transition=TRANSITION, initial=0,
        ),),
        step=0.5 * tau,
        horizon=8 * tau,
    )

    # 1. Seeded sampling is bitwise-deterministic.
    assert sto.sample(0) == sto.sample(0), (
        "same key must draw a bitwise-identical realization"
    )
    assert sto.sample(0) != sto.sample(1), (
        "different keys should draw distinct realizations"
    )

    # 2. Degenerate one-state process == static route(), bitwise.
    degenerate = StochasticScenario(
        links=(MarkovLinkModel(
            edges=edges, scales=(1.0,), transition=((1.0,),),
        ),),
        step=0.5 * tau, horizon=8 * tau,
    )
    realization = degenerate.sample(0)
    assert realization.is_trivial
    trivial = route_time_expanded(
        demands, cats, realization, KAPPA, m, milp_var_budget=0, seed=0,
        online=True, overlay=ov,
    )
    assert trivial.num_segments == 1
    assert trivial.solutions[0].trees == static.trees, (
        "online routing on a degenerate one-state process must return "
        "the static trees bitwise"
    )
    assert trivial.solutions[0].completion_time == static.completion_time

    # 3. Online ≤ oracle-static on every seeded rollout.
    makespans_static, makespans_online, reroutes = [], [], 0
    t_online = 0.0
    for key in range(NUM_ROLLOUTS):
        realization = sto.sample(key)
        s_static = simulate(static, ov, scenario=realization)
        t0 = time.perf_counter()
        online = route_time_expanded(
            demands, cats, realization, KAPPA, m, milp_var_budget=0,
            seed=0, online=True, overlay=ov, base_solution=static,
        )
        t_online += time.perf_counter() - t0
        s_online = simulate_phased(online, ov, scenario=realization)
        assert s_online.makespan <= s_static.makespan + 1e-9, (
            f"rollout {key}: online schedule ({s_online.makespan:.1f}s) "
            f"must not lose to oracle-static ({s_static.makespan:.1f}s)"
        )
        makespans_static.append(s_static.makespan)
        makespans_online.append(s_online.makespan)
        reroutes += online.metadata["reroutes"]

    mean_static = float(np.mean(makespans_static))
    mean_online = float(np.mean(makespans_online))
    return dict(
        t_online=t_online / NUM_ROLLOUTS,
        mean_static=mean_static,
        mean_online=mean_online,
        p95_online=float(np.percentile(makespans_online, 95.0)),
        win=mean_static / mean_online,
        reroutes=reroutes,
        rollouts=NUM_ROLLOUTS,
    )


def main() -> None:
    r = run()
    emit(
        "stochastic_routing",
        1e6 * r["t_online"],
        f"mean_static_s={r['mean_static']:.1f};"
        f"mean_online_s={r['mean_online']:.1f};"
        f"p95_online_s={r['p95_online']:.1f};"
        f"win={r['win']:.2f}x;reroutes={r['reroutes']}",
    )


if __name__ == "__main__":
    main()
