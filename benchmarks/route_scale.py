"""Routing scale microbenchmark: vectorized congestion-aware engine vs.
the retained pure-Python reference at 100 agents, plus a 500-agent
design-sweep smoke test — the regime the reference cannot touch.

The head-to-head instance is the sim_scale 300-node random-geometric
edge network with heterogeneous link capacities (0.3–3 Mbps) and a
100-agent ring-and-chords mixing topology, routed for 8 re-routing
rounds. Both engines must return *identical* trees on the same seed
(hence identical τ); the vectorized engine must be ≥15× faster and never
worse than direct routing.

The second section builds a 500-agent overlay (single-source-BFS path
construction), compiles the link×category incidence once, and runs a
full ``sweep_iterations`` design sweep (FMMD-P grid + congestion-aware
routing per point) to document the newly reachable scale.
"""

import time

import numpy as np

from repro.core import ConvergenceConstants, sweep_iterations
from repro.net import (
    build_overlay,
    compile_category_incidence,
    compute_categories,
    demands_from_links,
    random_geometric_underlay,
    route_congestion_aware,
    route_direct,
)
from repro.net.routing import _route_congestion_aware_reference
from benchmarks.common import emit

SPEEDUP_TARGET = 15.0
ROUNDS = 8


def make_instance(
    num_agents: int,
    extra_links: int,
    nodes: int = 300,
    radius: float = 0.10,
    seed: int = 3,
):
    """Heterogeneous-capacity geometric underlay + ring-and-chords demands."""
    u = random_geometric_underlay(nodes, radius=radius, seed=seed)
    rng = np.random.default_rng(7)
    for _, _, data in u.graph.edges(data=True):
        data["capacity"] = 125_000.0 * rng.uniform(0.3, 3.0)
    ov = build_overlay(u, list(u.graph.nodes)[:num_agents], method="bfs")
    cats = compute_categories(ov)
    links = {
        (min(a, b), max(a, b))
        for a, b in ((i, (i + 1) % num_agents) for i in range(num_agents))
    }
    while len(links) < num_agents + extra_links:
        a, b = rng.choice(num_agents, 2, replace=False)
        links.add((min(a, b), max(a, b)))
    return demands_from_links(sorted(links), 1e6, num_agents), cats


def run() -> dict:
    m = 100
    demands, cats = make_instance(num_agents=m, extra_links=30)

    t0 = time.perf_counter()
    vec = route_congestion_aware(demands, cats, 1e6, m, rounds=ROUNDS, seed=0)
    t_vec = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = _route_congestion_aware_reference(
        demands, cats, 1e6, m, rounds=ROUNDS, seed=0
    )
    t_ref = time.perf_counter() - t0

    assert vec.trees == ref.trees, "engines disagree on routed trees"
    assert vec.completion_time == ref.completion_time, (
        f"engines disagree: vectorized {vec.completion_time!r} "
        f"!= reference {ref.completion_time!r}"
    )
    direct = route_direct(demands, cats, 1e6)
    assert vec.completion_time <= direct.completion_time + 1e-9

    # Amortized regime: a precompiled incidence shared across calls, the
    # way sweep_iterations reuses it over the T grid.
    inc = compile_category_incidence(cats, m, 1e6)
    t0 = time.perf_counter()
    route_congestion_aware(
        demands, cats, 1e6, m, rounds=ROUNDS, seed=0, incidence=inc
    )
    t_amortized = time.perf_counter() - t0

    # 500-agent design sweep: overlay + categories + FMMD-P grid with
    # congestion-aware routing per point — untouchable before this PR.
    t0 = time.perf_counter()
    u = random_geometric_underlay(600, radius=0.08, seed=1)
    ov = build_overlay(u, list(u.graph.nodes)[:500], method="bfs")
    cats500 = compute_categories(ov)
    t_setup = time.perf_counter() - t0
    # T must exceed the 499-link connectivity floor for finite K(ρ).
    t0 = time.perf_counter()
    best = sweep_iterations(
        cats500, 1e6, 500, iteration_grid=(550, 625), method="fmmd-p",
        constants=ConvergenceConstants(epsilon=0.05), heuristic_rounds=2,
    )
    t_sweep = time.perf_counter() - t0
    assert np.isfinite(best.total_time)
    if best.routing.demands:
        direct500 = route_direct(best.routing.demands, cats500, 1e6)
        assert (
            best.routing.completion_time
            <= direct500.completion_time + 1e-9
        )

    return dict(
        t_vectorized=t_vec,
        t_reference=t_ref,
        t_amortized=t_amortized,
        speedup=t_ref / t_vec,
        tau=vec.completion_time,
        sweep_seconds=t_sweep,
        sweep_setup_seconds=t_setup,
        sweep_tau=best.routing.completion_time,
        sweep_total_time=best.total_time,
    )


def main() -> None:
    r = run()
    emit(
        "route_scale",
        1e6 * r["t_vectorized"],
        f"speedup={r['speedup']:.1f}x;"
        f"amortized_seconds={r['t_amortized']:.2f};"
        f"sweep500_seconds={r['sweep_seconds']:.1f};"
        f"sweep500_setup_seconds={r['sweep_setup_seconds']:.1f}",
    )
    assert r["speedup"] >= SPEEDUP_TARGET, (
        f"vectorized router only {r['speedup']:.1f}x faster "
        f"(target {SPEEDUP_TARGET:.0f}x)"
    )


if __name__ == "__main__":
    main()
