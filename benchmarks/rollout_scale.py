"""Monte-Carlo rollout throughput: one XLA launch vs the numpy loop.

The JAX engine prices a design under link-quality uncertainty by
running every Monte-Carlo rollout in a single device launch
(``jax_engine.simulate_rollout_batch``), where the numpy path calls
``simulate(engine="batched")`` once per rollout. This gate builds a
.220-agent single-hub star — every overlay link contends on its two
spoke uplinks, so one flaky-uplink Markov model perturbs the whole
instance — prices 256 correlated-fading rollouts both ways, and
checks:

- per-rollout makespan parity at rtol=1e-9 between the two engines on
  the same realization seeds (the numpy baseline is timed on a subset
  of the rollouts — its per-rollout cost is constant, the event loop
  is Python-overhead-bound — and the parity assertion covers exactly
  that subset);
- batch throughput: the warm one-launch cost per rollout must beat the
  numpy per-rollout cost by at least ``$ROLLOUT_SCALE_TARGET``
  (default 8x). The first launch is compilation and is excluded —
  designs are priced at hundreds of rollouts per candidate, so the
  warm cost is the one the designer pays.

The emitted record carries the measured speedup plus the tau_p95 /
tau_p99 pricing quantiles over all 256 rollouts, so the nightly trend
gate tracks both throughput and the statistic the designer consumes.

Honest floor vs the 20x goal: on a single CPU core this measures
~12x, and the arithmetic ceiling is ~15x — the numpy loop bottoms out
at ~43 us per water-filling round (Python dispatch floor) while the
fused JAX round costs ~2.7 us per rollout at 256 lanes (memory
bandwidth on the [512, 256] batch-last state). Reaching 20x+ needs
parallel lanes — multi-core XLA intra-op sharding or the Pallas fused
round kernel tracked in ROADMAP — so the default gate floor is set at
the conservative 8x and the measured ratio is trend-tracked instead.
"""

import os
import time

import networkx as nx
import numpy as np

from repro.net import (
    Underlay,
    build_overlay,
    compute_categories,
    demands_from_links,
    route_direct,
    simulate,
)
from repro.net import jax_engine
from repro.net.simulator import compile_incidence
from repro.net.stochastic import (
    MarkovLinkModel,
    StochasticScenario,
    densify_realizations,
)
from benchmarks.common import emit

NUM_AGENTS = 220
ROLLOUTS = 256
BASELINE_ROLLOUTS = 32
RTOL = 1e-9


def make_instance(num_agents=NUM_AGENTS, seed=11):
    """Single-hub star underlay with heterogeneous uplink capacities
    and a ring overlay: every overlay link is a two-spoke path through
    the hub, so B = E and the contention tables stay at the bounded
    degree (2) the batch-last kernel gathers through."""
    g = nx.Graph()
    rng = np.random.default_rng(seed)
    hub = num_agents
    for a in range(num_agents):
        g.add_edge(a, hub, capacity=125_000.0 * rng.uniform(0.3, 3.0))
    u = Underlay(graph=g)
    ov = build_overlay(u, list(range(num_agents)))
    cats = compute_categories(ov)
    links = sorted(
        {
            (min(a, b), max(a, b))
            for a, b in ((i, (i + 1) % num_agents) for i in range(num_agents))
        }
    )
    demands = demands_from_links(links, 1e6, num_agents)
    return route_direct(demands, cats, 1e6), ov


def run(rollouts=ROLLOUTS, baseline_rollouts=BASELINE_ROLLOUTS) -> dict:
    sol, ov = make_instance()
    inc = compile_incidence(sol, ov)
    tau = simulate(sol, ov, engine="batched", incidence=inc).makespan

    # Correlated fading on every 7th uplink: a two-state Markov chain
    # degrades the link to 35% of nominal, re-sampled on a 0.4*tau
    # grid over a 4*tau horizon.
    flaky = tuple((a, NUM_AGENTS) for a in range(0, NUM_AGENTS, 7))
    scenario = StochasticScenario(
        links=(
            MarkovLinkModel(
                edges=flaky,
                scales=(1.0, 0.35),
                transition=((0.8, 0.2), (0.5, 0.5)),
            ),
        ),
        step=0.4 * tau,
        horizon=4 * tau,
    )
    reals = tuple(scenario.sample((13, r)) for r in range(rollouts))
    batch = densify_realizations(reals, inc)

    # First launch compiles; the second is the steady-state cost a
    # design-pricing sweep pays per candidate.
    jax_engine.simulate_rollout_batch(sol, ov, batch, incidence=inc)
    t0 = time.perf_counter()
    priced = jax_engine.simulate_rollout_batch(sol, ov, batch, incidence=inc)
    t_jax = (time.perf_counter() - t0) / rollouts

    t0 = time.perf_counter()
    baseline = [
        simulate(sol, ov, scenario=sc, engine="batched", incidence=inc)
        for sc in batch.realizations[:baseline_rollouts]
    ]
    t_numpy = (time.perf_counter() - t0) / baseline_rollouts

    for r, (jx, npy) in enumerate(zip(priced, baseline)):
        assert np.isclose(
            jx.makespan, npy.makespan, rtol=RTOL, atol=0.0
        ), (
            f"rollout {r}: makespan parity broken beyond rtol={RTOL}: "
            f"jax={jx.makespan!r} numpy={npy.makespan!r}"
        )

    makespans = np.array([res.makespan for res in priced])
    return dict(
        rollouts=rollouts,
        baseline_rollouts=baseline_rollouts,
        t_jax=t_jax,
        t_numpy=t_numpy,
        speedup=t_numpy / t_jax,
        tau_nominal=tau,
        tau_p95=float(np.percentile(makespans, 95)),
        tau_p99=float(np.percentile(makespans, 99)),
    )


def main() -> None:
    r = run()
    target = float(os.environ.get("ROLLOUT_SCALE_TARGET", "8"))
    emit(
        "rollout_scale",
        1e6 * r["t_jax"],
        f"rollouts={r['rollouts']};speedup={r['speedup']:.1f}x;"
        f"tau_p95={r['tau_p95']:.1f};tau_p99={r['tau_p99']:.1f}",
    )
    assert r["speedup"] >= target, (
        f"rollout throughput regression: one-launch batch is only "
        f"{r['speedup']:.1f}x the numpy per-rollout loop "
        f"(floor {target:.0f}x, override via $ROLLOUT_SCALE_TARGET)"
    )


if __name__ == "__main__":
    main()
