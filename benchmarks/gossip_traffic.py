"""Beyond-paper benchmark: modeled gossip collective bytes per step —
sparse FMMD schedule vs clique all-gather vs all-reduce DP, across agent
counts. Quantifies the paper's payoff on the ICI fabric (DESIGN §4)."""

import time

import numpy as np

from benchmarks.common import emit
from repro.core import gossip
from repro.launch.fabric import design_mixing_matrix


def run(kappa: float = 1e9) -> list[dict]:
    rows = []
    for m, pods in ((8, 1), (16, 1), (32, 2)):
        w, design = design_mixing_matrix(m, pods=pods, kappa_bytes=kappa)
        sched = gossip.build_schedule(w)
        sparse = gossip.gossip_collective_bytes(sched, kappa)
        clique = m * (m - 1) * kappa          # all-gather everyone
        allreduce = 2 * (m - 1) / m * kappa * m  # ring AR total
        rows.append(
            dict(m=m, pods=pods, rounds=len(sched.rounds),
                 links=len(design.activated_links) if design else 0,
                 sparse_GB=sparse / 1e9, clique_GB=clique / 1e9,
                 allreduce_GB=allreduce / 1e9,
                 rho=float(np.linalg.norm(
                     w - np.full((m, m), 1 / m), 2)))
        )
    return rows


def main() -> None:
    t0 = time.perf_counter()
    rows = run()
    r16 = [r for r in rows if r["m"] == 16][0]
    emit(
        "gossip_traffic",
        1e6 * (time.perf_counter() - t0) / len(rows),
        f"m16_sparse={r16['sparse_GB']:.1f}GB_vs_clique={r16['clique_GB']:.1f}GB"
        f"_x{r16['clique_GB']/max(r16['sparse_GB'],1e-9):.1f}",
    )
    for r in rows:
        print(
            f"  m={r['m']:3d} pods={r['pods']} links={r['links']:3d} "
            f"rounds={r['rounds']:2d} rho={r['rho']:.3f} "
            f"sparse={r['sparse_GB']:6.1f}GB clique={r['clique_GB']:6.1f}GB "
            f"allreduce={r['allreduce_GB']:6.1f}GB"
        )


if __name__ == "__main__":
    main()
