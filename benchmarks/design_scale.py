"""Design-pipeline scale benchmark: vectorized category compilation and
the 1000-agent FMMD-P design sweep.

Section 1 (500 agents, the PR-2 sweep instance): times the retained
reference implementations (``_compute_categories_reference`` dict-of-set
grouping + ``_compile_category_incidence_reference`` per-link append
compiler) against the vectorized pipeline, asserts the outputs are
bitwise-identical (same family keys in the same order, same CSR entry
arrays), and gates

  * ``compile_category_incidence`` ≥ 10× — the CSR compilation step the
    tentpole rewrites builds straight off the precompiled flat payload
    (measured ~100-300×), and
  * the full compute+compile pipeline ≥ 2.5× — bounded below the
    compile ratio because reproducing the reference's frozenset-keyed
    mappings bit for bit costs ~2M tuple hashes that no array trick
    removes (measured ~3.3-3.7×).

Section 2 (1000 agents): a full ``sweep_iterations`` FMMD-P design —
1200-node geometric underlay, 1000-agent overlay (single-source-BFS
paths), T=1050 (past the 999-link connectivity floor so K(ρ) is finite)
with congestion-aware routing — gated under ``SWEEP_BUDGET_SECONDS``.
Before this PR the category compilation alone made this regime
untouchable; now the sweep is dominated by the inherent per-iteration
eigendecomposition of the 1000×1000 iterate.
"""

import time

import numpy as np

from repro.core import ConvergenceConstants, sweep_iterations
from repro.net import (
    build_overlay,
    compile_category_incidence,
    compute_categories,
    random_geometric_underlay,
)
from repro.net.categories import (
    _compile_category_incidence_reference,
    _compute_categories_reference,
)
from benchmarks.common import emit

COMPILE_SPEEDUP_TARGET = 10.0
PIPELINE_SPEEDUP_TARGET = 2.5
SWEEP_BUDGET_SECONDS = 1500.0
KAPPA = 1e6


def _overlay(num_nodes: int, num_agents: int, radius: float, seed: int):
    u = random_geometric_underlay(num_nodes, radius=radius, seed=seed)
    return build_overlay(
        u, list(u.graph.nodes)[:num_agents], method="bfs"
    )


def run() -> dict:
    # ---- Section 1: 500-agent category compilation, gated ≥10×. ----
    m = 500
    ov = _overlay(600, m, radius=0.08, seed=1)

    t0 = time.perf_counter()
    ref_cats = _compute_categories_reference(ov)
    t_ref_cats = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref_inc = _compile_category_incidence_reference(ref_cats, m, KAPPA)
    t_ref_inc = time.perf_counter() - t0

    t0 = time.perf_counter()
    vec_cats = compute_categories(ov)
    t_vec_cats = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec_inc = compile_category_incidence(vec_cats, m, KAPPA)
    t_vec_inc = time.perf_counter() - t0

    # Bitwise identity is the contract, not an approximation.
    assert list(vec_cats.members.items()) == list(ref_cats.members.items())
    assert list(vec_cats.capacity.items()) == list(ref_cats.capacity.items())
    assert list(vec_cats.edge_capacity.items()) == list(
        ref_cats.edge_capacity.items()
    )
    for name in ("capacity", "entry_link", "entry_cat", "entry_coef",
                 "link_ptr"):
        a, b = getattr(vec_inc, name), getattr(ref_inc, name)
        assert a.dtype == b.dtype and np.array_equal(a, b), name

    compile_speedup = t_ref_inc / t_vec_inc
    pipeline_speedup = (t_ref_cats + t_ref_inc) / (t_vec_cats + t_vec_inc)

    # ---- Section 2: 1000-agent FMMD-P sweep under budget. ----
    t0 = time.perf_counter()
    ov1000 = _overlay(1200, 1000, radius=0.06, seed=1)
    cats1000 = compute_categories(ov1000)
    t_setup = time.perf_counter() - t0
    t0 = time.perf_counter()
    best = sweep_iterations(
        cats1000, KAPPA, 1000, iteration_grid=(1050,), method="fmmd-p",
        constants=ConvergenceConstants(epsilon=0.05), heuristic_rounds=1,
    )
    t_sweep = time.perf_counter() - t0
    assert np.isfinite(best.total_time), "1000-agent design not finite"
    assert len(best.design.activated_links) >= 999, "design not spanning"

    return dict(
        t_ref_cats=t_ref_cats,
        t_ref_inc=t_ref_inc,
        t_vec_cats=t_vec_cats,
        t_vec_inc=t_vec_inc,
        compile_speedup=compile_speedup,
        pipeline_speedup=pipeline_speedup,
        num_categories=len(vec_cats.capacity),
        nnz=int(vec_inc.entry_link.size),
        setup1000_seconds=t_setup,
        sweep1000_seconds=t_sweep,
        sweep1000_tau=best.routing.completion_time,
        sweep1000_total_time=best.total_time,
    )


def main() -> None:
    r = run()
    emit(
        "design_scale",
        1e6 * (r["t_vec_cats"] + r["t_vec_inc"]),
        f"compile_speedup={r['compile_speedup']:.1f}x;"
        f"pipeline_speedup={r['pipeline_speedup']:.1f}x;"
        f"setup1000_seconds={r['setup1000_seconds']:.1f};"
        f"sweep1000_seconds={r['sweep1000_seconds']:.1f};"
        f"sweep1000_tau_s={r['sweep1000_tau']:.1f}",
    )
    assert r["compile_speedup"] >= COMPILE_SPEEDUP_TARGET, (
        f"incidence compilation only {r['compile_speedup']:.1f}x faster "
        f"(target {COMPILE_SPEEDUP_TARGET:.0f}x)"
    )
    assert r["pipeline_speedup"] >= PIPELINE_SPEEDUP_TARGET, (
        f"category pipeline only {r['pipeline_speedup']:.1f}x faster "
        f"(target {PIPELINE_SPEEDUP_TARGET:.0f}x)"
    )
    assert r["sweep1000_seconds"] <= SWEEP_BUDGET_SECONDS, (
        f"1000-agent sweep took {r['sweep1000_seconds']:.0f}s "
        f"(budget {SWEEP_BUDGET_SECONDS:.0f}s)"
    )


if __name__ == "__main__":
    main()
