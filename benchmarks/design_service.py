"""Design-as-a-service benchmark: replayed Markov link dynamics at
1000 agents, incremental amendment vs from-scratch redesign, plus a
chaos variant with pricing faults injected.

Three gates:

  * **event rate** — ``DesignService`` must sustain ≥ ``RATE_TARGET``×
    the event rate of the scratch pipeline (categories + incidence +
    FMMD-P + routing re-run per event). Scratch is timed on a sparse
    checkpoint subset (it is exactly the 490-second-sweep cost the
    service exists to amortize) and extrapolated per event.
  * **realized τ** — at every checkpoint the service's deployed τ must
    be equal-or-better than the scratch redesign's τ on the *same*
    network state (mean over checkpoints). The service is configured
    τ-greedy here (zero drift band, long horizon) so any strictly
    better candidate is adopted; at re-priced events it deploys
    ``min(incumbent, candidate)`` and can only tie or win.
  * **chaos** — the same stream replayed with a ``FaultInjector``
    (raise/timeout/nan/stale at ``CHAOS_RATE``): every event must still
    produce exactly one record (zero dropped), at least one fault must
    actually fire, and the mean deployed τ must stay within
    ``CHAOS_TAU_FACTOR``× of the fault-free run — graceful degradation,
    not collapse.

The scratch baseline pins the overlay's routing paths (hop-count paths
are capacity-independent; rebuilding them off a copied graph changes
BFS tie-breaks, not the metric) so both sides design against the same
category structure — the comparison measures *incrementality*, not
path-tie-break luck.

Usage::

  PYTHONPATH=src python -m benchmarks.design_service \
      [--agents 1000] [--nodes 1200] [--steps 30] [--iters 8] \
      [--checkpoint-every 6]

Defaults reproduce the acceptance-scale run; CI smoke can shrink it.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.fmmd import fmmd
from repro.net import (
    build_overlay,
    compile_category_incidence,
    compute_categories,
    random_geometric_underlay,
)
from repro.net.demands import demands_from_links
from repro.net.routing import route_direct
from repro.net.stochastic import MarkovLinkModel, StochasticScenario
from repro.net.topology import OverlayNetwork
from repro.runtime.design_service import DesignService, ServiceConfig
from repro.runtime.events import AgentLeave, events_from_stochastic
from repro.runtime.faultinject import FaultInjector, FaultPlan
from benchmarks.common import emit

KAPPA = 1e6
RATE_TARGET = 10.0
CHAOS_TAU_FACTOR = 1.5
CHAOS_RATE = 0.3


def _overlay(num_nodes: int, num_agents: int, seed: int):
    # 0.06 is the 1200-node acceptance instance; smaller smoke runs need
    # a wider radius to stay connected (~n·r² contact rate held roughly
    # constant).
    radius = max(0.06, 2.0 / num_nodes**0.5)
    und = random_geometric_underlay(num_nodes, radius=radius, seed=seed)
    return build_overlay(
        und, list(und.graph.nodes)[:num_agents], method="bfs"
    )


def _stream(svc: DesignService, steps: int, seed: int):
    """Markov dynamics over a spread of category member links, plus
    hazard-driven churn — the replayable input of the whole benchmark."""
    links = sorted(
        {(u, v) if u < v else (v, u)
         for u, v in svc.categories.edge_capacity}
    )
    rng = np.random.default_rng(seed)
    groups = [
        tuple(links[i] for i in sorted(
            rng.choice(len(links), size=min(12, len(links)),
                       replace=False).tolist()
        ))
        for _ in range(4)
    ]
    sto = StochasticScenario(
        links=tuple(
            MarkovLinkModel(
                edges=g,
                scales=(1.0, 0.3),
                transition=((0.55, 0.45), (0.5, 0.5)),
            )
            for g in groups
        ),
        step=5.0,
        horizon=5.0 * steps,
        churn_agents=(1, 2),
        churn_hazard=0.01,
    )
    return events_from_stochastic(sto, key=seed)


def _scratch_redesign(underlay, agent_nodes, scale, iters):
    """The full per-event pipeline the service amortizes away: regroup
    categories, recompile the incidence, cold FMMD-P, route. Paths are
    pinned to the unscaled graph (see module docstring)."""
    ov = build_overlay(underlay, agent_nodes)
    if scale:
        ov = OverlayNetwork(
            underlay=underlay.with_scaled_capacities(dict(scale)),
            agents=ov.agents,
            paths=ov.paths,
        )
    m = ov.num_agents
    cats = compute_categories(ov)
    inc = compile_category_incidence(cats, m, KAPPA)
    res = fmmd(
        m, iters, categories=cats, kappa=KAPPA, priority=True,
        incidence=inc,
    )
    routing = route_direct(
        demands_from_links(res.activated_links, KAPPA, m), cats, KAPPA
    )
    return float(routing.completion_time)


def _replay(overlay, events, iters, injector=None):
    cfg = ServiceConfig(
        design_iterations=iters,
        drift_band=0.0,  # τ-greedy: re-price on any realized-τ move
        horizon_rounds=1e9,
        transition_rounds=0.0,
    )
    svc = DesignService(
        overlay, kappa=KAPPA, config=cfg, fault_injector=injector
    )
    taus = []
    t0 = time.perf_counter()
    for ev in events:
        svc.process(ev)
        taus.append(svc.tau)
    elapsed = time.perf_counter() - t0
    return svc, taus, elapsed


def run(agents: int, nodes: int, steps: int, iters: int,
        checkpoint_every: int, rate_target: float = RATE_TARGET) -> dict:
    overlay = _overlay(nodes, agents, seed=1)
    base = DesignService(
        overlay, kappa=KAPPA,
        config=ServiceConfig(design_iterations=iters),
    )
    events = _stream(base, steps, seed=7)
    if not events:
        raise RuntimeError("empty event stream — raise steps")

    # ---- fault-free incremental replay --------------------------------
    svc, taus_inc, t_inc = _replay(overlay, events, iters)
    assert len(svc.log) == len(events), "dropped events in replay"
    rate_inc = len(events) / t_inc

    # ---- scratch checkpoints ------------------------------------------
    # Walk the stream maintaining (scale map, membership) and rebuild
    # from scratch at every k-th event; extrapolate the per-event cost.
    scale: dict = {}
    node_of = {
        h: overlay.agents[h] for h in range(overlay.num_agents)
    }
    scratch_times, tau_pairs = [], []
    for k, ev in enumerate(events):
        if isinstance(ev, AgentLeave):
            if ev.agent in node_of and len(node_of) > 1:
                del node_of[ev.agent]
        else:
            for e, s in ev.scales.items():
                key = (e[0], e[1]) if e[0] < e[1] else (e[1], e[0])
                if s == 1.0:
                    scale.pop(key, None)
                else:
                    scale[key] = s
        if k % checkpoint_every == 0:
            t0 = time.perf_counter()
            tau_scr = _scratch_redesign(
                overlay.underlay,
                [node_of[h] for h in sorted(node_of)],
                scale, iters,
            )
            scratch_times.append(time.perf_counter() - t0)
            tau_pairs.append((taus_inc[k], tau_scr))
    rate_scr = 1.0 / float(np.mean(scratch_times))
    speedup = rate_inc / rate_scr
    mean_inc = float(np.mean([a for a, _ in tau_pairs]))
    mean_scr = float(np.mean([b for _, b in tau_pairs]))

    # ---- chaos replay --------------------------------------------------
    injector = FaultInjector(
        FaultPlan(seed=13, rate=CHAOS_RATE, timeout_seconds=1.0)
    )
    svc_c, taus_chaos, _ = _replay(overlay, events, iters, injector)
    assert len(svc_c.log) == len(events), "chaos run dropped events"
    n_faults = len(injector.injected)
    mean_chaos = float(np.mean(taus_chaos))
    mean_free = float(np.mean(taus_inc))

    emit(
        "design_service_event_rate",
        1e6 / rate_inc,
        f"{rate_inc:.2f} ev/s incremental vs {rate_scr:.3f} ev/s "
        f"scratch = {speedup:.1f}x (target >= {rate_target}x) over "
        f"{len(events)} events at m={agents}",
    )
    emit(
        "design_service_realized_tau",
        1e6 / rate_inc,
        f"mean tau {mean_inc:.4g} (incremental) vs {mean_scr:.4g} "
        f"(scratch) over {len(tau_pairs)} checkpoints",
    )
    emit(
        "design_service_chaos",
        1e6 / rate_inc,
        f"mean tau {mean_chaos:.4g} chaos vs {mean_free:.4g} fault-free "
        f"({mean_chaos / max(mean_free, 1e-12):.2f}x, limit "
        f"{CHAOS_TAU_FACTOR}x), {n_faults} faults injected, "
        f"decisions {dict(sorted(svc_c.log.decisions.items()))}",
    )

    assert speedup >= rate_target, (
        f"incremental event rate only {speedup:.1f}x scratch "
        f"(target {rate_target}x)"
    )
    assert mean_inc <= mean_scr * (1.0 + 1e-9), (
        f"incremental realized tau {mean_inc:.6g} worse than scratch "
        f"{mean_scr:.6g}"
    )
    assert n_faults > 0, "chaos run injected no faults — plan too weak"
    assert mean_chaos <= CHAOS_TAU_FACTOR * mean_free, (
        f"chaos tau {mean_chaos:.6g} exceeds {CHAOS_TAU_FACTOR}x "
        f"fault-free {mean_free:.6g}"
    )
    return {
        "events": len(events),
        "speedup": speedup,
        "mean_tau_incremental": mean_inc,
        "mean_tau_scratch": mean_scr,
        "mean_tau_chaos": mean_chaos,
        "faults": n_faults,
    }


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--agents", type=int, default=1000)
    p.add_argument("--nodes", type=int, default=1200)
    p.add_argument("--steps", type=int, default=30)
    p.add_argument("--iters", type=int, default=8)
    p.add_argument("--checkpoint-every", type=int, default=6)
    # The 10x floor is the m=1000 acceptance gate; scaled-down smoke
    # runs (where scratch is not yet painful) may pass a lower floor.
    p.add_argument("--rate-target", type=float, default=RATE_TARGET)
    a = p.parse_args(argv)
    run(a.agents, a.nodes, a.steps, a.iters, a.checkpoint_every,
        rate_target=a.rate_target)


if __name__ == "__main__":
    main()
