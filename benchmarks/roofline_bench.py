"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run records (dryrun_results.json). One row per cell: the three terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline
fraction. This is the benchmark backing EXPERIMENTS.md §Roofline."""

import json
import os
import time

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def load(mesh: str = "pod16x16") -> list[dict]:
    with open(RESULTS) as f:
        records = json.load(f)
    return [r for r in records if r.get("mesh") == mesh]


def render(records: list[dict]) -> list[str]:
    lines = []
    hdr = (
        f"| {'arch':24s} | {'shape':11s} | {'compute':>9s} | {'memory':>9s} "
        f"| {'collective':>10s} | {'dominant':10s} | {'MF/HF':>6s} "
        f"| {'roofline':>8s} |"
    )
    lines.append(hdr)
    lines.append("|" + "-" * (len(hdr) - 2) + "|")
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']:24s} | {r['shape']:11s} | {'—':>9s} | {'—':>9s} "
                f"| {'—':>10s} | {'skipped':10s} | {'—':>6s} | {'—':>8s} |"
            )
            continue
        if r["status"] != "ok":
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']:24s} | {r['shape']:11s} "
            f"| {rf['compute_s']*1e3:8.2f}ms | {rf['memory_s']*1e3:8.2f}ms "
            f"| {rf['collective_s']*1e3:9.2f}ms | {rf['dominant']:10s} "
            f"| {rf['useful_flops_fraction']:6.2f} "
            f"| {rf['roofline_fraction']*100:7.2f}% |"
        )
    return lines


def main() -> None:
    t0 = time.perf_counter()
    for mesh in ("pod16x16", "pod2x16x16"):
        records = load(mesh)
        if not records:
            continue
        print(f"# mesh {mesh} ({len(records)} cells)")
        for ln in render(records):
            print(ln)
    ok = [r for r in load("pod16x16") if r["status"] == "ok"]
    best = max(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    emit(
        "roofline_bench",
        1e6 * (time.perf_counter() - t0),
        f"cells={len(ok)};best={best['arch']}x{best['shape']}="
        f"{best['roofline']['roofline_fraction']*100:.1f}%",
    )


if __name__ == "__main__":
    main()
