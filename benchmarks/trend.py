"""Benchmark trend gate: diff two ``bench-results.jsonl`` files.

The nightly workflow uploads every benchmark record as a JSON line
(``benchmarks/common.emit`` with ``$BENCH_JSON`` set):

    {"name": ..., "us_per_call": ..., "derived": "k1=v1;k2=v2x;...",
     "timestamp": ...}

The ``trend`` job downloads the previous successful run's artifact and
runs this script against the current run's file. It fails (exit 1) with
a readable table when any *headline* metric regresses more than
``--threshold`` (default 10%); wall-clock metrics — ``us_per_call``
plus any derived key containing ``seconds`` or ``speedup`` (measured
timings and ratios of timings; the naming convention the emitters
follow) — are compared against the looser ``--time-threshold``
(default 50%) because shared CI runners jitter far more than the
machine-independent headline metrics (simulated makespans ``*_s``,
win ratios of simulated values, counts, error magnitudes).

Direction is inferred per metric: keys ending in ``x`` or containing
``win``/``speedup``/``ratio`` are higher-is-better; everything else
(timings, makespans, error magnitudes) is lower-is-better. Benchmarks
present only in one file are reported but never fail the gate — a brand
new benchmark has no baseline, and a removed one is a code change, not
a regression. A missing baseline *file* (the very first run, or the
previous run predates artifact upload) passes with a notice.

``compare`` only diffs metrics present in *both* records, so a metric
(or whole benchmark) that silently stops being emitted would otherwise
vanish from the gate without a trace — a benchmark that loses its
headline metric looks permanently green. Vanished benchmarks and
vanished per-benchmark metrics are therefore listed explicitly in the
output (a notice, not a failure: removal is a code change the PR diff
shows, not a nightly regression — but it must be *visible*).

Stdlib-only on purpose: the trend job runs without installing the repo.

Usage:  python benchmarks/trend.py BASELINE.jsonl CURRENT.jsonl
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import sys

_HIGHER_HINTS = ("win", "speedup", "ratio")
TIME_METRIC = "us_per_call"


@dataclasses.dataclass(frozen=True)
class Delta:
    """One compared metric of one benchmark."""

    bench: str
    metric: str
    baseline: float
    current: float
    higher_is_better: bool
    threshold: float

    @property
    def change(self) -> float:
        """Signed relative change, oriented so positive == better."""
        if self.baseline == 0:
            return 0.0
        rel = (self.current - self.baseline) / abs(self.baseline)
        return rel if self.higher_is_better else -rel

    @property
    def regressed(self) -> bool:
        return self.change < -self.threshold


def higher_is_better(key: str) -> bool:
    return key.endswith("x") or any(h in key for h in _HIGHER_HINTS)


def is_wallclock(key: str) -> bool:
    """Measured-timing metrics (runner-jitter-prone): ``us_per_call``
    and, by emitter naming convention, ``*seconds*`` timings and
    ``*speedup*`` timing ratios. Simulated durations use the ``_s``
    suffix instead and stay on the tight threshold."""
    return (
        key == TIME_METRIC or "seconds" in key or "speedup" in key
    )


def parse_derived(derived: str) -> dict[str, float]:
    """Numeric metrics out of the ``k1=v1;k2=4.2x;...`` derived field."""
    out: dict[str, float] = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        val = val.strip()
        if val.endswith("x"):
            val = val[:-1]
        try:
            out[key.strip()] = float(val)
        except ValueError:
            continue  # non-numeric derived detail
    return out


def load_records(path: str) -> dict[str, dict]:
    """Latest record per benchmark name (later lines win — a re-run
    within one job supersedes its earlier emission)."""
    records: dict[str, dict] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue  # tolerate a torn tail line
            name = rec.get("name")
            if name:
                records[str(name)] = rec
    return records


def metrics_of(rec: dict) -> dict[str, float]:
    out = {}
    try:
        out[TIME_METRIC] = float(rec.get(TIME_METRIC))
    except (TypeError, ValueError):
        pass
    out.update(parse_derived(rec.get("derived", "")))
    return out


def vanished_metrics(
    baseline: dict[str, dict], current: dict[str, dict]
) -> list[str]:
    """``bench.metric`` entries present in the baseline record but
    missing from the current record of a benchmark that still ran —
    metrics the gate can no longer see (``compare`` iterates current
    metrics only)."""
    gone: list[str] = []
    for name in sorted(current):
        base_rec = baseline.get(name)
        if base_rec is None:
            continue
        missing = sorted(
            set(metrics_of(base_rec)) - set(metrics_of(current[name]))
        )
        gone.extend(f"{name}.{key}" for key in missing)
    return gone


def compare(
    baseline: dict[str, dict],
    current: dict[str, dict],
    threshold: float = 0.10,
    time_threshold: float = 0.50,
) -> list[Delta]:
    """Deltas for every metric present in both files (benchmark-wise)."""
    deltas: list[Delta] = []
    for name in sorted(current):
        base_rec = baseline.get(name)
        if base_rec is None:
            continue  # new benchmark: nothing to regress against
        base_m = metrics_of(base_rec)
        cur_m = metrics_of(current[name])
        for key in cur_m:
            if key not in base_m:
                continue
            b, c = base_m[key], cur_m[key]
            if not (math.isfinite(b) and math.isfinite(c)):
                continue
            deltas.append(
                Delta(
                    bench=name,
                    metric=key,
                    baseline=b,
                    current=c,
                    higher_is_better=higher_is_better(key),
                    threshold=(
                        time_threshold if is_wallclock(key) else threshold
                    ),
                )
            )
    return deltas


def format_table(deltas: list[Delta]) -> str:
    header = (
        f"{'benchmark':22s} {'metric':18s} {'baseline':>14s} "
        f"{'current':>14s} {'change':>8s}  status"
    )
    lines = [header, "-" * len(header)]
    for d in deltas:
        arrow = "+" if d.change >= 0 else ""
        status = "REGRESSED" if d.regressed else "ok"
        lines.append(
            f"{d.bench:22s} {d.metric:18s} {d.baseline:14.4g} "
            f"{d.current:14.4g} {arrow}{100 * d.change:7.1f}%  {status}"
        )
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="previous run's bench-results.jsonl")
    ap.add_argument("current", help="this run's bench-results.jsonl")
    ap.add_argument(
        "--threshold", type=float, default=0.10,
        help="max tolerated relative regression for derived headline "
             "metrics (default 0.10 = 10%%)",
    )
    ap.add_argument(
        "--time-threshold", type=float, default=0.50,
        help="max tolerated relative regression for wall-clock metrics "
             "(us_per_call, *seconds*, *speedup*; default 0.50 — CI "
             "runner jitter)",
    )
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(
            f"trend: no baseline at {args.baseline!r} (first run, or the "
            "previous run uploaded no artifact) — passing with a notice."
        )
        return 0
    baseline = load_records(args.baseline)
    current = (
        load_records(args.current) if os.path.exists(args.current) else {}
    )
    if not current:
        if os.environ.get("BENCH_JSON"):
            print(
                f"trend: $BENCH_JSON is set but {args.current!r} holds "
                "no benchmark records — the benchmark job emitted "
                "nothing (every benchmark died before common.emit, or "
                "emission broke). Failing so the empty run is visible "
                "instead of silently passing the gate."
            )
            return 1
        print(f"trend: no records in {args.current!r} — nothing to gate.")
        return 0

    deltas = compare(
        baseline, current,
        threshold=args.threshold, time_threshold=args.time_threshold,
    )
    new = sorted(set(current) - set(baseline))
    gone = sorted(set(baseline) - set(current))
    lost = vanished_metrics(baseline, current)
    print(format_table(deltas))
    if new:
        print(f"new benchmarks (no baseline yet): {', '.join(new)}")
    if gone:
        print(
            "benchmarks absent from this run (their metrics are no "
            f"longer gated): {', '.join(gone)}"
        )
    if lost:
        print(
            "metrics present in the baseline but missing from this run "
            f"(no longer gated): {', '.join(lost)}"
        )

    regressions = [d for d in deltas if d.regressed]
    if regressions:
        print()
        print(
            f"trend: {len(regressions)} metric(s) regressed beyond the "
            "threshold:"
        )
        print(format_table(regressions))
        return 1
    print("trend: no regressions beyond threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
