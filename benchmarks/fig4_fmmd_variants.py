"""Fig. 4: FMMD vs FMMD-W / FMMD-P / FMMD-WP — ρ and τ̄ vs iterations T.

Paper findings reproduced: (i) ρ falls and τ̄ grows with T; (ii) weight
optimization is necessary for small ρ; (iii) the priority search space
cuts τ̄ by ~3× at equal T with only slight ρ degradation.
"""

import time

from benchmarks.common import KAPPA, NUM_AGENTS, emit, paper_scenario
from repro.core.fmmd import _tau_bar, fmmd


def run() -> list[dict]:
    _, _, cats = paper_scenario()
    rows = []
    for t in (4, 8, 12, 16, 24, 32):
        for variant, kw in (
            ("FMMD", {}),
            ("FMMD-W", {"weight_opt": True}),
            ("FMMD-P", {"priority": True, "categories": cats,
                        "kappa": KAPPA}),
            ("FMMD-WP", {"weight_opt": True, "priority": True,
                         "categories": cats, "kappa": KAPPA}),
        ):
            t0 = time.perf_counter()
            res = fmmd(NUM_AGENTS, t, **kw)
            dt = time.perf_counter() - t0
            tau_bar = _tau_bar(frozenset(res.activated_links), cats, KAPPA)
            rows.append(
                dict(T=t, variant=variant, rho=res.rho, tau_bar=tau_bar,
                     links=len(res.activated_links), seconds=dt)
            )
    return rows


def main() -> None:
    rows = run()
    at12 = {r["variant"]: r for r in rows if r["T"] == 12}
    emit(
        "fig4_fmmd_variants",
        1e6 * sum(r["seconds"] for r in rows) / len(rows),
        f"tau_ratio_P_vs_plain={at12['FMMD']['tau_bar']/max(at12['FMMD-P']['tau_bar'],1e-9):.2f}x;"
        f"rho_W={at12['FMMD-W']['rho']:.3f};rho_plain={at12['FMMD']['rho']:.3f}",
    )
    for r in rows:
        print(
            f"  T={r['T']:3d} {r['variant']:8s} rho={r['rho']:.4f} "
            f"tau_bar={r['tau_bar']:9.1f}s links={r['links']}"
        )


if __name__ == "__main__":
    main()
