"""Invariant lint + contract suite runtime, as a tracked metric.

The static checkers run on every push and the runtime contracts run
under the nightly tier-1 suite, so their cost is part of the CI
budget: this benchmark times both and emits them through
``common.emit`` so ``benchmarks/trend.py`` flags contract-overhead
regressions like any other tracked metric.

* ``lint_seconds``      — one full ``repro.analysis`` run (all six
                          checkers + waiver resolution) on this repo;
* ``validate_seconds``  — REPRO_VALIDATE=1 construction of the three
                          CSR structures on a 60-agent instance;
* ``tracelint_seconds`` — tracing every registered trace-lint target
                          (``tracelint.collect_metrics``), emitted in a
                          second ``tracelint`` record together with the
                          per-target jaxpr equation counts and the
                          water-fill round's carry/operand/round-pair
                          bytes — the Pallas-readiness numbers ROADMAP
                          open item 1 tracks (eqn counts and bytes are
                          deterministic for fixed shapes, so trend's
                          tight threshold is exactly right for them).

The validated/plain overhead ratio is printed for humans but not
emitted: trend's naming convention reads ``ratio``/``x`` as
higher-is-better, which is backwards for an overhead — regressions
surface through the two ``*seconds*`` wall-clock metrics instead.

The run also asserts the suite is green on the repo (exit 0) — a red
lint should fail the nightly loudly, not just the push gate.
"""

from __future__ import annotations

import os
import time
from pathlib import Path

from benchmarks.common import emit
from repro.analysis import tracelint
from repro.analysis.__main__ import CHECKERS, run as run_checkers
from repro.net import (
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    random_geometric_underlay,
)
from repro.net.categories import compile_category_incidence
from repro.net.demands import demands_from_links
from repro.net.routing import route_congestion_aware
from repro.net.simulator import compile_incidence

REPO = Path(__file__).resolve().parents[1]
NUM_AGENTS = 60
KAPPA = 94.47e6


def _time_lint() -> float:
    t0 = time.perf_counter()
    unwaived, waived = run_checkers(REPO, list(CHECKERS))
    elapsed = time.perf_counter() - t0
    assert not unwaived, (
        "repo lint is red:\n" + "\n".join(f.render() for f in unwaived)
    )
    assert waived, "waiver file should hold live exemptions"
    return elapsed


def _build_structures(overlay, cats, sol):
    """The constructions the contracts guard: category incidence,
    branch incidence, and the _FlatCategories payload (rebuilt via
    compute_categories)."""
    compute_categories(overlay)
    inc = compile_category_incidence(cats, NUM_AGENTS, KAPPA)
    binc = compile_incidence(sol, overlay)
    return inc, binc


def _time_construction(overlay, cats, sol, validate: bool,
                       reps: int = 3) -> float:
    prev = os.environ.get("REPRO_VALIDATE")
    os.environ["REPRO_VALIDATE"] = "1" if validate else "0"
    try:
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            _build_structures(overlay, cats, sol)
            best = min(best, time.perf_counter() - t0)
        return best
    finally:
        if prev is None:
            del os.environ["REPRO_VALIDATE"]
        else:
            os.environ["REPRO_VALIDATE"] = prev


def _time_tracelint() -> tuple[float, dict[str, int]]:
    t0 = time.perf_counter()
    metrics = tracelint.collect_metrics(REPO)
    return time.perf_counter() - t0, metrics


def main() -> None:
    lint_seconds = _time_lint()
    tracelint_seconds, trace_metrics = _time_tracelint()

    u = random_geometric_underlay(300, seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, NUM_AGENTS))
    cats = compute_categories(ov)
    ring = [(i, (i + 1) % NUM_AGENTS) for i in range(NUM_AGENTS)]
    demands = demands_from_links(ring, KAPPA, NUM_AGENTS)
    sol = route_congestion_aware(demands, cats, KAPPA, NUM_AGENTS)

    plain = _time_construction(ov, cats, sol, validate=False)
    validated = _time_construction(ov, cats, sol, validate=True)
    overhead = validated / plain if plain > 0 else float("inf")

    emit(
        "analysis_suite",
        lint_seconds * 1e6,
        f"lint_seconds={lint_seconds:.3f};"
        f"validate_seconds={validated:.3f}",
    )
    emit(
        "tracelint",
        tracelint_seconds * 1e6,
        f"tracelint_seconds={tracelint_seconds:.3f};" + ";".join(
            f"{key}={value}"
            for key, value in sorted(trace_metrics.items())
        ),
    )
    print(f"  lint suite ({', '.join(CHECKERS)}): {lint_seconds:.2f}s")
    print(
        f"  tracelint targets: {tracelint_seconds:.2f}s, "
        + ", ".join(
            f"{k}={v}" for k, v in sorted(trace_metrics.items())
        )
    )
    print(
        f"  {NUM_AGENTS}-agent CSR construction: {plain * 1e3:.1f}ms "
        f"plain vs {validated * 1e3:.1f}ms validated "
        f"({overhead:.2f}x)"
    )


if __name__ == "__main__":
    main()
