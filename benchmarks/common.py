"""Shared benchmark setup: the paper's evaluation scenario (§IV-A)."""

from __future__ import annotations

import datetime
import json
import os

from repro.core import ConvergenceConstants
from repro.net import (
    PAPER_MODEL_BYTES,
    build_overlay,
    compute_categories,
    lowest_degree_nodes,
    roofnet_like,
)

NUM_AGENTS = 10
KAPPA = PAPER_MODEL_BYTES  # ResNet-50 fp32, 94.47 MB (paper §IV-A1)
CONSTANTS = ConvergenceConstants(epsilon=0.05)


def paper_scenario(seed: int = 0):
    """Roofnet-statistics-matched underlay, 10 lowest-degree agents."""
    u = roofnet_like(seed=seed)
    ov = build_overlay(u, lowest_degree_nodes(u, NUM_AGENTS))
    cats = compute_categories(ov)
    return u, ov, cats


def emit(name: str, us_per_call: float, derived: str) -> None:
    """Benchmark output contract: name,us_per_call,derived CSV.

    When ``$BENCH_JSON`` names a file, the record is also appended there
    as one JSON line (name/us_per_call/derived/timestamp) — the nightly
    workflow uploads that file as an artifact so benchmark history is a
    tracked time series, not just a pass/fail floor.
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    path = os.environ.get("BENCH_JSON")
    if path:
        record = {
            "name": name,
            "us_per_call": us_per_call,
            "derived": derived,
            "timestamp": datetime.datetime.now(
                datetime.timezone.utc
            ).isoformat(),
        }
        with open(path, "a") as f:
            f.write(json.dumps(record) + "\n")
