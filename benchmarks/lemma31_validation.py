"""Lemma III.1/III.2 numeric validation: fluid-simulated makespan equals
the closed form max_e κ·t_e/C_e on random scenarios, for both max-min
(TCP-like) and static equal-share allocations."""

import time

import numpy as np

from repro.net import (
    build_overlay,
    compute_categories,
    demands_from_links,
    lemma31_time,
    random_geometric_underlay,
    route_direct,
    simulate,
)
from benchmarks.common import emit


def run(trials: int = 20) -> dict:
    rng = np.random.default_rng(0)
    max_rel_err = 0.0
    t0 = time.perf_counter()
    for trial in range(trials):
        u = random_geometric_underlay(14, radius=0.45, seed=trial)
        m = 5
        ov = build_overlay(u, list(u.graph.nodes)[:m])
        cats = compute_categories(ov)
        links = [
            (i, j) for i in range(m) for j in range(i + 1, m)
            if rng.random() < 0.5
        ] or [(0, 1)]
        demands = demands_from_links(links, 1e6, m)
        sol = route_direct(demands, cats, 1e6)
        closed = lemma31_time(sol, ov, 1e6)
        for fairness in ("maxmin", "equal"):
            sim = simulate(sol, ov, fairness=fairness)
            max_rel_err = max(
                max_rel_err, abs(sim.makespan - closed) / closed
            )
    return dict(trials=trials, max_rel_err=max_rel_err,
                seconds=time.perf_counter() - t0)


def main() -> None:
    r = run()
    emit(
        "lemma31_validation",
        1e6 * r["seconds"] / r["trials"],
        f"max_rel_err={r['max_rel_err']:.2e};trials={r['trials']}",
    )
    assert r["max_rel_err"] < 1e-6


if __name__ == "__main__":
    main()
