"""Table I: design + routing wall-clock per algorithm.

Paper's finding: FMMD is notably faster than SCA; the MILP (8) (category
form (12)) is much faster than the MICP (5) — we compare the exact MILP
against the congestion heuristic as the scalable stand-in.
"""

import time

import numpy as np

from benchmarks.common import CONSTANTS, KAPPA, NUM_AGENTS, emit, paper_scenario
from repro.core import design


def run() -> dict:
    ov_u, ov, cats = None, None, None
    _, ov, cats = paper_scenario()
    times = {}
    for method in ("sca", "fmmd-wp", "prim", "ring", "clique"):
        t0 = time.perf_counter()
        out = design(
            method, cats, KAPPA, NUM_AGENTS, overlay=ov,
            iterations=12, constants=CONSTANTS, optimize_routing=True,
        )
        times[method] = dict(
            total_s=time.perf_counter() - t0,
            design_s=out.design.design_seconds,
            route_s=out.routing.solve_seconds,
            route_method=out.routing.method,
            tau=out.tau,
            rho=out.rho,
        )
    return times


def main() -> None:
    times = run()
    emit(
        "table1_runtimes",
        1e6 * sum(v["total_s"] for v in times.values()) / len(times),
        f"fmmd_s={times['fmmd-wp']['total_s']:.2f};"
        f"sca_s={times['sca']['total_s']:.2f};"
        f"speedup={times['sca']['total_s']/max(times['fmmd-wp']['total_s'],1e-9):.1f}x",
    )
    for k, v in times.items():
        print(
            f"  {k:8s} total={v['total_s']:7.2f}s design={v['design_s']:7.2f}s "
            f"route={v['route_s']:6.2f}s ({v['route_method']}) "
            f"tau={v['tau']:8.1f}s rho={v['rho']:.4f}"
        )


if __name__ == "__main__":
    main()
