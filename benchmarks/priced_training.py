"""Nightly gate: ≥80% modeled training-time reduction, FMMD-P vs Clique.

Reproduces the paper's headline number at benchmark scale: on a
Roofnet-like instance (10 lowest-degree agents, 94MB model payload),
training over the FMMD-P designed overlay reaches the Clique baseline's
final loss in ≤20% of the modeled wall-clock — every gossip round
charged its simulated network τ through ``core.priced_training``
(same ``evaluate_design`` pricing path the designer uses).

One command emits the loss-vs-wall-clock curves for all five schemes
(Clique / ring / prim / FMMD-P / SCA) and enforces the gate:

    PYTHONPATH=src:. python benchmarks/priced_training.py

Exit is nonzero if the reduction drops below GATE_REDUCTION or the
final losses diverge by more than LOSS_TOL (the reduction is only
meaningful at equal training quality). ``time_reduction_ratio`` is the
trend-tracked headline (higher is better).
"""

import sys
import time

from benchmarks.common import emit
from benchmarks.fig5_training import run

GATE_REDUCTION = 0.80
LOSS_TOL = 0.02
STEPS = 120


def main() -> None:
    t0 = time.perf_counter()
    res = run(steps=STEPS)
    dt = time.perf_counter() - t0

    # Loss-vs-wall-clock curves (the Fig. 5 x-axis), from the per-round
    # charged log — replayable, not steps × one constant.
    for name, v in res.items():
        print(f"  curve[{name}] tau_model={v['tau_model']}")
        for rec in v["log"].records[:: max(1, STEPS // 6)]:
            print(
                f"    step={rec.step:4d} wall={rec.wall_clock/3600:8.2f}h "
                f"loss={rec.loss:.4f}"
            )

    base = res["clique"]
    fm = res["fmmd-wp"]
    loss_gap = abs(fm["final_loss"] - base["final_loss"])
    # Time for each scheme to reach the worse of the two final losses:
    # the equal-quality point the reduction is measured at.
    target = max(base["final_loss"], fm["final_loss"]) + 1e-9
    t_clique = min(base["log"].time_to_loss(target), base["time_to_final"])
    t_fmmd = min(fm["log"].time_to_loss(target), fm["time_to_final"])
    reduction = 1.0 - t_fmmd / max(t_clique, 1e-9)

    emit(
        "priced_training",
        1e6 * dt,
        f"time_reduction_ratio={reduction:.3f};"
        f"final_loss_gap={loss_gap:.4f};"
        f"t_clique_h={t_clique/3600:.1f};t_fmmd_h={t_fmmd/3600:.1f};"
        f"tau_model={fm['tau_model']}",
    )
    print(
        f"  FMMD-P reaches loss {target:.4f} in {t_fmmd/3600:.1f}h vs "
        f"Clique {t_clique/3600:.1f}h -> {100*reduction:.0f}% reduction "
        f"(gate >= {100*GATE_REDUCTION:.0f}%, loss gap {loss_gap:.4f} "
        f"<= {LOSS_TOL})"
    )
    if loss_gap > LOSS_TOL:
        print(f"  GATE FAIL: final losses diverge ({loss_gap:.4f})")
        sys.exit(1)
    if reduction < GATE_REDUCTION:
        print(f"  GATE FAIL: reduction {reduction:.3f} < {GATE_REDUCTION}")
        sys.exit(1)
    print("  GATE PASS")


if __name__ == "__main__":
    main()
