"""Phase-adaptive (time-expanded) routing gate.

Two checks on the paper's Roofnet-like scenario (§IV-A):

  1. *Degenerate case*: on a trivial scenario ``route_time_expanded``
     must return the static ``route()`` answer bitwise (same trees,
     same τ) — phase-adaptivity costs nothing when there are no phases.
  2. *Two-phase degradation*: mid-round, the middle edges of several
     ring links' default underlay paths degrade 20×. The static-optimal
     schedule keeps pushing traffic through them; the phase-adaptive
     schedule re-routes segment 2 around the degraded region (carrying
     each branch's remaining volume across the swap). Gate: the
     phase-adaptive schedule's simulated makespan is ≤ the
     static-optimal schedule's.

Also exercises the sweep-amortization path: per-phase solutions are
cached by (activated-link set, phase scale), so a second
``route_time_expanded`` over the same demands routes zero segments.
"""

import time

from repro.net import (
    CapacityPhase,
    Scenario,
    build_overlay,
    compute_categories,
    demands_from_links,
    lowest_degree_nodes,
    mid_path_edges,
    roofnet_like,
    route,
    route_time_expanded,
    simulate,
    simulate_phased,
)
from benchmarks.common import KAPPA, NUM_AGENTS, emit

DEGRADATION = 0.05  # 20x capacity drop on the degraded edges
BREAK_FRAC = 0.15   # phase boundary, as a fraction of the static tau


def make_instance():
    u = roofnet_like(seed=0)
    ov = build_overlay(u, lowest_degree_nodes(u, NUM_AGENTS))
    cats = compute_categories(ov)
    m = NUM_AGENTS
    links = sorted({(min(i, (i + 1) % m), max(i, (i + 1) % m))
                    for i in range(m)})
    demands = demands_from_links(links, KAPPA, m)
    return ov, cats, demands


def degradation_scenario(ov, static, links=5):
    """Degrade the middle edges of the first ``links`` ring links'
    default paths — the hops a re-routed overlay can actually avoid
    (unlike agent access edges, which every schedule must cross)."""
    drop = {
        e: DEGRADATION
        for e in mid_path_edges(ov, [(k, k + 1) for k in range(links)])
    }
    return Scenario(capacity_phases=(
        CapacityPhase(start=BREAK_FRAC * static.completion_time,
                      scale=drop),
    ))


def run() -> dict:
    ov, cats, demands = make_instance()
    m = NUM_AGENTS

    t0 = time.perf_counter()
    static = route(demands, cats, KAPPA, m, milp_var_budget=0, seed=0)
    t_static = time.perf_counter() - t0

    # 1. Trivial scenario: bitwise identity with static route().
    trivial = route_time_expanded(
        demands, cats, Scenario(), KAPPA, m, milp_var_budget=0, seed=0
    )
    assert trivial.num_segments == 1
    assert trivial.solutions[0].trees == static.trees, (
        "time-expanded routing on a trivial scenario must return the "
        "static trees bitwise"
    )
    assert trivial.solutions[0].completion_time == static.completion_time

    # 2. Two-phase degradation: phased makespan <= static makespan.
    scenario = degradation_scenario(ov, static)
    t0 = time.perf_counter()
    phased = route_time_expanded(
        demands, cats, scenario, KAPPA, m, milp_var_budget=0, seed=0
    )
    t_phased = time.perf_counter() - t0
    sim_static = simulate(static, ov, scenario=scenario)
    sim_phased = simulate_phased(phased, ov, scenario=scenario)
    assert sim_phased.makespan <= sim_static.makespan + 1e-9, (
        f"phase-adaptive schedule ({sim_phased.makespan:.1f}s) must not "
        f"lose to the static-optimal one ({sim_static.makespan:.1f}s)"
    )

    # 3. Sweep amortization: a second call over the same demands serves
    # every segment from the (activated-link set, phase) cache.
    cache: dict = {}
    key = frozenset((d.source, k) for d in demands for k in d.destinations)
    route_time_expanded(
        demands, cats, scenario, KAPPA, m, milp_var_budget=0, seed=0,
        routing_cache=cache, cache_key=key,
    )
    again = route_time_expanded(
        demands, cats, scenario, KAPPA, m, milp_var_budget=0, seed=0,
        routing_cache=cache, cache_key=key,
    )
    assert again.metadata["routed_segments"] == 0, (
        "cached sweep re-routed segments it should have reused"
    )

    return dict(
        t_static=t_static,
        t_phased=t_phased,
        tau_static=static.completion_time,
        makespan_static=sim_static.makespan,
        makespan_phased=sim_phased.makespan,
        speedup=sim_static.makespan / sim_phased.makespan,
        segments=phased.num_segments,
    )


def main() -> None:
    r = run()
    emit(
        "phase_routing",
        1e6 * r["t_phased"],
        f"makespan_static_s={r['makespan_static']:.1f};"
        f"makespan_phased_s={r['makespan_phased']:.1f};"
        f"win={r['speedup']:.2f}x;segments={r['segments']}",
    )


if __name__ == "__main__":
    main()
