"""Simulator scale microbenchmark: vectorized incidence-matrix engine vs.
the reference dict-loop engine at 100 agents / 1000+ branches.

The instance is a 300-node random-geometric edge network with
heterogeneous link capacities (0.3–3 Mbps) and a 100-agent overlay whose
mixing topology is a ring plus 2000 random chords — ~4000 unicast
branches under direct routing. Both engines must agree bitwise on the
makespan; the vectorized engine must be ≥20× faster.

A second, vectorized-only section scales to larger instances the
reference engine cannot touch, to document the new reachable regime.
"""

import time

import numpy as np

from repro.net import (
    build_overlay,
    compute_categories,
    demands_from_links,
    random_geometric_underlay,
    route_direct,
    simulate,
)
from benchmarks.common import emit

SPEEDUP_TARGET = 20.0


def make_instance(
    num_agents: int,
    extra_links: int,
    nodes: int = 300,
    radius: float = 0.10,
    seed: int = 3,
):
    """Heterogeneous-capacity geometric underlay + ring-and-chords overlay."""
    u = random_geometric_underlay(nodes, radius=radius, seed=seed)
    rng = np.random.default_rng(7)
    for _, _, data in u.graph.edges(data=True):
        data["capacity"] = 125_000.0 * rng.uniform(0.3, 3.0)
    ov = build_overlay(u, list(u.graph.nodes)[:num_agents])
    cats = compute_categories(ov)
    links = {
        (min(a, b), max(a, b))
        for a, b in ((i, (i + 1) % num_agents) for i in range(num_agents))
    }
    while len(links) < num_agents + extra_links:
        a, b = rng.choice(num_agents, 2, replace=False)
        links.add((min(a, b), max(a, b)))
    demands = demands_from_links(sorted(links), 1e6, num_agents)
    return route_direct(demands, cats, 1e6), ov


def run() -> dict:
    sol, ov = make_instance(num_agents=100, extra_links=2000)
    num_branches = sum(len(t) for t in sol.trees)

    t0 = time.perf_counter()
    vec = simulate(sol, ov, engine="vectorized")
    t_vec = time.perf_counter() - t0

    t0 = time.perf_counter()
    ref = simulate(sol, ov, engine="reference")
    t_ref = time.perf_counter() - t0

    assert vec.makespan == ref.makespan, (
        f"engines disagree: vectorized {vec.makespan!r} "
        f"!= reference {ref.makespan!r}"
    )
    assert vec.num_events == ref.num_events

    # Vectorized-only: a regime the reference engine cannot reach in
    # benchmark time (denser overlay, more branches).
    sol_big, ov_big = make_instance(num_agents=100, extra_links=3500)
    branches_big = sum(len(t) for t in sol_big.trees)
    t0 = time.perf_counter()
    simulate(sol_big, ov_big, engine="vectorized")
    t_big = time.perf_counter() - t0

    return dict(
        num_branches=num_branches,
        t_vectorized=t_vec,
        t_reference=t_ref,
        speedup=t_ref / t_vec,
        branches_big=branches_big,
        t_big=t_big,
    )


def main() -> None:
    r = run()
    emit(
        "sim_scale",
        1e6 * r["t_vectorized"],
        f"speedup={r['speedup']:.1f}x;branches={r['num_branches']};"
        f"big_branches={r['branches_big']};big_seconds={r['t_big']:.2f}",
    )
    assert r["speedup"] >= SPEEDUP_TARGET, (
        f"vectorized simulator only {r['speedup']:.1f}x faster "
        f"(target {SPEEDUP_TARGET:.0f}x)"
    )


if __name__ == "__main__":
    main()
