"""Benchmark harness: one function per paper table/figure (+ extensions).

Each prints a ``name,us_per_call,derived`` CSV line followed by detail
rows. Usage: PYTHONPATH=src python -m benchmarks.run [name ...]
"""

import sys


def main() -> None:
    from benchmarks import (
        analysis_bench,
        design_scale,
        design_service,
        engine_parity,
        fig4_fmmd_variants,
        fig5_training,
        gossip_traffic,
        lemma31_validation,
        phase_routing,
        priced_training,
        roofline_bench,
        rollout_scale,
        route_scale,
        sim_scale,
        stochastic_routing,
        table1_runtimes,
    )

    all_benches = {
        "fig4_fmmd_variants": fig4_fmmd_variants.main,
        "table1_runtimes": table1_runtimes.main,
        "fig5_training": fig5_training.main,
        "priced_training": priced_training.main,
        "lemma31_validation": lemma31_validation.main,
        "roofline_bench": roofline_bench.main,
        "gossip_traffic": gossip_traffic.main,
        "sim_scale": sim_scale.main,
        "route_scale": route_scale.main,
        "phase_routing": phase_routing.main,
        "stochastic_routing": stochastic_routing.main,
        "engine_parity": engine_parity.main,
        "rollout_scale": rollout_scale.main,
        "design_scale": design_scale.main,
        # argv pinned: harness arguments are bench names, not flags
        "design_service": lambda: design_service.main([]),
        "analysis_bench": analysis_bench.main,
    }
    names = sys.argv[1:] or list(all_benches)
    for name in names:
        all_benches[name]()


if __name__ == "__main__":
    main()
