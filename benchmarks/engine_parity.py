"""Large-instance engine parity: batched water-filling vs. one-bottleneck.

The ROADMAP required nightly evidence on instances well past the
property-test sizes (3–7 agents) before flipping the batched
water-filling engine to the default. This gate builds a 220-agent /
~9000-branch heterogeneous-capacity instance (the ``sim_scale``
construction, scaled up) and checks that the batched engine — which
freezes *all* tied bottlenecks per allocation round — matches the
one-bottleneck-per-round engine to rtol=1e-9 on the makespan and every
flow completion time, under both the static network and a degraded
scenario. With this gate green, ``simulate(engine="batched")`` became
the default (PR 4); ``engine="vectorized"`` replays the reference
tie-break order bitwise and ``engine="reference"`` remains the
pure-Python escape hatch.
"""

import time

import numpy as np

from repro.net import (
    CapacityPhase,
    Scenario,
    build_overlay,
    compute_categories,
    demands_from_links,
    random_geometric_underlay,
    route_direct,
    simulate,
)
from benchmarks.common import emit

NUM_AGENTS = 220
EXTRA_LINKS = 4000
RTOL = 1e-9


def make_instance(num_agents=NUM_AGENTS, extra_links=EXTRA_LINKS,
                  nodes=500, radius=0.08, seed=3):
    """Heterogeneous-capacity geometric underlay + ring-and-chords
    overlay (the ``sim_scale`` construction at 200+ agents)."""
    u = random_geometric_underlay(nodes, radius=radius, seed=seed)
    rng = np.random.default_rng(7)
    for _, _, data in u.graph.edges(data=True):
        data["capacity"] = 125_000.0 * rng.uniform(0.3, 3.0)
    ov = build_overlay(u, list(u.graph.nodes)[:num_agents])
    cats = compute_categories(ov)
    links = {
        (min(a, b), max(a, b))
        for a, b in ((i, (i + 1) % num_agents) for i in range(num_agents))
    }
    while len(links) < num_agents + extra_links:
        a, b = rng.choice(num_agents, 2, replace=False)
        links.add((min(a, b), max(a, b)))
    demands = demands_from_links(sorted(links), 1e6, num_agents)
    return route_direct(demands, cats, 1e6), ov


def _check(a, b, label):
    assert np.isclose(a.makespan, b.makespan, rtol=RTOL, atol=0.0), (
        f"{label}: makespans diverge beyond rtol={RTOL}: "
        f"batched={a.makespan!r} vectorized={b.makespan!r}"
    )
    assert np.allclose(
        a.flow_completion, b.flow_completion, rtol=RTOL, equal_nan=True
    ), f"{label}: flow completion times diverge beyond rtol={RTOL}"


def run() -> dict:
    sol, ov = make_instance()
    num_branches = sum(len(t) for t in sol.trees)

    t0 = time.perf_counter()
    bat = simulate(sol, ov, engine="batched")
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    vec = simulate(sol, ov, engine="vectorized")
    t_vectorized = time.perf_counter() - t0
    _check(bat, vec, "static network")

    # Same parity with moving bottlenecks (a mid-run uniform sag).
    sc = Scenario(capacity_phases=(
        CapacityPhase(start=0.25 * vec.makespan, scale=0.5),
    ))
    _check(
        simulate(sol, ov, scenario=sc, engine="batched"),
        simulate(sol, ov, scenario=sc, engine="vectorized"),
        "degraded scenario",
    )

    return dict(
        num_agents=NUM_AGENTS,
        num_branches=num_branches,
        t_batched=t_batched,
        t_vectorized=t_vectorized,
        speedup=t_vectorized / t_batched,
        rel_err=abs(bat.makespan - vec.makespan) / vec.makespan,
    )


def main() -> None:
    r = run()
    emit(
        "engine_parity",
        1e6 * r["t_batched"],
        f"agents={r['num_agents']};branches={r['num_branches']};"
        f"batched_speedup={r['speedup']:.2f}x;rel_err={r['rel_err']:.2e}",
    )


if __name__ == "__main__":
    main()
