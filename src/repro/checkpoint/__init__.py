"""Checkpoint/restore with atomic writes, retention, async saves."""

from repro.checkpoint.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
)
