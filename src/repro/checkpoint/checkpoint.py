"""Checkpoint/restore for fault-tolerant training.

Layout: <dir>/step_<N>/ with one .npz per top-level state key plus a
manifest (pytree structure + shapes + metadata). Writes go to a temp
directory and are atomically renamed, so a crash mid-save never corrupts
the latest checkpoint. ``AsyncCheckpointer`` runs saves on a background
thread (device→host transfer happens synchronously, serialization
asynchronously), and retention keeps the most recent K checkpoints.

Elastic restore: ``restore(..., num_agents=m)`` re-maps stacked-agent
state between different agent counts (new agents start from agent 0's
replica; dropped agents are discarded) — the checkpoint side of elastic
scaling (see repro.runtime.fault_tolerance for the mixing-matrix side).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(state: Any):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(directory: str, step: int, state: Any, keep: int = 3) -> str:
    """Synchronous atomic save; returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(state)
    arrays = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i}"] = arr
    np.savez(os.path.join(tmp, "state.npz"), **arrays)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "time": time.time(),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _apply_retention(directory, keep)
    return final


def _apply_retention(directory: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    example_state: Any,
    step: int | None = None,
    num_agents: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore (state, step). ``example_state`` provides the pytree
    structure; ``num_agents`` triggers elastic agent-axis re-mapping;
    ``shardings`` places leaves directly onto devices."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    data = np.load(os.path.join(path, "state.npz"))
    leaves, treedef = _flatten(example_state)
    loaded = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        ref_shape = tuple(np.asarray(jax.eval_shape(lambda: ref)).shape) \
            if not hasattr(ref, "shape") else tuple(ref.shape)
        if (
            num_agents is not None
            and arr.ndim >= 1
            and len(ref_shape) == arr.ndim
            and ref_shape[1:] == arr.shape[1:]
            and ref_shape[0] != arr.shape[0]
        ):
            arr = _remap_agents(arr, ref_shape[0])
        loaded.append(arr)
    state = jax.tree.unflatten(treedef, loaded)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step


def _remap_agents(arr: np.ndarray, new_m: int) -> np.ndarray:
    """Elastic agent-axis resize: shrink = truncate; grow = clone agent 0."""
    old_m = arr.shape[0]
    if new_m <= old_m:
        return arr[:new_m]
    extra = np.repeat(arr[:1], new_m - old_m, axis=0)
    return np.concatenate([arr, extra], axis=0)


class AsyncCheckpointer:
    """Non-blocking saves: device→host copy now, disk write in background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def worker():
            try:
                save(self.directory, step, host_state, keep=self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
