"""Benchmark topology designs (paper §IV-A3): Clique, Ring, Prim.

Each returns the activated link set; weights are then optimized via (14)
— the paper does the same for fair comparison ("we have used (14) to
optimize the link weights under each design").
"""

from __future__ import annotations

import time

import networkx as nx
import numpy as np

from repro.core import mixing
from repro.core.fmmd import FMMDResult
from repro.core.weight_opt import optimize_weights
from repro.net.topology import OverlayNetwork


def clique_links(m: int) -> tuple[tuple[int, int], ...]:
    """Activate all overlay links (the baseline the paper beats by >80%)."""
    return tuple((i, j) for i in range(m) for j in range(i + 1, m))


def ring_links(m: int) -> tuple[tuple[int, int], ...]:
    """Ring in agent-index order (common practice)."""
    return tuple(
        (min(i, (i + 1) % m), max(i, (i + 1) % m)) for i in range(m)
    )


def prim_links(overlay: OverlayNetwork) -> tuple[tuple[int, int], ...]:
    """Minimum spanning tree (Prim), proposed by Marfoq et al. [16].

    Edge weight = default-path transfer cost of the overlay link: hop
    count / bottleneck capacity of its underlay routing path (for uniform
    capacities this reduces to hop count, a proxy for contention).
    """
    m = overlay.num_agents
    g = nx.Graph()
    for i, j in overlay.overlay_links:
        edges = overlay.path_edges(i, j)
        bottleneck = min(overlay.underlay.capacity(*e) for e in edges)
        g.add_edge(i, j, weight=len(edges) / bottleneck)
    mst = nx.minimum_spanning_tree(g, algorithm="prim")
    return tuple(sorted((min(i, j), max(i, j)) for i, j in mst.edges))


def design_from_links(
    m: int,
    links,
    name: str,
) -> FMMDResult:
    """Wrap a fixed topology + (14)-optimized weights as a design result."""
    t0 = time.perf_counter()
    res = optimize_weights(m, links)
    return FMMDResult(
        matrix=res.matrix,
        activated_links=res.links,
        rho=res.rho,
        rho_trajectory=(res.rho,),
        selected_atoms=(),
        design_seconds=time.perf_counter() - t0,
        variant=name,
    )


def clique_design(m: int) -> FMMDResult:
    return design_from_links(m, clique_links(m), "Clique")


def ring_design(m: int) -> FMMDResult:
    return design_from_links(m, ring_links(m), "Ring")


def prim_design(overlay: OverlayNetwork) -> FMMDResult:
    return design_from_links(
        overlay.num_agents, prim_links(overlay), "Prim"
    )
