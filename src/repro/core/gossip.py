"""Distributed D-PSGD mixing as TPU collectives (hardware adaptation §4).

The mixing step x_i ← Σ_j W_ij x_j is realized three ways:

  * ``mix_dense``   — einsum with W over the stacked agent axis. GSPMD
    compiles this to all-gather + local contraction: the *Clique/J*
    communication pattern, O(m·κ) bytes per agent. Baseline.
  * ``mix_allreduce`` — exact mean over agents (only valid for W = J);
    compiles to a single all-reduce: what classic synchronous data
    parallelism does. Reference point for the roofline.
  * ``mix_sparse``  — a static schedule of ``ppermute`` rounds derived
    from W's sparsity (edge-coloring of the activated digraph): each
    agent only ships κ bytes per activated neighbor. This is the paper's
    payoff on the ICI fabric: collective bytes ∝ |E_a| instead of m².

The schedule is built once per designed W (it is a *hyperparameter*, like
the mixing matrix itself) and baked into the jitted step as constants.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


@dataclasses.dataclass(frozen=True)
class GossipSchedule:
    """Static ppermute schedule for a sparse mixing matrix.

    rounds[r]   — tuple of (src_agent, dst_agent) pairs; each agent
                  appears at most once as src and once as dst per round
                  (ppermute semantics: missing dsts receive zeros).
    weights[r]  — length-m vector; weights[r][dst] = W[dst, src] for the
                  edge delivered to dst in round r (0 if none).
    self_weight — length-m vector of W[a, a].
    """

    num_agents: int
    rounds: tuple[tuple[tuple[int, int], ...], ...]
    weights: tuple[tuple[float, ...], ...]
    self_weight: tuple[float, ...]


def build_schedule(w: np.ndarray, atol: float = 1e-12) -> GossipSchedule:
    """Greedy edge-coloring of the activated digraph into ppermute rounds."""
    w = np.asarray(w, dtype=np.float64)
    m = w.shape[0]
    edges = [
        (src, dst)
        for dst in range(m)
        for src in range(m)
        if src != dst and abs(w[dst, src]) > atol
    ]
    rounds: list[list[tuple[int, int]]] = []
    for e in edges:
        placed = False
        for r in rounds:
            if all(e[0] != f[0] and e[1] != f[1] for f in r):
                r.append(e)
                placed = True
                break
        if not placed:
            rounds.append([e])
    weights = []
    for r in rounds:
        vec = [0.0] * m
        for src, dst in r:
            vec[dst] = float(w[dst, src])
        weights.append(tuple(vec))
    return GossipSchedule(
        num_agents=m,
        rounds=tuple(tuple(r) for r in rounds),
        weights=tuple(weights),
        self_weight=tuple(float(w[a, a]) for a in range(m)),
    )


def mix_dense(params: Any, w: jnp.ndarray) -> Any:
    """x_i ← Σ_j W_ij x_j over the leading (stacked) agent axis."""
    return jax.tree.map(
        lambda p: jnp.einsum(
            "ab,b...->a...", w.astype(jnp.float32), p.astype(jnp.float32)
        ).astype(p.dtype),
        params,
    )


def mix_allreduce(params: Any) -> Any:
    """W = J: plain averaging (classic DP all-reduce)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(
            jnp.mean(p.astype(jnp.float32), axis=0, keepdims=True), p.shape
        ).astype(p.dtype),
        params,
    )


def mix_sparse_shardmap(
    params: Any,
    schedule: GossipSchedule,
    mesh: jax.sharding.Mesh,
    agent_axes: tuple[str, ...],
    param_specs: Any,
) -> Any:
    """Sparse mixing via a ppermute schedule inside shard_map.

    ``agent_axes`` are the mesh axes whose product forms the agent space
    (e.g. ("data",) single-pod, ("pod", "data") multi-pod agents-on-data,
    ("pod",) for pod-level agents). Each leaf of ``params`` must have the
    stacked agent dim 0 sharded over exactly ``agent_axes`` (size-1 local
    slice inside the shard_map body).

    Weight lookup is a gather from a tiny constant table indexed by the
    rank's agent id — numerically identical to the dense einsum on the
    activated support (validated in tests).
    """
    from jax.sharding import PartitionSpec as P

    m = schedule.num_agents
    axis_sizes = [mesh.shape[a] for a in agent_axes]
    if int(np.prod(axis_sizes)) != m:
        raise ValueError(
            f"agent axes {agent_axes} (={axis_sizes}) != num_agents {m}"
        )

    self_w = jnp.asarray(schedule.self_weight, jnp.float32)
    round_w = [jnp.asarray(w, jnp.float32) for w in schedule.weights]
    perms = [tuple(r) for r in schedule.rounds]

    def agent_id():
        idx = jnp.zeros((), jnp.int32)
        for a in agent_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def body(p):
        aid = agent_id()

        def mix_leaf(x):
            acc = x.astype(jnp.float32) * self_w[aid]
            for r, perm in enumerate(perms):
                recv = jax.lax.ppermute(x, agent_axes, perm)
                acc = acc + recv.astype(jnp.float32) * round_w[r][aid]
            return acc.astype(x.dtype)

        return jax.tree.map(mix_leaf, p)

    # in/out specs mirror the jit-level param specs (leaf dim0 on agents).
    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(param_specs,),
        out_specs=param_specs,
    )(params)


def mix_sparse_flat(
    params: Any,
    schedule: GossipSchedule,
    mesh: jax.sharding.Mesh,
    agent_axes: tuple[str, ...],
    slice_axes: tuple[str, ...] = ("model",),
) -> Any:
    """Sparse gossip for layouts whose params are REPLICATED over
    ``slice_axes`` (e.g. the data_dp layout: small models, no TP).

    Naively ppermuting replicated leaves would ship κ from every replica
    (|slice_axes|× redundant traffic). Instead the whole tree is raveled
    to one [A, N_pad] buffer sliced over ``slice_axes``: each replica
    ppermutes only its 1/|slice| slice, and the combined result is
    written back replicated (an all-gather of N/|slice| per chip —
    amortized across every leaf at once).
    """
    from jax.sharding import PartitionSpec as P

    leaves, treedef = jax.tree.flatten(params)
    slice_size = 1
    for a in slice_axes:
        slice_size *= mesh.shape[a]
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    a_dim = leaves[0].shape[0]
    total = sum(sizes)
    pad = (-total) % slice_size
    # Ship in the native dtype when uniform (bf16 halves gossip bytes);
    # the per-edge accumulation is fp32 either way (mix_leaf).
    dtypes = {l.dtype for l in leaves}
    wire_dtype = leaves[0].dtype if len(dtypes) == 1 else jnp.float32
    flat = jnp.concatenate(
        [l.reshape(a_dim, -1).astype(wire_dtype) for l in leaves], axis=1
    )
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    agent_spec = agent_axes if len(agent_axes) > 1 else agent_axes[0]
    slice_spec = slice_axes if len(slice_axes) > 1 else slice_axes[0]
    spec = P(agent_spec, slice_spec)
    flat = jax.lax.with_sharding_constraint(
        flat, jax.sharding.NamedSharding(mesh, spec)
    )
    mixed = mix_sparse_shardmap(flat, schedule, mesh, agent_axes, spec)
    mixed = jax.lax.with_sharding_constraint(
        mixed, jax.sharding.NamedSharding(mesh, P(agent_spec, None))
    )
    out, off = [], 0
    for l, n in zip(leaves, sizes):
        out.append(
            mixed[:, off : off + n].reshape(l.shape).astype(l.dtype)
        )
        off += n
    return jax.tree.unflatten(treedef, out)


def effective_mixing_matrix(w: np.ndarray, rounds: int = 1) -> np.ndarray:
    """W^rounds — the matrix one model update sees under multi-round
    graph gossip (``rounds`` back-to-back exchanges on the same overlay
    before the local step; arxiv 2506.10607). ρ(Wʳ − J) = ρ(W − J)ʳ, so
    extra rounds buy convergence speed at r× the per-update network
    price — ``priced_training.GossipStrategy`` charges exactly that.
    ``rounds=1`` returns the float64 view of ``w`` (one-shot mixing).
    """
    if rounds < 1:
        raise ValueError(f"gossip rounds must be >= 1: {rounds}")
    w = np.asarray(w, dtype=np.float64)
    return np.linalg.matrix_power(w, rounds) if rounds > 1 else w


def gossip_collective_bytes(
    schedule: GossipSchedule, kappa_bytes: float, gossip_rounds: int = 1
) -> float:
    """Modeled per-iteration gossip traffic (all agents, both directions).

    Each directed activated edge ships κ bytes; compare with clique
    all-gather: m·(m−1)·κ. ``gossip_rounds`` scales the figure for a
    multi-round strategy (the ppermute schedule replays per round).
    """
    if gossip_rounds < 1:
        raise ValueError(f"gossip rounds must be >= 1: {gossip_rounds}")
    return (
        kappa_bytes * sum(len(r) for r in schedule.rounds) * gossip_rounds
    )
