"""Paper core: mixing-matrix design, D-PSGD, joint designer."""

from repro.core.designer import DesignOutcome, design, evaluate_design, sweep_iterations
from repro.core.dpsgd import (
    consensus_distance,
    feddyn_init,
    make_dpsgd_step,
    make_feddyn_step,
    mix_params,
    replicate_for_agents,
    train,
)
from repro.core.priced_training import (
    GossipStrategy,
    PhasedTau,
    PricedTrainLog,
    RoundRecord,
    StaticTau,
    StochasticTau,
    pricer_for,
    train_priced,
)
from repro.core.fmmd import FMMDResult, fmmd, fmmd_wp, theorem35_bound
from repro.core.mixing import (
    ConvergenceConstants,
    ideal_matrix,
    incidence_matrix,
    iterations_to_converge,
    matrix_from_weights,
    rho,
    rho_gradient,
    swapping_matrix,
    total_time,
    validate_mixing,
    weights_from_matrix,
)
from repro.core.sca import sca_design
from repro.core.topology_baselines import (
    clique_design,
    clique_links,
    prim_design,
    prim_links,
    ring_design,
    ring_links,
)
from repro.core.weight_opt import WeightOptResult, optimize_weights
