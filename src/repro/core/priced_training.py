"""Network-priced DFL training — the loop the paper's Fig. 5 draws.

``train_priced`` drives the D-PSGD step (``make_dpsgd_step`` /
``make_feddyn_step``) with the designer's mixing matrix while charging
every gossip round its *simulated* network time, so loss-vs-wall-clock
curves come out of the designed overlay instead of a hand-picked
constant. Three pricing models, one per simulation entry point:

  * ``StaticTau``    — every round costs the design's routed τ
    (``DesignOutcome.tau``): the paper's static-network assumption.
  * ``PhasedTau``    — round k starts at the accumulated wall-clock t_k
    and costs ``simulate(sol, overlay, scenario.shifted(t_k))``: the
    deterministic time-varying price, exact against the same fluid
    model ``evaluate_design(scenario=...)`` uses (memoized by shifted-
    scenario signature — rounds inside one phase re-price for free).
  * ``StochasticTau`` — per-round τ from a Monte-Carlo rollout batch
    (mean, p95, or per-round sample); with ``engine="jax"`` the whole
    batch prices as one XLA launch against the ``DeviceIncidence``
    cached per activated-link set (the PR-8 engine), and an outcome
    already priced stochastically donates its ``tau_samples`` for free.

The communication strategy is pluggable (``GossipStrategy``): one-shot
mixing applies W once per model update; multi-round graph gossip
(arxiv 2506.10607) applies W r times — effective matrix Wʳ, r network
rounds charged per update. Heterogeneity-robust local updates ride in
the step function (``prox_mu`` / ``make_feddyn_step``), orthogonal to
pricing.

Every charged round lands in a replayable ``PricedTrainLog``
(JSON-round-trippable; ``validate()`` asserts the charged wall-clock is
bitwise the running sum of per-round τ), and ``train_priced`` accepts
mid-run redesigns — the fault-tolerance path swaps (W, pricer) on a
named round and the log shows the τ source switch.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Mapping, MutableMapping, Sequence

import numpy as np

from repro.core.dpsgd import consensus_distance
from repro.core.gossip import effective_mixing_matrix
from repro.net.simulator import Scenario, SimResult, compile_incidence, simulate


# ---------------------------------------------------------------------------
# Gossip strategy plug point
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GossipStrategy:
    """How one model update's communication is realized atop W.

    ``rounds=1`` is one-shot mixing (plain D-PSGD). ``rounds=r`` is
    multi-round graph gossip: r back-to-back exchanges per update, so
    the update mixes with Wʳ — ρ contracts r× faster per update — while
    the pricer charges r network rounds, each at its own simulated τ
    (under phased pricing consecutive gossip rounds of one update can
    land in different capacity phases). The strategy only changes *how
    often* the priced exchange runs, never its price: both variants are
    priced over the same designed topology.
    """

    rounds: int = 1
    label: str = ""

    def __post_init__(self):
        if self.rounds < 1:
            raise ValueError(f"gossip rounds must be >= 1: {self.rounds}")

    @property
    def name(self) -> str:
        if self.label:
            return self.label
        return "one-shot" if self.rounds == 1 else f"gossip-x{self.rounds}"

    def effective_matrix(self, w: np.ndarray) -> np.ndarray:
        return effective_mixing_matrix(w, self.rounds)


# ---------------------------------------------------------------------------
# Per-round τ pricers
# ---------------------------------------------------------------------------


def _finite_tau(sim: SimResult) -> float:
    """Price one simulated round, mirroring ``evaluate_design``: a
    truncated run or one where churn cancelled every flow outright
    (all-NaN completions) prices as inf, never as cheap/free."""
    undelivered = sim.cancelled_branches > 0 and all(
        np.isnan(c) for c in sim.flow_completion
    )
    return float(
        np.inf if sim.unfinished_branches or undelivered else sim.makespan
    )


def _scenario_signature(sc: Scenario):
    """Hashable identity of a scenario's conditions (per-edge scale
    maps sorted), for memoizing per-round simulations."""

    def scale_key(s):
        if isinstance(s, Mapping):
            return tuple(sorted(s.items()))
        return s

    return (
        tuple((p.start, scale_key(p.scale)) for p in sc.capacity_phases),
        tuple(
            (c.src, c.dst, c.rate, c.start, c.stop)
            for c in sc.cross_traffic
        ),
        tuple(
            (s.agent, s.slowdown, s.start, s.stop) for s in sc.stragglers
        ),
        tuple((c.agent, c.time) for c in sc.churn),
        sc.floor_frac,
    )


@dataclasses.dataclass(frozen=True)
class StaticTau:
    """Constant per-round price — the design's routed τ."""

    tau: float
    label: str = "static"

    @property
    def kind(self) -> str:
        return "static"

    def tau_for(self, round_index: int, t_start: float) -> float:
        return float(self.tau)

    @classmethod
    def from_outcome(cls, outcome, label: str = "") -> "StaticTau":
        return cls(outcome.tau, label=label or outcome.name)


class PhasedTau:
    """Deterministic time-varying price: round k costs the simulated
    makespan under ``scenario.shifted(t_k)`` where t_k is the round's
    wall-clock start — the same fluid model as
    ``evaluate_design(scenario=...)``, applied per round instead of
    once. The branch incidence compiles once; simulations memoize on
    the shifted scenario's signature, so every round inside one
    capacity phase after the last breakpoint reuses a single simulate.
    """

    def __init__(
        self,
        sol,
        overlay,
        scenario: Scenario,
        engine: str = "batched",
        label: str = "",
    ):
        if scenario is None:
            raise ValueError(
                "PhasedTau needs the deterministic scenario it prices; "
                "use StaticTau for a static network"
            )
        self.sol = sol
        self.overlay = overlay
        self.scenario = scenario
        self.engine = engine
        self.label = label or "phased"
        self._incidence = (
            compile_incidence(sol, overlay) if sol.demands else None
        )
        self._memo: dict = {}

    @property
    def kind(self) -> str:
        return "phased"

    def tau_for(self, round_index: int, t_start: float) -> float:
        if self._incidence is None:
            return 0.0
        shifted = self.scenario.shifted(float(t_start))
        key = _scenario_signature(shifted)
        tau = self._memo.get(key)
        if tau is None:
            tau = _finite_tau(
                simulate(
                    self.sol, self.overlay,
                    scenario=None if shifted.is_trivial else shifted,
                    engine=self.engine, incidence=self._incidence,
                )
            )
            self._memo[key] = tau
        return tau

    @classmethod
    def from_outcome(
        cls, outcome, overlay, scenario: Scenario,
        engine: str = "batched", label: str = "",
    ) -> "PhasedTau":
        return cls(
            outcome.routing, overlay, scenario, engine=engine,
            label=label or outcome.name,
        )


def _device_incidence_for(
    sol, overlay, activated_links, routing_cache: MutableMapping | None
):
    """The ``DeviceIncidence`` for a routed design, cached under the
    same ``("jax-device-incidence", activated-link set)`` key
    ``evaluate_design`` uses — share a ``routing_cache`` and the
    incidence compiles exactly once per design. Pulled out of
    ``StochasticTau.price`` so the trace-lint registry
    (``repro.analysis.tracelint_targets``) certifies the pricing batch
    path through the very same cache/compile code the pricer runs."""
    from repro.net import jax_engine

    dev_key = ("jax-device-incidence", frozenset(activated_links))
    dev = (
        routing_cache.get(dev_key)
        if routing_cache is not None else None
    )
    if dev is None:
        binc = compile_incidence(sol, overlay)
        flow_size = np.array(
            [d.size for d in sol.demands], dtype=np.float64
        )
        dev = jax_engine.device_incidence(binc, flow_size)
        if routing_cache is not None:
            routing_cache[dev_key] = dev
    return dev


@dataclasses.dataclass(frozen=True)
class StochasticTau:
    """Per-round price from a Monte-Carlo τ sample set.

    ``reduce="mean"``/``"p95"`` charge every round the expectation /
    tail of the rollout batch (risk-neutral vs conservative budgeting);
    ``reduce="sample"`` charges round k the k-th sample (cycling), so a
    training run experiences the *distribution* — per-round τ varies,
    replayable because the samples are seeded. Build via
    ``from_outcome`` (an outcome already priced with ``stochastic=``
    donates its ``tau_samples``) or ``price`` (one jax-engine rollout
    batch, reusing the designer's ``DeviceIncidence`` cache key).
    """

    samples: tuple[float, ...]
    reduce: str = "mean"
    label: str = "stochastic"

    def __post_init__(self):
        if not self.samples:
            raise ValueError("StochasticTau needs at least one τ sample")
        if self.reduce not in ("mean", "p95", "sample"):
            raise ValueError(
                f"unknown reduce {self.reduce!r}: valid reductions are "
                "'mean', 'p95', and 'sample'"
            )

    @property
    def kind(self) -> str:
        return f"stochastic-{self.reduce}"

    @property
    def tau_mean(self) -> float:
        return float(np.mean(self.samples))

    @property
    def tau_p95(self) -> float:
        return float(np.percentile(self.samples, 95.0))

    def tau_for(self, round_index: int, t_start: float) -> float:
        if self.reduce == "mean":
            return self.tau_mean
        if self.reduce == "p95":
            return self.tau_p95
        return float(self.samples[round_index % len(self.samples)])

    @classmethod
    def from_outcome(
        cls, outcome, reduce: str = "mean", label: str = ""
    ) -> "StochasticTau":
        if not outcome.tau_samples:
            raise ValueError(
                "outcome carries no tau_samples; price it with "
                "stochastic= (evaluate_design) or use StochasticTau.price"
            )
        return cls(
            samples=outcome.tau_samples, reduce=reduce,
            label=label or outcome.name,
        )

    @classmethod
    def price(
        cls,
        outcome,
        overlay,
        stochastic,
        rollouts: int = 256,
        seed: int = 0,
        engine: str = "jax",
        reduce: str = "mean",
        routing_cache: MutableMapping | None = None,
        label: str = "",
    ) -> "StochasticTau":
        """Price the outcome's routed schedule over ``rollouts`` seeded
        realizations. ``engine="jax"`` runs them as one XLA launch
        against a ``DeviceIncidence`` cached under the same
        ``("jax-device-incidence", activated-link set)`` key
        ``evaluate_design`` uses — share its ``routing_cache`` and the
        incidence compiles exactly once per design."""
        sol = outcome.routing
        if not sol.demands:
            return cls(samples=(0.0,), reduce=reduce, label=label)
        if engine == "jax":
            from repro.net import jax_engine

            dev = _device_incidence_for(
                sol, overlay, outcome.design.activated_links,
                routing_cache,
            )
            batch = stochastic.realization_batch(seed, rollouts, dev.source)
            sims = jax_engine.rollout_batch_results(sol, dev, batch)
        else:
            sims = [
                simulate(
                    sol, overlay, scenario=realization, engine=engine
                )
                for realization in stochastic.sample_many(seed, rollouts)
            ]
        return cls(
            samples=tuple(_finite_tau(s) for s in sims),
            reduce=reduce,
            label=label or outcome.name,
        )


def pricer_for(
    outcome,
    mode: str = "static",
    overlay=None,
    scenario: Scenario | None = None,
    stochastic=None,
    rollouts: int = 256,
    seed: int = 0,
    engine: str = "batched",
    reduce: str = "mean",
    routing_cache: MutableMapping | None = None,
):
    """One pricer per pricing mode, from a ``DesignOutcome``.

    mode="static"      → ``StaticTau`` at ``outcome.tau`` (which is
                         already scenario- or expectation-priced when
                         the outcome was).
    mode="phased"      → ``PhasedTau`` over ``scenario`` (requires
                         ``overlay``; any numpy/jax simulate engine).
    mode="stochastic"  → ``StochasticTau``: reuses ``outcome.tau_samples``
                         when present and ``stochastic`` is None, else
                         prices a fresh rollout batch (``engine="jax"``
                         for the one-launch path).
    """
    if mode == "static":
        return StaticTau.from_outcome(outcome)
    if mode == "phased":
        if overlay is None or scenario is None:
            raise ValueError("phased pricing needs overlay= and scenario=")
        return PhasedTau.from_outcome(
            outcome, overlay, scenario, engine=engine
        )
    if mode == "stochastic":
        if stochastic is None:
            return StochasticTau.from_outcome(outcome, reduce=reduce)
        if overlay is None:
            raise ValueError("stochastic pricing needs overlay=")
        return StochasticTau.price(
            outcome, overlay, stochastic, rollouts=rollouts, seed=seed,
            engine=engine, reduce=reduce, routing_cache=routing_cache,
        )
    raise ValueError(
        f"unknown pricing mode {mode!r}: valid modes are 'static', "
        "'phased', and 'stochastic'"
    )


# ---------------------------------------------------------------------------
# The priced training loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundRecord:
    """One training step's charge: which design's τ, how much, when."""

    step: int
    design: str          # label of the design whose τ was charged
    pricing: str         # pricer kind ("static" | "phased" | ...)
    gossip_rounds: int   # network rounds this step (strategy.rounds)
    tau: float           # network seconds charged for this step
    wall_clock: float    # cumulative modeled wall-clock AFTER this step
    loss: float
    consensus: float = float("nan")  # logged every log_every steps


@dataclasses.dataclass
class PricedTrainLog:
    """Replayable per-round τ accounting of one priced training run.

    ``records`` has one entry per training step. The charged wall-clock
    is the exact running float sum of per-step τ (``validate()`` holds
    it bitwise), so a log replays to the same loss-vs-wall-clock curve
    it was recorded from — ``to_json``/``from_json`` round-trip every
    field through ``repr`` floats (exact for binary64).
    """

    records: list[RoundRecord] = dataclasses.field(default_factory=list)

    @property
    def steps(self) -> list[int]:
        return [r.step for r in self.records]

    @property
    def losses(self) -> list[float]:
        return [r.loss for r in self.records]

    @property
    def wall_clock(self) -> list[float]:
        return [r.wall_clock for r in self.records]

    @property
    def total_wall(self) -> float:
        return self.records[-1].wall_clock if self.records else 0.0

    def validate(self) -> None:
        """Charged wall-clock ≡ running sum of per-step τ, bitwise."""
        wall = 0.0
        for r in self.records:
            wall += r.tau
            if r.wall_clock != wall and not (
                np.isnan(r.wall_clock) and np.isnan(wall)
            ):
                raise ValueError(
                    f"step {r.step}: wall_clock {r.wall_clock!r} != "
                    f"running τ sum {wall!r}"
                )

    def time_to_loss(self, target: float) -> float:
        """Modeled wall-clock at which the loss first reaches
        ``target`` (inf if it never does) — the Fig. 5 x-axis read."""
        for r in self.records:
            if r.loss <= target:
                return r.wall_clock
        return float("inf")

    def to_json(self) -> str:
        return json.dumps(
            {"records": [dataclasses.asdict(r) for r in self.records]}
        )

    @classmethod
    def from_json(cls, text: str) -> "PricedTrainLog":
        data = json.loads(text)
        return cls(
            records=[RoundRecord(**r) for r in data["records"]]
        )


def train_priced(
    params: Any,
    step_fn: Callable,
    batcher: Callable[[int], Any],
    w: np.ndarray,
    pricer,
    num_steps: int,
    strategy: GossipStrategy = GossipStrategy(),
    design_label: str = "design",
    redesigns: Mapping[int, tuple[str, np.ndarray, Any]] | None = None,
    intervene: Callable[[int, Any], tuple[Any, tuple | None]] | None = None,
    log_every: int = 10,
    extract_params: Callable[[Any], Any] | None = None,
    compute_time_per_step: float = 0.0,
) -> tuple[Any, PricedTrainLog]:
    """D-PSGD training charged per gossip round by a network pricer.

    Per training step: (1) apply any scheduled redesign or intervention,
    (2) run ``step_fn(carry, batch, w_eff, k)`` where ``w_eff`` is the
    strategy's effective matrix (Wʳ for multi-round gossip), (3) charge
    ``strategy.rounds`` network rounds, each priced by
    ``pricer.tau_for(global_round_index, wall_clock_at_round_start)``
    — so under phased pricing every gossip round sees the capacity
    phase actually active when it starts — plus
    ``compute_time_per_step`` (0 by default: D-PSGD overlaps compute
    with the exchange, eq. (2), and the paper's axis is
    communication-bound).

    ``redesigns`` maps step index → ``(label, new_w, new_pricer)``: at
    the *start* of that step the mixing matrix and pricer swap, so the
    step's rounds charge the new design's τ (the mid-run redesign
    contract, tested bitwise). ``intervene(k, carry)`` is the dynamic
    variant for fault-tolerance flows — it may shrink the carry (agent
    failure) and return a redesign tuple, or ``(carry, None)``.

    ``extract_params`` maps the step carry to the stacked params pytree
    for consensus logging (identity by default; ``lambda c: c[0]`` for
    ``make_feddyn_step``'s ``(params, h)`` carry).
    """
    import jax.numpy as jnp

    if num_steps < 0:
        raise ValueError(f"num_steps must be nonnegative: {num_steps}")
    redesigns = dict(redesigns or {})
    extract = extract_params or (lambda c: c)
    w_eff = jnp.asarray(strategy.effective_matrix(w))
    log = PricedTrainLog()
    wall = 0.0
    gossip_round = 0
    for k in range(num_steps):
        switch = redesigns.pop(k, None)
        if intervene is not None:
            params, dyn_switch = intervene(k, params)
            if dyn_switch is not None:
                switch = dyn_switch
        if switch is not None:
            design_label, new_w, pricer = switch
            w_eff = jnp.asarray(strategy.effective_matrix(new_w))
        batch = batcher(k)
        params, loss = step_fn(params, batch, w_eff, jnp.asarray(k))
        tau_step = 0.0
        for _ in range(strategy.rounds):
            tau_step += float(
                pricer.tau_for(gossip_round, wall + tau_step)
            )
            gossip_round += 1
        tau_step += compute_time_per_step
        wall += tau_step
        consensus = (
            float(consensus_distance(extract(params)))
            if log_every and (k % log_every == 0 or k == num_steps - 1)
            else float("nan")
        )
        log.records.append(
            RoundRecord(
                step=k,
                design=design_label,
                pricing=pricer.kind,
                gossip_rounds=strategy.rounds,
                tau=tau_step,
                wall_clock=wall,
                loss=float(loss),
                consensus=consensus,
            )
        )
    return params, log
