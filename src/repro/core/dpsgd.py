"""D-PSGD (Lian et al. [1]) — decentralized parallel SGD in JAX.

Update rule (paper eq. (2)), which lets every agent overlap its gradient
computation with the parameter exchange:

    x_i^(k+1) = Σ_j W_ij x_j^(k) − η g(x_i^(k); ξ_i^(k)).

Simulation mode (this module): all m agents live on one host as a stacked
pytree with leading axis m; mixing is an einsum with W. Distributed mode
(repro.core.gossip): agents are blocks of the mesh's data axis and mixing
becomes a schedule of collective-permutes derived from W's sparsity.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def mix_params(params: Any, w: jnp.ndarray) -> Any:
    """Σ_j W_ij x_j per agent: dense mixing over the leading agent axis."""
    return jax.tree.map(
        lambda p: jnp.einsum(
            "ab,b...->a...", w.astype(p.dtype), p,
            precision=jax.lax.Precision.HIGHEST,
        ),
        params,
    )


def make_dpsgd_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 0.1,
    mix_first: bool = False,
    prox_mu: float = 0.0,
) -> Callable:
    """Build a jitted D-PSGD step.

    loss_fn(params_i, batch_i) -> scalar loss for ONE agent.

    mix_first=False implements eq. (2) (exchange ∥ compute overlap);
    mix_first=True implements the equivalent rule x_i ← Σ_j W_ij (x_j − ηg_j)
    — same convergence per [1], exposed for testing both forms.

    prox_mu > 0 adds a FedProx-style proximal term adapted to gossip:
    each agent's gradient is corrected by μ(x_i − Σ_j W_ij x_j), pulling
    the local update toward the *neighborhood average* it just received
    instead of a (nonexistent) server model. Under non-IID data the
    correction damps client drift between exchanges — steady-state
    consensus distance shrinks with μ while the fixed point of the
    averaged dynamics is unchanged (the correction sums to ~0 across
    agents for doubly-stochastic W). μ = 0 recovers plain D-PSGD
    bitwise.
    """

    def lr_at(step):
        if callable(learning_rate):
            return learning_rate(step)
        return jnp.asarray(learning_rate)

    @jax.jit
    def step_fn(params: Any, batch: Any, w: jnp.ndarray, step: jnp.ndarray):
        loss, grads = jax.vmap(jax.value_and_grad(loss_fn))(params, batch)
        eta = lr_at(step)
        if mix_first:
            if prox_mu:
                anchor = mix_params(params, w)
                grads = jax.tree.map(
                    lambda g, p, a: g + prox_mu * (p - a),
                    grads, params, anchor,
                )
            local = jax.tree.map(lambda p, g: p - eta * g, params, grads)
            new_params = mix_params(local, w)
        else:
            mixed = mix_params(params, w)
            if prox_mu:
                grads = jax.tree.map(
                    lambda g, p, a: g + prox_mu * (p - a),
                    grads, params, mixed,
                )
            new_params = jax.tree.map(lambda p, g: p - eta * g, mixed, grads)
        return new_params, jnp.mean(loss)

    return step_fn


def feddyn_init(params: Any) -> Any:
    """Zero-initialized per-agent dynamic-regularization state for
    ``make_feddyn_step`` (same stacked pytree shape as ``params``)."""
    return jax.tree.map(jnp.zeros_like, params)


def make_feddyn_step(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 0.1,
    alpha: float = 0.01,
) -> Callable:
    """FedDyn-style dynamic regularization adapted to gossip.

    Each agent carries a corrective state h_i (initialized by
    ``feddyn_init``) that accumulates its historical drift from the
    neighborhood anchor a_i = Σ_j W_ij x_j:

        x_i ← a_i − η (g_i − h_i + α (x_i − a_i))
        h_i ← h_i − α (x_i⁺ − a_i)

    Over time h_i absorbs the persistent non-IID gradient bias, so the
    per-agent fixed points line up without the bias↔penalty tradeoff a
    static proximal term makes (FedDyn's dynamic-regularizer argument,
    transplanted from the server setting to the mixing anchor — see
    arxiv 2511.03284 for the decentralized treatment). The state is
    strictly local: only x is gossiped, so the network price per round
    is identical to plain D-PSGD's.

    The returned step has signature ``step_fn((params, h), batch, w,
    step) -> ((params, h), loss)`` — thread it through
    ``priced_training.train_priced`` with ``extract_params=lambda c:
    c[0]``.
    """

    def lr_at(step):
        if callable(learning_rate):
            return learning_rate(step)
        return jnp.asarray(learning_rate)

    @jax.jit
    def step_fn(carry: Any, batch: Any, w: jnp.ndarray, step: jnp.ndarray):
        params, h = carry
        loss, grads = jax.vmap(jax.value_and_grad(loss_fn))(params, batch)
        eta = lr_at(step)
        anchor = mix_params(params, w)
        new_params = jax.tree.map(
            lambda a, g, hh, p: a - eta * (g - hh + alpha * (p - a)),
            anchor, grads, h, params,
        )
        new_h = jax.tree.map(
            lambda hh, x, a: hh - alpha * (x - a), h, new_params, anchor
        )
        return (new_params, new_h), jnp.mean(loss)

    return step_fn


def consensus_distance(params: Any) -> jnp.ndarray:
    """‖x_i − x̄‖² averaged over agents — the disagreement D-PSGD drives down."""
    def per_leaf(p):
        mean = jnp.mean(p, axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)

    leaves = [per_leaf(p) for p in jax.tree.leaves(params)]
    m = jax.tree.leaves(params)[0].shape[0]
    return sum(leaves) / m


def replicate_for_agents(params: Any, m: int) -> Any:
    """Stack identical initial parameters for m agents (standard init)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), params
    )


@dataclasses.dataclass
class TrainLog:
    steps: list
    losses: list
    consensus: list
    wall_time: list  # modeled wall-clock (Σ per-iteration τ)


def train(
    params: Any,
    step_fn: Callable,
    batcher: Callable[[int], Any],
    w: np.ndarray,
    num_steps: int,
    tau_per_iteration: float = 0.0,
    log_every: int = 10,
) -> tuple[Any, TrainLog]:
    """Simulation-mode D-PSGD training loop with modeled wall-clock time."""
    w = jnp.asarray(w)
    log = TrainLog([], [], [], [])
    for k in range(num_steps):
        batch = batcher(k)
        params, loss = step_fn(params, batch, w, jnp.asarray(k))
        if k % log_every == 0 or k == num_steps - 1:
            log.steps.append(k)
            log.losses.append(float(loss))
            log.consensus.append(float(consensus_distance(params)))
            log.wall_time.append((k + 1) * tau_per_iteration)
    return params, log
