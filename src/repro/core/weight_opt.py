"""Link-weight optimization on a fixed support (paper eq. (14)).

    min_α ρ  s.t.  −ρI ⪯ I − B diag(α) Bᵀ − J ⪯ ρI,   α_ij = 0 ∀(i,j) ∉ E_a

This is an SDP; with no SDP solver offline we minimize the (convex,
nonsmooth) spectral norm directly by smoothed spectral minimization in
JAX: ρ_β(A) = logsumexp(β·|λ(A)|)/β ↓ ρ(A) as β ↑. We anneal β and finish
with the exact ρ. Validated against analytic optima (clique ⇒ W = J,
ring ⇒ known cosine spectrum) in tests.

The same machinery, with an optional reweighted-ℓ1 penalty, powers the
SCA baseline (repro.core.sca).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing


def _matrix_from_alpha(
    alpha: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray, m: int
) -> jnp.ndarray:
    """Differentiable W(α) = I − B diag(α) Bᵀ on the given support."""
    w = jnp.eye(m)
    w = w.at[rows, cols].add(alpha)
    w = w.at[cols, rows].add(alpha)
    w = w.at[rows, rows].add(-alpha)
    w = w.at[cols, cols].add(-alpha)
    return w


def _smoothed_rho(
    alpha: jnp.ndarray,
    rows: jnp.ndarray,
    cols: jnp.ndarray,
    m: int,
    beta: float,
    l1: jnp.ndarray | float = 0.0,
) -> jnp.ndarray:
    w = _matrix_from_alpha(alpha, rows, cols, m)
    a = w - jnp.full((m, m), 1.0 / m, dtype=w.dtype)
    eigs = jnp.linalg.eigvalsh(a)
    both = jnp.concatenate([eigs, -eigs])  # |λ| via max(λ, −λ) smoothing
    smooth = jax.nn.logsumexp(beta * both) / beta
    return smooth + jnp.sum(jnp.asarray(l1) * jnp.abs(alpha))


@dataclasses.dataclass(frozen=True)
class WeightOptResult:
    matrix: np.ndarray
    alpha: np.ndarray
    links: tuple[tuple[int, int], ...]
    rho: float
    iterations: int


def optimize_weights(
    m: int,
    links: Sequence[tuple[int, int]],
    init_alpha: Sequence[float] | None = None,
    steps: int = 800,
    betas: Sequence[float] = (40.0, 160.0, 640.0, 2560.0),
    lr: float = 0.05,
    l1: np.ndarray | float = 0.0,
    seed: int = 0,
) -> WeightOptResult:
    """Solve (14): best symmetric row-stochastic W supported on ``links``.

    Adam on the β-smoothed spectral norm with annealed β. ``l1`` adds a
    (re)weighted-ℓ1 penalty used by the SCA baseline; 0 reproduces (14).
    """
    links = tuple((min(i, j), max(i, j)) for i, j in links)
    if len(set(links)) != len(links):
        raise ValueError("duplicate links in support")
    if not links:
        return WeightOptResult(
            matrix=np.eye(m), alpha=np.zeros(0), links=(), rho=mixing.rho(np.eye(m)),
            iterations=0,
        )
    rows = jnp.array([i for i, _ in links])
    cols = jnp.array([j for _, j in links])
    if init_alpha is None:
        # Degree-normalized local-averaging start (always a valid W).
        deg = np.zeros(m)
        for i, j in links:
            deg[i] += 1
            deg[j] += 1
        a0 = np.array([1.0 / (max(deg[i], deg[j]) + 1.0) for i, j in links])
    else:
        a0 = np.asarray(init_alpha, dtype=np.float64)

    @partial(jax.jit, static_argnames=("beta",))
    def step(alpha, mom, vel, t, beta):
        val, g = jax.value_and_grad(_smoothed_rho)(
            alpha, rows, cols, m, beta, l1
        )
        mom = 0.9 * mom + 0.1 * g
        vel = 0.999 * vel + 0.001 * g * g
        mhat = mom / (1.0 - 0.9 ** t)
        vhat = vel / (1.0 - 0.999 ** t)
        alpha = alpha - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        return alpha, mom, vel, val

    alpha = jnp.asarray(a0)
    mom = jnp.zeros_like(alpha)
    vel = jnp.zeros_like(alpha)
    best_alpha, best_rho = np.asarray(alpha), np.inf
    t = 0
    per_phase = max(1, steps // len(tuple(betas)))
    for beta in betas:
        for _ in range(per_phase):
            t += 1
            alpha, mom, vel, _ = step(alpha, mom, vel, float(t), float(beta))
        cand = np.asarray(alpha)
        r = mixing.rho(mixing.matrix_from_weights(m, links, cand))
        if r < best_rho:
            best_rho, best_alpha = r, cand

    # Polish 1: uniform-weight golden-section search (never lose to the
    # best uniform design; exact for symmetric supports like ring/clique).
    if np.isscalar(l1) and float(l1) == 0.0:
        lo_, hi_ = 0.0, 1.0
        invphi = (np.sqrt(5.0) - 1.0) / 2.0
        f = lambda a: mixing.rho(
            mixing.matrix_from_weights(m, links, np.full(len(links), a))
        )
        c_, d_ = hi_ - invphi * (hi_ - lo_), lo_ + invphi * (hi_ - lo_)
        fc, fd = f(c_), f(d_)
        for _ in range(60):
            if fc < fd:
                hi_, d_, fd = d_, c_, fc
                c_ = hi_ - invphi * (hi_ - lo_)
                fc = f(c_)
            else:
                lo_, c_, fc = c_, d_, fd
                d_ = lo_ + invphi * (hi_ - lo_)
                fd = f(d_)
        a_u = (lo_ + hi_) / 2.0
        if f(a_u) < best_rho:
            best_rho = f(a_u)
            best_alpha = np.full(len(links), a_u)
        # Polish 2: restart Adam from the uniform optimum at high β.
        alpha = jnp.asarray(np.full(len(links), a_u))
        mom = jnp.zeros_like(alpha)
        vel = jnp.zeros_like(alpha)
        t2 = 0
        for _ in range(per_phase):
            t2 += 1
            alpha, mom, vel, _ = step(
                alpha, mom, vel, float(t2), float(betas[-1])
            )
        cand = np.asarray(alpha)
        r = mixing.rho(mixing.matrix_from_weights(m, links, cand))
        if r < best_rho:
            best_rho, best_alpha = r, cand

    w = mixing.matrix_from_weights(m, links, best_alpha)
    mixing.validate_mixing(w)
    return WeightOptResult(
        matrix=w,
        alpha=best_alpha,
        links=links,
        rho=best_rho,
        iterations=t,
    )
