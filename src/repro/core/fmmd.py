"""Frank-Wolfe Mixing Matrix Design — FMMD and variants (paper Alg. 1).

Minimizes ρ(W) = ‖W − J‖ over conv(S⁺), the convex hull of the swapping
matrices plus identity (Lemma III.4): after T Frank-Wolfe iterations the
solution combines ≤ T atoms, hence activates ≤ T overlay links, which
bounds the per-iteration communication time (Theorem III.5):

    τ(W^(T)) · K(ρ(W^(T))) ≤ (κT/C_min) · K((m−3)/m + 16/(T+2)).

Variants (paper §III-B2, "Further Improvements"):
  * FMMD-W  — re-optimize the weights on the selected support via (14).
  * FMMD-P  — restrict the atom search (19) to unselected atoms that
    minimize the default-path time bound τ̄ (22)-(23).
  * FMMD-WP — both (the paper's headline algorithm).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import mixing
from repro.core.weight_opt import optimize_weights
from repro.net.categories import (
    Categories,
    CategoryIncidence,
    compile_category_incidence,
)


@dataclasses.dataclass(frozen=True)
class FMMDResult:
    matrix: np.ndarray
    activated_links: tuple[tuple[int, int], ...]
    rho: float
    rho_trajectory: tuple[float, ...]
    selected_atoms: tuple[tuple[int, int] | None, ...]  # None = identity atom
    design_seconds: float
    variant: str


def _tau_bar(
    links: frozenset,
    categories: Categories,
    kappa: float,
    incidence: CategoryIncidence | None = None,
) -> float:
    """τ̄(W) of eq. (22): completion time under default-path routing.

    ``links`` holds undirected activated links; each contributes both
    directed unicast flows (i→j and j→i) to its categories. With a
    matching precompiled ``incidence`` the t_F loads come from CSR
    slices instead of the O(Σ_F |F|) family iteration — bitwise equal
    (integer loads are exact in either summation order, and the
    κ·t_F/C_F max uses the same per-element arithmetic).
    """
    uses = {}
    for (i, j) in links:
        uses[(i, j)] = 1
        uses[(j, i)] = 1
    if (
        incidence is not None
        and incidence.kappa == kappa
        and incidence.matches(categories)
    ):
        return incidence.completion_time(incidence.loads_from_uses(uses))
    return categories.completion_time(uses, kappa)


def _csr_gather(
    ptr: np.ndarray, data: np.ndarray, ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate ``data[ptr[id]:ptr[id+1]]`` for every id (a multi-slice
    gather without a Python loop), plus the owning position per entry."""
    starts = ptr[ids]
    lens = ptr[ids + 1] - starts
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=data.dtype), np.empty(0, dtype=np.int64)
    cum = np.concatenate(([0], np.cumsum(lens)[:-1]))
    pos = np.arange(total) + np.repeat(starts - cum, lens)
    owner = np.repeat(np.arange(ids.size), lens)
    return data[pos], owner


class _PriorityState:
    """Incremental category loads for the FMMD-P atom filter (eq. 23).

    The reference filter rebuilt the τ̄ link-uses dict per atom per
    Frank-Wolfe iteration — O(|atoms| · Σ_F |F|) in Python, the designer
    bottleneck at 100+ agents. Here the atom→category incidence (δ_F per
    atom, counting both directed links) is flattened once, the selected
    loads t_F live in a numpy array updated on atom selection, and each
    iteration's candidate τ̄ table is

        τ̄(sel ∪ {a}) = max(max_F κ·t_F/C_F,  max_{F ∋ a} κ·(t_F+δ)/C_F),

    exact because adding an atom can only raise the loads of the
    categories it touches. The per-element arithmetic matches
    ``Categories.completion_time`` bit for bit, so the candidate set —
    down to the reference's 1e-15 tie margin — is unchanged.

    The per-atom maxima are maintained *incrementally*: loads only ever
    grow (atoms are only selected, never dropped), so every entry's
    κ·(t_F+δ)/C_F is nondecreasing and a running elementwise max over
    re-evaluations of just the categories a selection touched equals
    the full recomputation — making each Frank-Wolfe iteration's filter
    O(1) Python (one vector max against the current τ̄) instead of a
    ``maximum.at`` scatter over every (atom, category) pair per step.
    """

    def __init__(
        self,
        atoms,
        m: int,
        categories: Categories,
        kappa: float,
        incidence: CategoryIncidence | None = None,
    ):
        if incidence is not None and (
            incidence.num_agents != m
            or incidence.kappa != kappa
            or not incidence.matches(categories)
        ):
            raise ValueError("incidence does not match (categories, m, κ)")
        inc = (
            incidence
            if incidence is not None
            else compile_category_incidence(categories, m, kappa)
        )
        self.kappa = kappa
        self.cap = inc.capacity
        self.num_categories = inc.num_categories
        self.loads = np.zeros(inc.num_categories)
        self._inc = inc
        self._m = m
        atoms_arr = np.asarray(
            [(i, j) for i, j in atoms], dtype=np.int64
        ).reshape(-1, 2)
        self._num_atoms = atoms_arr.shape[0]
        ai, aj = atoms_arr[:, 0], atoms_arr[:, 1]
        cats_f, own_f = _csr_gather(inc.link_ptr, inc.entry_cat, ai * m + aj)
        cats_r, own_r = _csr_gather(inc.link_ptr, inc.entry_cat, aj * m + ai)
        nf = max(inc.num_categories, 1)
        key = (
            np.concatenate([own_f, own_r]) * nf
            + np.concatenate([cats_f, cats_r])
        )
        ukey, counts = np.unique(key, return_counts=True)
        self.entry_atom = ukey // nf  # atom position per (atom, cat) pair
        self.entry_cat = ukey % nf
        self.entry_delta = counts.astype(np.float64)  # δ ∈ {1, 2}
        # Category-major CSR over the (atom, cat) entries, so a selection
        # can re-evaluate exactly the entries of the categories whose
        # loads it changed.
        order = np.argsort(self.entry_cat, kind="stable")
        self._entries_by_cat = order
        self._cat_ptr = np.concatenate(
            (
                np.zeros(1, dtype=np.int64),
                np.cumsum(
                    np.bincount(
                        self.entry_cat, minlength=self.num_categories
                    ),
                    dtype=np.int64,
                ),
            )
        )
        # Running per-atom max of κ·(t_F+δ)/C_F (−inf for category-free
        # atoms, like the reference table's fill value).
        self._rebuild_atom_max()

    def _rebuild_atom_max(self) -> None:
        self._atom_max = np.full(self._num_atoms, -np.inf)
        if self.entry_atom.size:
            np.maximum.at(
                self._atom_max, self.entry_atom,
                self.kappa
                * (self.loads[self.entry_cat] + self.entry_delta)
                / self.cap[self.entry_cat],
            )

    def reset(
        self, incidence: CategoryIncidence | None = None
    ) -> "_PriorityState":
        """Warm-start for a fresh FMMD run, optionally rebinding to a
        capacity-only rescale/patch of the compiled incidence.

        The atom→category entry arrays are capacity-independent (family
        structure is pinned by routing paths), so after a
        ``LinkStateChange`` the service loop reuses them verbatim: only
        ``cap`` is swapped, the selected loads zeroed, and the per-atom
        maxima rebuilt with the same vector op ``__init__`` uses — the
        expensive CSR gather + unique over every (atom, category) pair
        is skipped. Bitwise-identical to constructing a cold state from
        the patched incidence (property-tested). Returns ``self``.
        """
        if incidence is not None:
            if (
                incidence.num_agents != self._m
                or incidence.num_categories != self.num_categories
                or incidence.kappa != self.kappa
            ):
                raise ValueError(
                    "reset incidence must be a capacity-only rescale of "
                    "the compiled structure (same m, #categories, κ)"
                )
            self.cap = incidence.capacity
            self._inc = incidence
        self.loads = np.zeros(self.num_categories)
        self._rebuild_atom_max()
        return self

    def select(self, atom: tuple[int, int]) -> None:
        """Account (i, j) and (j, i) loads for a newly selected atom."""
        i, j = atom
        inc, m = self._inc, self._m
        cats_f = inc.link_categories(i * m + j)
        cats_r = inc.link_categories(j * m + i)
        self.loads[cats_f] += 1.0
        self.loads[cats_r] += 1.0
        touched = np.unique(np.concatenate((cats_f, cats_r)))
        if not touched.size or not self.entry_atom.size:
            return
        pos, _ = _csr_gather(self._cat_ptr, self._entries_by_cat, touched)
        if pos.size:
            cats = self.entry_cat[pos]
            np.maximum.at(
                self._atom_max, self.entry_atom[pos],
                self.kappa
                * (self.loads[cats] + self.entry_delta[pos])
                / self.cap[cats],
            )

    def current_tau(self) -> float:
        if not self.num_categories:
            return 0.0
        return float(np.max(self.kappa * self.loads / self.cap))

    def candidate_taus(self, num_atoms: int) -> np.ndarray:
        """τ̄ of the tentative iterate per atom, as one vector op."""
        if num_atoms != self._num_atoms:
            raise ValueError(
                f"state was built for {self._num_atoms} atoms, "
                f"got {num_atoms}"
            )
        return np.maximum(self._atom_max, self.current_tau())


def fmmd(
    m: int,
    iterations: int,
    categories: Categories | None = None,
    kappa: float = 1.0,
    weight_opt: bool = False,
    priority: bool = False,
    allowed_links: Sequence[tuple[int, int]] | None = None,
    incidence: CategoryIncidence | None = None,
    warm_state: "_PriorityState | None" = None,
) -> FMMDResult:
    """Run FMMD (Alg. 1) with optional -W / -P improvements.

    ``allowed_links`` restricts the atom set for non-fully-connected
    overlays (paper footnote 1). ``categories``/``kappa`` are required
    when ``priority=True`` (the τ̄ bound needs network knowledge);
    ``incidence`` (a matching precompiled ``CategoryIncidence``) skips
    the priority filter's category compilation, e.g. across a sweep.
    ``warm_state`` (a ``_PriorityState`` the caller already ``reset()``)
    skips the priority filter's atom→category flattening entirely — the
    incremental-redesign path: after a capacity-only network change the
    service loop rebinds the incumbent state to the patched incidence
    and re-runs the design with zero structural setup. The caller owns
    the contract that the state was built for the SAME atom list, m,
    and κ (atom count and m are checked; atom identity cannot be
    cheaply verified).
    """
    if priority and categories is None:
        raise ValueError("FMMD-P needs categories (τ̄ bound)")
    t0 = time.perf_counter()

    if allowed_links is None:
        atoms = [(i, j) for i in range(m) for j in range(i + 1, m)]
    else:
        atoms = [tuple(sorted(l)) for l in allowed_links]

    w = np.eye(m)  # W^(0) = I (an atom in S⁺)
    selected: list[tuple[int, int] | None] = []
    selected_links: set[tuple[int, int]] = set()
    trajectory: list[float] = [mixing.rho(w)]

    num_atoms = len(atoms)
    atoms_ij = np.asarray(atoms, dtype=np.int64).reshape(-1, 2)
    ai, aj = atoms_ij[:, 0], atoms_ij[:, 1]
    prio = None
    if priority:
        if warm_state is not None:
            if warm_state._num_atoms != num_atoms or warm_state._m != m:
                raise ValueError(
                    f"warm_state was built for {warm_state._num_atoms} "
                    f"atoms at m={warm_state._m}, this run has "
                    f"{num_atoms} atoms at m={m}"
                )
            if warm_state.kappa != kappa:
                raise ValueError("warm_state κ does not match")
            prio = warm_state
        else:
            prio = _PriorityState(
                atoms, m, categories, kappa, incidence=incidence
            )
    # Persistent unselected-atom mask, flipped on selection — replaces
    # the per-iteration O(|atoms|) ``np.fromiter`` set-membership
    # rebuild. ``atoms`` may contain duplicate values (caller-supplied
    # ``allowed_links``): every position of a selected value flips.
    unsel_mask = np.ones(num_atoms, dtype=bool)
    atom_positions: dict[tuple[int, int], list[int]] = {}
    for q, a in enumerate(atoms):
        atom_positions.setdefault(a, []).append(q)

    for k in range(iterations):
        rho_k, grad = mixing.rho_and_gradient(w)  # eq. (18), one eigh
        if k > 0:
            trajectory.append(rho_k)  # ρ(W^(k)) from the same factoring
        gamma = 2.0 / (k + 2.0)

        # Inner products <S, ∇ρ> for all atoms (eq. 19), vectorized:
        #   <I, G> = tr(G);  <S^(i,j), G> = tr(G) − (G_ii + G_jj − 2 G_ij).
        tr = float(np.trace(grad))
        diag = np.diagonal(grad)
        scores = tr - ((diag[ai] + diag[aj]) - 2.0 * grad[ai, aj])

        cand_mask = None
        if priority:
            # (23): among UNSELECTED atoms, keep only those minimizing the
            # τ̄ of the tentative iterate. The identity atom constructs
            # W^(0), so it is in S(W^(k)) from the start and is excluded —
            # otherwise it would always win (it never increases τ̄) and the
            # algorithm would stall.
            if unsel_mask.any():
                taus = np.where(
                    unsel_mask, prio.candidate_taus(num_atoms), np.inf
                )
                cand_mask = unsel_mask & (taus <= taus.min() + 1e-15)
            # else: every link already activated → full search incl. I

        if cand_mask is not None:
            atom = atoms[int(np.argmin(np.where(cand_mask, scores, np.inf)))]
        elif num_atoms and tr > scores.min():
            atom = atoms[int(np.argmin(scores))]
        else:  # identity first in candidate order: wins score ties
            atom = None
        mixing.fw_step(w, gamma, atom)  # W ← (1−γ)W + γS, in place
        selected.append(atom)
        if atom is not None and atom not in selected_links:
            selected_links.add(atom)
            for q in atom_positions[atom]:
                unsel_mask[q] = False
            if prio is not None:
                prio.select(atom)
    rho_final = mixing.rho(w) if iterations > 0 else trajectory[0]
    if iterations > 0:
        trajectory.append(rho_final)  # ρ(W^(T)), reused for the result

    links = tuple(sorted(selected_links))
    variant = "FMMD" + ("-W" if weight_opt else "") + ("-P" if priority else "")
    if weight_opt and links:
        res = optimize_weights(m, links)
        w = res.matrix
        # weight optimization may zero out some links; recompute support
        links_w, _ = mixing.weights_from_matrix(w)
        links = tuple(links_w)
        rho_final = mixing.rho(w)  # weight opt rewrote the iterate
    mixing.validate_mixing(w)
    return FMMDResult(
        matrix=w,
        activated_links=links,
        rho=rho_final,
        rho_trajectory=tuple(trajectory),
        selected_atoms=tuple(selected),
        design_seconds=time.perf_counter() - t0,
        variant=variant.replace("-W-P", "-WP"),
    )


def fmmd_wp(
    m: int,
    iterations: int,
    categories: Categories,
    kappa: float,
    allowed_links: Sequence[tuple[int, int]] | None = None,
    incidence: CategoryIncidence | None = None,
) -> FMMDResult:
    """FMMD-WP — the paper's best-performing variant."""
    return fmmd(
        m,
        iterations,
        categories=categories,
        kappa=kappa,
        weight_opt=True,
        priority=True,
        allowed_links=allowed_links,
        incidence=incidence,
    )


def theorem35_bound(
    m: int,
    iterations: int,
    c_min: float,
    kappa: float,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
) -> float:
    """Right-hand side of the Theorem III.5 guarantee (eq. 20)."""
    if m <= 3 or iterations <= 16 * m / 3 - 2:
        raise ValueError("bound requires m > 3 and T > 16m/3 − 2")
    rho_bound = (m - 3.0) / m + 16.0 / (iterations + 2.0)
    return (kappa * iterations / c_min) * mixing.iterations_to_converge(
        rho_bound, m, constants
    )
