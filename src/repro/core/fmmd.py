"""Frank-Wolfe Mixing Matrix Design — FMMD and variants (paper Alg. 1).

Minimizes ρ(W) = ‖W − J‖ over conv(S⁺), the convex hull of the swapping
matrices plus identity (Lemma III.4): after T Frank-Wolfe iterations the
solution combines ≤ T atoms, hence activates ≤ T overlay links, which
bounds the per-iteration communication time (Theorem III.5):

    τ(W^(T)) · K(ρ(W^(T))) ≤ (κT/C_min) · K((m−3)/m + 16/(T+2)).

Variants (paper §III-B2, "Further Improvements"):
  * FMMD-W  — re-optimize the weights on the selected support via (14).
  * FMMD-P  — restrict the atom search (19) to unselected atoms that
    minimize the default-path time bound τ̄ (22)-(23).
  * FMMD-WP — both (the paper's headline algorithm).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import mixing
from repro.core.weight_opt import optimize_weights
from repro.net.categories import Categories


@dataclasses.dataclass(frozen=True)
class FMMDResult:
    matrix: np.ndarray
    activated_links: tuple[tuple[int, int], ...]
    rho: float
    rho_trajectory: tuple[float, ...]
    selected_atoms: tuple[tuple[int, int] | None, ...]  # None = identity atom
    design_seconds: float
    variant: str


def _tau_bar(
    links: frozenset, categories: Categories, kappa: float
) -> float:
    """τ̄(W) of eq. (22): completion time under default-path routing.

    ``links`` holds undirected activated links; each contributes both
    directed unicast flows (i→j and j→i) to its categories.
    """
    uses = {}
    for (i, j) in links:
        uses[(i, j)] = 1
        uses[(j, i)] = 1
    return categories.completion_time(uses, kappa)


def fmmd(
    m: int,
    iterations: int,
    categories: Categories | None = None,
    kappa: float = 1.0,
    weight_opt: bool = False,
    priority: bool = False,
    allowed_links: Sequence[tuple[int, int]] | None = None,
) -> FMMDResult:
    """Run FMMD (Alg. 1) with optional -W / -P improvements.

    ``allowed_links`` restricts the atom set for non-fully-connected
    overlays (paper footnote 1). ``categories``/``kappa`` are required
    when ``priority=True`` (the τ̄ bound needs network knowledge).
    """
    if priority and categories is None:
        raise ValueError("FMMD-P needs categories (τ̄ bound)")
    t0 = time.perf_counter()

    if allowed_links is None:
        atoms = [(i, j) for i in range(m) for j in range(i + 1, m)]
    else:
        atoms = [tuple(sorted(l)) for l in allowed_links]

    w = np.eye(m)  # W^(0) = I (an atom in S⁺)
    selected: list[tuple[int, int] | None] = []
    selected_links: set[tuple[int, int]] = set()
    trajectory: list[float] = [mixing.rho(w)]

    for k in range(iterations):
        grad = mixing.rho_gradient(w)  # eq. (18)
        gamma = 2.0 / (k + 2.0)

        # Inner products <S, ∇ρ> for all atoms (eq. 19):
        #   <I, G> = tr(G);  <S^(i,j), G> = tr(G) − (G_ii + G_jj − 2 G_ij).
        tr = float(np.trace(grad))
        scores = {None: tr}
        for (i, j) in atoms:
            scores[(i, j)] = tr - (grad[i, i] + grad[j, j] - 2.0 * grad[i, j])

        if priority:
            # (23): among UNSELECTED atoms, keep only those minimizing the
            # τ̄ of the tentative iterate. The identity atom constructs
            # W^(0), so it is in S(W^(k)) from the start and is excluded —
            # otherwise it would always win (it never increases τ̄) and the
            # algorithm would stall.
            unselected = [a for a in atoms if a not in selected_links]
            if unselected:
                taus = {
                    a: _tau_bar(
                        frozenset(selected_links | {a}), categories, kappa
                    )
                    for a in unselected
                }
                best_tau = min(taus.values())
                candidates = [
                    a for a, t in taus.items() if t <= best_tau + 1e-15
                ]
            else:  # every link already activated: fall back to full search
                candidates = [None] + atoms
        else:
            candidates = [None] + atoms

        atom = min(candidates, key=lambda a: scores[a])
        s = (
            np.eye(m)
            if atom is None
            else mixing.swapping_matrix(m, atom[0], atom[1])
        )
        w = (1.0 - gamma) * w + gamma * s
        selected.append(atom)
        if atom is not None:
            selected_links.add(atom)
        trajectory.append(mixing.rho(w))

    links = tuple(sorted(selected_links))
    variant = "FMMD" + ("-W" if weight_opt else "") + ("-P" if priority else "")
    if weight_opt and links:
        res = optimize_weights(m, links)
        w = res.matrix
        # weight optimization may zero out some links; recompute support
        links_w, _ = mixing.weights_from_matrix(w)
        links = tuple(links_w)
    mixing.validate_mixing(w)
    return FMMDResult(
        matrix=w,
        activated_links=links,
        rho=mixing.rho(w),
        rho_trajectory=tuple(trajectory),
        selected_atoms=tuple(selected),
        design_seconds=time.perf_counter() - t0,
        variant=variant.replace("-W-P", "-WP"),
    )


def fmmd_wp(
    m: int,
    iterations: int,
    categories: Categories,
    kappa: float,
    allowed_links: Sequence[tuple[int, int]] | None = None,
) -> FMMDResult:
    """FMMD-WP — the paper's best-performing variant."""
    return fmmd(
        m,
        iterations,
        categories=categories,
        kappa=kappa,
        weight_opt=True,
        priority=True,
        allowed_links=allowed_links,
    )


def theorem35_bound(
    m: int,
    iterations: int,
    c_min: float,
    kappa: float,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
) -> float:
    """Right-hand side of the Theorem III.5 guarantee (eq. 20)."""
    if m <= 3 or iterations <= 16 * m / 3 - 2:
        raise ValueError("bound requires m > 3 and T > 16m/3 − 2")
    rho_bound = (m - 3.0) / m + 16.0 / (iterations + 2.0)
    return (kappa * iterations / c_min) * mixing.iterations_to_converge(
        rho_bound, m, constants
    )
