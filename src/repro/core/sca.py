"""SCA baseline — successive convex approximation topology design.

Reconstruction of the state-of-the-art heuristic from [18] (Huang, Sun,
He, MobiHoc'24), which this paper's FMMD matches in training performance
at lower design cost. [18] sparsifies the mixing matrix by successively
solving convex approximations of the ℓ0-regularized spectral objective.

We implement the standard reweighted-ℓ1 SCA scheme: iterate

    α^(t+1) = argmin_α  ρ_β(W(α)) + λ Σ_ij  |α_ij| / (|α^(t)_ij| + δ)

(each subproblem convex in α given the weights — solved by the same
smoothed spectral machinery as (14)), pruning links whose weight falls
below tolerance. λ sweeps a sparsity frontier; the design minimizing the
estimated total time τ̄(W)·K(ρ(W)) is returned — the same objective (15)
FMMD targets.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import mixing
from repro.core.fmmd import FMMDResult, _tau_bar
from repro.core.weight_opt import optimize_weights
from repro.net.categories import Categories


def sca_design(
    m: int,
    categories: Categories,
    kappa: float,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    lambdas: tuple[float, ...] = (0.05, 0.15, 0.4, 1.0, 2.5),
    sca_rounds: int = 3,
    prune_tol: float = 5e-3,
    opt_steps: int = 300,
) -> FMMDResult:
    """Run the SCA sparsification sweep and pick the best total-time design."""
    t0 = time.perf_counter()
    all_links = [(i, j) for i in range(m) for j in range(i + 1, m)]

    best: tuple[float, FMMDResult] | None = None
    for lam in lambdas:
        links = list(all_links)
        alpha = None
        for _ in range(sca_rounds):
            if not links:
                break
            # Reweighted-ℓ1 coefficients from the previous iterate.
            if alpha is None:
                weights = np.full(len(links), lam)
            else:
                weights = lam / (np.abs(alpha) + 1e-2)
            res = optimize_weights(
                m, links, init_alpha=alpha, l1=weights, steps=opt_steps
            )
            # Prune near-zero links (the SCA sparsification step).
            keep = [
                (l, a)
                for l, a in zip(res.links, res.alpha)
                if abs(a) > prune_tol
            ]
            if not keep:
                links, alpha = [], None
                break
            links = [l for l, _ in keep]
            alpha = np.array([a for _, a in keep])
        if not links:
            continue
        # Final clean weight optimization on the chosen support (14).
        res = optimize_weights(m, links, steps=opt_steps)
        links_nz, _ = mixing.weights_from_matrix(res.matrix)
        tau = _tau_bar(frozenset(links_nz), categories, kappa)
        total = mixing.total_time(tau, res.rho, m, constants)
        cand = FMMDResult(
            matrix=res.matrix,
            activated_links=tuple(links_nz),
            rho=res.rho,
            rho_trajectory=(res.rho,),
            selected_atoms=(),
            design_seconds=0.0,
            variant="SCA",
        )
        if best is None or total < best[0]:
            best = (total, cand)

    if best is None:
        raise RuntimeError("SCA produced no feasible design")
    result = best[1]
    return FMMDResult(
        matrix=result.matrix,
        activated_links=result.activated_links,
        rho=result.rho,
        rho_trajectory=result.rho_trajectory,
        selected_atoms=(),
        design_seconds=time.perf_counter() - t0,
        variant="SCA",
    )
