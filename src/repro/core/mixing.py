"""Mixing-matrix algebra (paper §II-D, §III-B).

A valid D-PSGD mixing matrix W is symmetric with every row/column summing
to one (doubly-stochasticity of values in [0,1] is NOT required by the
adopted convergence bound — paper footnote 2). Every such W decomposes as

    W = I − B diag(α) Bᵀ                                  (3)
      = (1 − Σ α_ij) I + Σ α_ij S^(i,j)                   (16, Lemma III.4)

with B the overlay incidence matrix and S^(i,j) the swapping matrices.
The convergence-controlling parameter is ρ(W) = ‖W − J‖ (Theorem III.3);
iterations to ε-stationarity scale as K(ρ) of eq. (13).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np


def ideal_matrix(m: int) -> np.ndarray:
    """J = 𝟙𝟙ᵀ/m — one-shot full averaging."""
    return np.full((m, m), 1.0 / m)


def swapping_matrix(m: int, i: int, j: int) -> np.ndarray:
    """S^(i,j): identity with rows/cols i,j swapped — activates link (i,j)."""
    s = np.eye(m)
    s[i, i] = s[j, j] = 0.0
    s[i, j] = s[j, i] = 1.0
    return s


def incidence_matrix(m: int, links: Sequence[tuple[int, int]]) -> np.ndarray:
    """|V|×|E| oriented incidence matrix B (orientation arbitrary)."""
    b = np.zeros((m, len(links)))
    for e, (i, j) in enumerate(links):
        b[i, e] = 1.0
        b[j, e] = -1.0
    return b


def matrix_from_weights(
    m: int, links: Sequence[tuple[int, int]], alpha: Sequence[float]
) -> np.ndarray:
    """W = I − B diag(α) Bᵀ (eq. 3); W_ij = α_ij off-diagonal."""
    alpha = np.asarray(alpha, dtype=np.float64)
    if len(alpha) != len(links):
        raise ValueError("alpha/links length mismatch")
    w = np.eye(m)
    for (i, j), a in zip(links, alpha):
        w[i, j] = w[j, i] = a
        w[i, i] -= a
        w[j, j] -= a
    return w


def weights_from_matrix(w: np.ndarray) -> tuple[list[tuple[int, int]], np.ndarray]:
    """Inverse of ``matrix_from_weights`` on the nonzero support."""
    m = w.shape[0]
    links, alpha = [], []
    for i in range(m):
        for j in range(i + 1, m):
            if abs(w[i, j]) > 1e-12:
                links.append((i, j))
                alpha.append(w[i, j])
    return links, np.asarray(alpha)


def validate_mixing(w: np.ndarray, atol: float = 1e-8) -> None:
    """Check symmetry and unit row/column sums."""
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        raise ValueError("mixing matrix must be square")
    if not np.allclose(w, w.T, atol=atol):
        raise ValueError("mixing matrix must be symmetric")
    ones = np.ones(w.shape[0])
    if not np.allclose(w @ ones, ones, atol=atol):
        raise ValueError("mixing matrix rows must sum to one")


def rho(w: np.ndarray) -> float:
    """ρ(W) = ‖W − J‖ (spectral norm; W−J is symmetric)."""
    m = w.shape[0]
    eigs = np.linalg.eigvalsh(w - ideal_matrix(m))
    return float(np.max(np.abs(eigs)))


def rho_gradient(w: np.ndarray) -> np.ndarray:
    """Subgradient ∇ρ(W) = u_max v_maxᵀ (eq. 18).

    For the symmetric W−J this is sign(λ*)·v* v*ᵀ with (λ*, v*) the
    extreme eigenpair by absolute value.
    """
    return rho_and_gradient(w)[1]


def rho_and_gradient(w: np.ndarray) -> tuple[float, np.ndarray]:
    """(ρ(W), ∇ρ(W)) from a single eigendecomposition.

    Callers that need both per step (the FMMD loop tracks the ρ
    trajectory while following the gradient) would otherwise factor
    W − J twice per iteration — at 500 agents the dominant sweep cost.
    The ρ value may differ from ``rho()`` in the last ulp (LAPACK's
    with-vectors driver vs. values-only). LAPACK's subset drivers
    (dsyevr/dsyevx IL=IU) were evaluated for the extreme pair and
    rejected: on the heavily clustered spectra of early Frank-Wolfe
    iterates they can return an *empty* subset at the degenerate end,
    and on dense-spectrum iterates the saving over dsyevd is <1.3×.
    """
    m = w.shape[0]
    eigs, vecs = np.linalg.eigh(w - ideal_matrix(m))
    k = int(np.argmax(np.abs(eigs)))
    v = vecs[:, k]
    grad = math.copysign(1.0, eigs[k]) * np.outer(v, v)
    return float(np.abs(eigs[k])), grad


def fw_step(
    w: np.ndarray, gamma: float, atom: tuple[int, int] | None
) -> None:
    """In-place Frank-Wolfe update W ← (1−γ)·W + γ·S^(atom).

    Bitwise-identical to forming the atom densely (``swapping_matrix``
    or I) and evaluating ``(1−γ)·W + γ·S`` — without the two O(m²)
    temporaries per step: entries where S is zero see ``(1−γ)·w + γ·0``,
    an exact no-op on the nonnegative FW iterates; the diagonal adds
    ``γ·1`` with the same two flops; and for a swapping atom the
    (i,i)/(j,j) entries are restored to their pure scaled values while
    (i,j)/(j,i) gain γ.
    """
    w *= 1.0 - gamma
    diag = np.einsum("ii->i", w)
    if atom is None:  # identity atom
        diag += gamma
        return
    i, j = atom
    sii, sjj = w[i, i], w[j, j]
    diag += gamma
    w[i, i] = sii
    w[j, j] = sjj
    w[i, j] += gamma
    w[j, i] += gamma


@dataclasses.dataclass(frozen=True)
class ConvergenceConstants:
    """Problem constants of assumptions (1)-(3), Theorem III.3."""

    lipschitz: float = 1.0        # l
    sigma_hat: float = 1.0        # σ̂  (stochastic gradient noise)
    zeta_hat: float = 1.0         # ζ̂  (data heterogeneity)
    m1: float = 0.0               # M1
    m2: float = 0.0               # M2
    f_gap: float = 1.0            # F(x̄¹) − F_inf
    epsilon: float = 1e-2         # target ε-stationarity


def iterations_to_converge(
    rho_value: float, m: int, c: ConvergenceConstants = ConvergenceConstants()
) -> float:
    """K(ρ) of eq. (13), up to the universal constant.

    Increasing in ρ; diverges as ρ → 1. Used to *rank* designs (the
    universal constant cancels in comparisons).
    """
    if not (0.0 <= rho_value):
        raise ValueError("rho must be nonnegative")
    if rho_value >= 1.0:
        return math.inf
    gap = 1.0 - rho_value**2
    eps = c.epsilon
    term1 = c.sigma_hat**2 / (m * eps**2)
    term2 = (
        c.zeta_hat * math.sqrt(c.m1 + 1.0)
        + c.sigma_hat * math.sqrt(gap)
    ) / (gap * eps**1.5)
    term3 = math.sqrt((c.m2 + 1.0) * (c.m1 + 1.0)) / (gap * eps)
    return c.lipschitz * c.f_gap * (term1 + term2 + term3)


def total_time(
    tau: float, rho_value: float, m: int,
    c: ConvergenceConstants = ConvergenceConstants(),
) -> float:
    """Objective (15): per-iteration time × iterations to convergence."""
    return tau * iterations_to_converge(rho_value, m, c)
