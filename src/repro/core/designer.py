"""Joint design pipeline — the paper's full system (objective (15)).

Given an overlay (or just its inferred categories), a model size κ, and
convergence constants, produce:

  1. a mixing matrix W (FMMD-WP by default, or a named baseline),
  2. an optimal overlay routing for the demands W triggers (MILP (8)/(12)
     or the congestion-aware heuristic),
  3. per-iteration time τ (routed) and τ̄ (default paths), ρ(W), K(ρ),
     and the estimated total training time τ·K.

``sweep_iterations`` searches the FMMD iteration count T — the outer
knob trading per-iteration cost against convergence speed.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, MutableMapping, Sequence

import numpy as np

from repro.core import mixing
from repro.core.fmmd import FMMDResult, fmmd, fmmd_wp, _tau_bar
from repro.core.sca import sca_design
from repro.core.topology_baselines import (
    clique_design,
    prim_design,
    ring_design,
)
from repro.net.categories import (
    Categories,
    CategoryIncidence,
    compile_category_incidence,
    compute_categories,
)
from repro.net.demands import demands_from_links
from repro.net.routing import (
    PhasedRoutingSolution,
    RoutingSolution,
    route,
    route_direct,
    route_time_expanded,
)
from repro.net.simulator import (
    Scenario,
    SimResult,
    compile_incidence,
    simulate,
    simulate_phased,
)
from repro.net.stochastic import StochasticScenario
from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class DesignOutcome:
    design: FMMDResult
    routing: RoutingSolution
    tau: float           # routed per-iteration time (optimal scheme)
    tau_bar: float       # default-path per-iteration time (eq. 22)
    rho: float
    iterations_to_eps: float
    total_time: float    # τ · K(ρ) — objective (15)
    sim: SimResult | None = None  # static schedule under the scenario
    # Phase-adaptive (time-expanded) schedule, when priced alongside the
    # static one via ``reroute_per_phase=True``:
    phased_routing: PhasedRoutingSolution | None = None
    sim_phased: SimResult | None = None
    tau_static_sched: float = float("nan")  # simulated τ, static schedule
    tau_phased: float = float("nan")        # simulated τ, phased schedule
    # Stochastic pricing (``stochastic=`` + ``stochastic_rollouts=N``):
    # per-rollout simulated τ of the deployed schedule (online re-routed
    # when ``reroute_per_phase``, else static), its seeded mean — which
    # ``tau``/``total_time`` then price — and the p95/p99 tails (p99 is
    # only meaningful at the 256+ rollout budgets ``engine="jax"``
    # makes affordable; at N=8 it ~equals the max sample).
    tau_samples: tuple[float, ...] = ()
    tau_mean: float = float("nan")
    tau_p95: float = float("nan")
    tau_p99: float = float("nan")

    @property
    def name(self) -> str:
        return self.design.variant


def _check_per_edge_scalable(categories: Categories, scenario) -> None:
    """Fail fast — with the fix — when phase-adaptive routing would need
    per-edge capacity scaling that the categories cannot provide.

    ``Categories.scaled`` with a per-edge ``CapacityPhase`` scale
    re-derives C_F from ground-truth member edges and edge capacities;
    inferred categories (``infer_categories``) withhold both, so the
    deep scaling call would raise an unactionable ``ValueError`` from
    inside the routing stack. Catch it at the designer level instead.
    """
    if scenario is None or not getattr(scenario, "capacity_phases", ()):
        return
    if categories.edge_capacity is not None and all(
        categories.members.values()
    ):
        return
    if any(
        isinstance(ph.scale, Mapping) for ph in scenario.capacity_phases
    ):
        raise ValueError(
            "reroute_per_phase with per-edge CapacityPhase scales needs "
            "ground-truth categories: these categories have no member "
            "edges / edge capacities (infer_categories withholds them), "
            "so Categories.scaled cannot re-derive the per-phase C_F. "
            "Either build the categories with compute_categories(overlay) "
            "or restrict the scenario to scalar phase scales."
        )


def evaluate_design(
    design: FMMDResult,
    categories: Categories,
    kappa: float,
    num_agents: int,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    optimize_routing: bool = True,
    milp_time_limit: float = 60.0,
    overlay: OverlayNetwork | None = None,
    scenario: Scenario | None = None,
    incidence: CategoryIncidence | None = None,
    routing_cache: MutableMapping | None = None,
    heuristic_rounds: int = 8,
    reroute_per_phase: bool = False,
    stochastic: StochasticScenario | None = None,
    stochastic_rollouts: int = 8,
    stochastic_seed: int = 0,
    engine: str = "batched",
) -> DesignOutcome:
    """Route the design's demands and price its total training time.

    With ``scenario`` (and the ``overlay`` it needs), the per-iteration
    time τ is the fluid-simulated makespan under the scenario's degraded
    network instead of the closed-form static value — so a design can be
    priced under time-varying capacities, cross-traffic, stragglers, and
    churn before deployment. Churn-cancelled exchanges are priced as
    renormalized-mixing rounds (the survivors' completion time; see
    ``outcome.sim.cancelled_branches`` for how much of W was lost), while
    a simulation that never completes (``unfinished_branches > 0``) or
    delivers nothing (every flow fully churn-cancelled — all-NaN
    ``flow_completion``) prices as τ = inf rather than silently
    under-counting.

    ``reroute_per_phase=True`` additionally prices the phase-adaptive
    schedule (``route_time_expanded`` against the scenario's capacity
    phases): both schedules are simulated, both τ values land in
    ``tau_static_sched``/``tau_phased`` (with the simulations in
    ``sim``/``sim_phased`` and the schedule in ``phased_routing``), and
    the design is priced at the better of the two — the schedule an
    operator would actually deploy. Requires ``optimize_routing``, and —
    when the scenario's phases carry *per-edge* scale maps — categories
    with ground-truth members/edge capacities (``compute_categories``;
    inferred categories fail fast here with the fix spelled out rather
    than deep inside ``Categories.scaled``).

    ``stochastic`` (a ``StochasticScenario``) prices the design as a
    *seeded expectation*: ``stochastic_rollouts`` realizations are drawn
    with keys ``(stochastic_seed, r)``, each is simulated — with
    ``reroute_per_phase=True`` the deployed schedule is the *online*
    re-router (``route_time_expanded(online=True)``, deciding at every
    boundary from the realized state only), else the static one — and
    ``tau`` becomes the mean over rollouts (``tau_mean``), with the p95
    tail in ``tau_p95`` and every sample in ``tau_samples``. Mutually
    exclusive with ``scenario`` (a stochastic model IS a distribution
    over scenarios); deterministic events ride in ``stochastic.base``.

    ``incidence`` (precompiled ``CategoryIncidence``) and
    ``routing_cache`` (activated-link-set → ``RoutingSolution``;
    phase-adaptive segments under ``(link-set, phase-scale)`` keys)
    amortize routing work across repeated calls with the same
    categories/κ/routing settings — different FMMD iteration counts
    frequently activate the same link set, so a grid sweep rarely
    re-routes; stochastic rollouts reuse it too (recurring Markov states
    re-realize the same per-edge scales).

    ``engine`` selects the simulation engine for every pricing run
    (see ``simulate``). With ``engine="jax"`` the stochastic path
    compiles the branch incidence once per activated-link set (cached
    as a padded ``DeviceIncidence`` in ``routing_cache``) and prices
    ALL ``stochastic_rollouts`` in one batched XLA launch instead of a
    Python loop — which is what makes 256+ rollout budgets (and hence
    a meaningful ``tau_p99``) practical. The jax engine prices the
    static deployed schedule; combining it with ``reroute_per_phase``
    (host-side online re-routing) is rejected — price that policy with
    the numpy engines.

    Engine / scenario / stochastic matrix::

        engine=       scenario=                     stochastic=
        ------------  ----------------------------  -------------------------
        "batched"     full (needs ``overlay=``);    host loop over rollouts;
                      ``reroute_per_phase=True``    ``reroute_per_phase``
                      prices the phase-adaptive     deploys the *online*
                      schedule too                  re-router per rollout
        "vectorized"  full (same as "batched")      same host loop
        "reference"   RAISES on any scenario        RAISES (rollouts are
                                                    scenarios)
        "jax"         capacity phases + churn;      ALL rollouts in one XLA
                      RAISES on cross-traffic /     launch (``DeviceIncidence``
                      stragglers; RAISES with       cached in
                      ``reroute_per_phase=True``    ``routing_cache``); RAISES
                                                    with ``reroute_per_phase``

        Always RAISES: ``scenario=`` and ``stochastic=`` together;
        either without ``overlay=``; ``reroute_per_phase`` without
        ``optimize_routing``; per-edge capacity phases with inferred
        (memberless) categories.
    """
    if (scenario is not None or stochastic is not None) and overlay is None:
        raise ValueError("scenario pricing requires the overlay")
    if scenario is not None and stochastic is not None:
        raise ValueError(
            "pass either a deterministic scenario or a stochastic model, "
            "not both (deterministic events ride in stochastic.base)"
        )
    if stochastic is not None and stochastic_rollouts < 1:
        raise ValueError("stochastic_rollouts must be >= 1")
    if reroute_per_phase and not optimize_routing:
        raise ValueError(
            "reroute_per_phase re-optimizes routing per capacity phase; "
            "it requires optimize_routing=True"
        )
    if engine == "jax" and reroute_per_phase:
        raise ValueError(
            "engine='jax' prices the static deployed schedule on the "
            "device; online per-phase re-routing is host-side — price "
            "reroute_per_phase with engine='batched'"
        )
    if reroute_per_phase:
        _check_per_edge_scalable(categories, scenario)
    links = design.activated_links
    demands = demands_from_links(links, kappa, num_agents) if links else []
    if demands:
        cache_key = frozenset(links)
        sol = (
            routing_cache.get(cache_key)
            if routing_cache is not None else None
        )
        if sol is None:
            if optimize_routing:
                sol = route(
                    demands, categories, kappa, num_agents,
                    time_limit=milp_time_limit, incidence=incidence,
                    heuristic_rounds=heuristic_rounds,
                )
            else:
                sol = route_direct(demands, categories, kappa)
            if routing_cache is not None:
                routing_cache[cache_key] = sol
    else:
        sol = RoutingSolution(
            demands=(), trees=(), completion_time=0.0,
            method="empty", solve_seconds=0.0,
        )

    def _priced_tau(sim: SimResult) -> float:
        # A truncated run, or one where churn cancelled every flow
        # outright (all-NaN completions), must not price as cheap/free.
        undelivered = sim.cancelled_branches > 0 and all(
            np.isnan(c) for c in sim.flow_completion
        )
        return (
            np.inf if sim.unfinished_branches or undelivered
            else sim.makespan
        )

    sim = None
    sim_phased = None
    phased = None
    tau = sol.completion_time
    tau_static_sched = float("nan")
    tau_phased = float("nan")
    tau_samples: tuple[float, ...] = ()
    tau_mean = float("nan")
    tau_p95 = float("nan")
    tau_p99 = float("nan")
    if stochastic is not None and demands and engine == "jax":
        # Deferred import: the numpy pricing path must not pay a jax
        # import (or trace) unless the device engine is requested.
        from repro.net import jax_engine

        dev_key = ("jax-device-incidence", frozenset(links))
        dev = (
            routing_cache.get(dev_key)
            if routing_cache is not None else None
        )
        if dev is None:
            binc = compile_incidence(sol, overlay)
            flow_size = np.array(
                [d.size for d in sol.demands], dtype=np.float64
            )
            dev = jax_engine.device_incidence(binc, flow_size)
            if routing_cache is not None:
                routing_cache[dev_key] = dev
        batch = stochastic.realization_batch(
            stochastic_seed, stochastic_rollouts, dev.source
        )
        sims = jax_engine.rollout_batch_results(sol, dev, batch)
        sim = sims[-1]  # inspection aid, as in the numpy path
        samples = [_priced_tau(s) for s in sims]
        tau_samples = tuple(float(s) for s in samples)
        tau_mean = float(np.mean(samples))
        tau_p95 = float(np.percentile(samples, 95.0))
        tau_p99 = float(np.percentile(samples, 99.0))
        tau = tau_mean
        tau_static_sched = tau_mean
    elif stochastic is not None and demands:
        static_samples = []
        online_samples = []
        for realization in stochastic.sample_many(
            stochastic_seed, stochastic_rollouts
        ):
            sim = simulate(sol, overlay, scenario=realization, engine=engine)
            static_samples.append(_priced_tau(sim))
            if reroute_per_phase and realization.capacity_phases:
                _check_per_edge_scalable(categories, realization)
                # The deployed policy: online re-routing from observed
                # state at every realized phase boundary.
                phased = route_time_expanded(
                    demands, categories, realization, kappa, num_agents,
                    time_limit=milp_time_limit, incidence=incidence,
                    heuristic_rounds=heuristic_rounds,
                    routing_cache=routing_cache,
                    cache_key=frozenset(links), base_solution=sol,
                    online=True, overlay=overlay,
                )
                sim_phased = simulate_phased(
                    phased, overlay, scenario=realization, engine=engine
                )
                online_samples.append(_priced_tau(sim_phased))
            elif reroute_per_phase:
                # Trivial realization: the online schedule degenerates
                # to the static route bitwise — reuse its sample.
                online_samples.append(static_samples[-1])
        # ``sim``/``sim_phased``/``phased_routing`` keep the LAST
        # rollout's artifacts (inspection aids); the pricing is the
        # seeded expectation over all of them.
        samples = online_samples if reroute_per_phase else static_samples
        tau_samples = tuple(float(s) for s in samples)
        tau_mean = float(np.mean(samples))
        tau_p95 = float(np.percentile(samples, 95.0))
        tau_p99 = float(np.percentile(samples, 99.0))
        tau = tau_mean
        tau_static_sched = float(np.mean(static_samples))
        if reroute_per_phase:
            tau_phased = float(np.mean(online_samples))
    elif scenario is not None and demands:
        sim = simulate(sol, overlay, scenario=scenario, engine=engine)
        tau = tau_static_sched = _priced_tau(sim)
        if reroute_per_phase and scenario.capacity_phases:
            phased = route_time_expanded(
                demands, categories, scenario, kappa, num_agents,
                time_limit=milp_time_limit, incidence=incidence,
                heuristic_rounds=heuristic_rounds,
                routing_cache=routing_cache, cache_key=frozenset(links),
                base_solution=sol,  # unscaled segments reuse the static route
            )
            sim_phased = simulate_phased(
                phased, overlay, scenario=scenario, engine=engine
            )
            tau_phased = _priced_tau(sim_phased)
            # Deploy whichever schedule the scenario actually favors.
            tau = min(tau_static_sched, tau_phased)
    rho_v = design.rho
    k_eps = mixing.iterations_to_converge(rho_v, num_agents, constants)
    return DesignOutcome(
        design=design,
        routing=sol,
        tau=tau,
        tau_bar=_tau_bar(
            frozenset(links), categories, kappa, incidence=incidence
        ),
        rho=rho_v,
        iterations_to_eps=k_eps,
        total_time=tau * k_eps,
        sim=sim,
        phased_routing=phased,
        sim_phased=sim_phased,
        tau_static_sched=tau_static_sched,
        tau_phased=tau_phased,
        tau_samples=tau_samples,
        tau_mean=tau_mean,
        tau_p95=tau_p95,
        tau_p99=tau_p99,
    )


def design(
    method: str,
    categories: Categories,
    kappa: float,
    num_agents: int,
    overlay: OverlayNetwork | None = None,
    iterations: int = 12,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    optimize_routing: bool = True,
    scenario: Scenario | None = None,
    milp_time_limit: float = 60.0,
    incidence: CategoryIncidence | None = None,
    routing_cache: MutableMapping | None = None,
    heuristic_rounds: int = 8,
    reroute_per_phase: bool = False,
    stochastic: StochasticScenario | None = None,
    stochastic_rollouts: int = 8,
    stochastic_seed: int = 0,
    engine: str = "batched",
) -> DesignOutcome:
    """Produce and price one named design.

    method ∈ {"fmmd", "fmmd-w", "fmmd-p", "fmmd-wp", "clique", "ring",
              "prim", "sca"}. ``scenario`` prices the design under a
    degraded/time-varying network (requires ``overlay``);
    ``reroute_per_phase`` additionally prices the phase-adaptive
    schedule (see ``evaluate_design``); ``stochastic`` prices it as a
    seeded expectation over ``stochastic_rollouts`` realizations
    (online re-routed when ``reroute_per_phase``);
    ``incidence``/``routing_cache`` amortize routing across repeated
    calls, and ``engine`` selects the simulation engine —
    ``engine="jax"`` batches all rollouts in one XLA launch (see
    ``evaluate_design``).
    """
    m = num_agents
    method = method.lower()
    if method == "fmmd":
        d = fmmd(m, iterations)
    elif method == "fmmd-w":
        d = fmmd(m, iterations, weight_opt=True)
    elif method == "fmmd-p":
        d = fmmd(m, iterations, categories=categories, kappa=kappa,
                 priority=True, incidence=incidence)
    elif method == "fmmd-wp":
        d = fmmd_wp(m, iterations, categories, kappa, incidence=incidence)
    elif method == "clique":
        d = clique_design(m)
    elif method == "ring":
        d = ring_design(m)
    elif method == "prim":
        if overlay is None:
            raise ValueError("prim needs the overlay (path structure)")
        d = prim_design(overlay)
    elif method == "sca":
        d = sca_design(m, categories, kappa, constants)
    else:
        raise ValueError(f"unknown design method: {method}")
    return evaluate_design(
        d, categories, kappa, m, constants, optimize_routing,
        milp_time_limit=milp_time_limit, overlay=overlay,
        scenario=scenario, incidence=incidence,
        routing_cache=routing_cache, heuristic_rounds=heuristic_rounds,
        reroute_per_phase=reroute_per_phase,
        stochastic=stochastic,
        stochastic_rollouts=stochastic_rollouts,
        stochastic_seed=stochastic_seed,
        engine=engine,
    )


def sweep_iterations(
    categories: Categories,
    kappa: float,
    num_agents: int,
    iteration_grid: Sequence[int] = (4, 8, 12, 16, 24, 32),
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    method: str = "fmmd-wp",
    overlay: OverlayNetwork | None = None,
    scenario: Scenario | None = None,
    optimize_routing: bool = True,
    milp_time_limit: float = 60.0,
    heuristic_rounds: int = 8,
    reroute_per_phase: bool = False,
    stochastic: StochasticScenario | None = None,
    stochastic_rollouts: int = 8,
    stochastic_seed: int = 0,
    engine: str = "batched",
) -> DesignOutcome:
    """Outer search over the design method's T for the best total time.

    ``overlay``/``scenario`` price every grid point under a degraded or
    time-varying network; ``reroute_per_phase`` prices the
    phase-adaptive schedule alongside the static one at every grid
    point (see ``evaluate_design``); ``stochastic`` prices every grid
    point as a seeded expectation over ``stochastic_rollouts``
    realizations — every point sees the SAME realizations (common
    random numbers), so the T comparison is not confounded by sampling
    noise; ``optimize_routing=False`` skips
    the routing optimizer (default paths only), ``milp_time_limit``
    caps each point's MILP, and ``heuristic_rounds`` tunes the
    congestion-aware re-routing budget. The link×category incidence is
    compiled once and the routing solutions are cached by
    activated-link set — and, for phase-adaptive segments, by
    (activated-link set, phase scale) — so grid points whose designs
    activate the same links are routed exactly once per phase.
    ``engine="jax"`` additionally caches one padded device incidence
    per activated-link set and prices each grid point's rollout batch
    as a single XLA launch (see ``evaluate_design``).

    Engine / scenario / stochastic matrix (every grid point prices
    through ``evaluate_design``, so its matrix applies verbatim)::

        engine=       scenario=                     stochastic=
        ------------  ----------------------------  -------------------------
        "batched"     full (needs ``overlay=``)     host loop, common random
                                                    numbers across grid points
        "vectorized"  full (same as "batched")      same host loop
        "reference"   RAISES on any scenario        RAISES
        "jax"         capacity phases + churn;      one XLA launch per grid
                      RAISES on cross-traffic /     point; RAISES with
                      stragglers or                 ``reroute_per_phase=True``
                      ``reroute_per_phase=True``

        Always RAISES: ``scenario=`` with ``stochastic=``; either
        without ``overlay=``; ``reroute_per_phase`` without
        ``optimize_routing``.
    """
    # One compilation serves both the routing heuristic and the FMMD-P
    # priority filter across every grid point.
    incidence = (
        compile_category_incidence(categories, num_agents, kappa)
        if optimize_routing or method.lower() in ("fmmd-p", "fmmd-wp")
        else None
    )
    routing_cache: dict = {}
    best: DesignOutcome | None = None
    for t in iteration_grid:
        out = design(
            method, categories, kappa, num_agents, overlay=overlay,
            iterations=t, constants=constants,
            optimize_routing=optimize_routing, scenario=scenario,
            milp_time_limit=milp_time_limit, incidence=incidence,
            routing_cache=routing_cache,
            heuristic_rounds=heuristic_rounds,
            reroute_per_phase=reroute_per_phase,
            stochastic=stochastic,
            stochastic_rollouts=stochastic_rollouts,
            stochastic_seed=stochastic_seed,
            engine=engine,
        )
        if np.isfinite(out.total_time) and (
            best is None or out.total_time < best.total_time
        ):
            best = out
    if best is None:
        raise RuntimeError("no finite design found; widen iteration_grid")
    return best
