"""Joint design pipeline — the paper's full system (objective (15)).

Given an overlay (or just its inferred categories), a model size κ, and
convergence constants, produce:

  1. a mixing matrix W (FMMD-WP by default, or a named baseline),
  2. an optimal overlay routing for the demands W triggers (MILP (8)/(12)
     or the congestion-aware heuristic),
  3. per-iteration time τ (routed) and τ̄ (default paths), ρ(W), K(ρ),
     and the estimated total training time τ·K.

``sweep_iterations`` searches the FMMD iteration count T — the outer
knob trading per-iteration cost against convergence speed.
"""

from __future__ import annotations

import dataclasses
from typing import MutableMapping, Sequence

import numpy as np

from repro.core import mixing
from repro.core.fmmd import FMMDResult, fmmd, fmmd_wp, _tau_bar
from repro.core.sca import sca_design
from repro.core.topology_baselines import (
    clique_design,
    prim_design,
    ring_design,
)
from repro.net.categories import (
    Categories,
    CategoryIncidence,
    compile_category_incidence,
    compute_categories,
)
from repro.net.demands import demands_from_links
from repro.net.routing import RoutingSolution, route, route_direct
from repro.net.simulator import Scenario, SimResult, simulate
from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class DesignOutcome:
    design: FMMDResult
    routing: RoutingSolution
    tau: float           # routed per-iteration time (optimal scheme)
    tau_bar: float       # default-path per-iteration time (eq. 22)
    rho: float
    iterations_to_eps: float
    total_time: float    # τ · K(ρ) — objective (15)
    sim: SimResult | None = None  # fluid simulation (scenario pricing)

    @property
    def name(self) -> str:
        return self.design.variant


def evaluate_design(
    design: FMMDResult,
    categories: Categories,
    kappa: float,
    num_agents: int,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    optimize_routing: bool = True,
    milp_time_limit: float = 60.0,
    overlay: OverlayNetwork | None = None,
    scenario: Scenario | None = None,
    incidence: CategoryIncidence | None = None,
    routing_cache: MutableMapping | None = None,
    heuristic_rounds: int = 8,
) -> DesignOutcome:
    """Route the design's demands and price its total training time.

    With ``scenario`` (and the ``overlay`` it needs), the per-iteration
    time τ is the fluid-simulated makespan under the scenario's degraded
    network instead of the closed-form static value — so a design can be
    priced under time-varying capacities, cross-traffic, stragglers, and
    churn before deployment. Churn-cancelled exchanges are priced as
    renormalized-mixing rounds (the survivors' completion time; see
    ``outcome.sim.cancelled_branches`` for how much of W was lost), while
    a simulation that never completes (``unfinished_branches > 0``)
    prices as τ = inf rather than silently under-counting.

    ``incidence`` (precompiled ``CategoryIncidence``) and
    ``routing_cache`` (activated-link-set → ``RoutingSolution``) amortize
    routing work across repeated calls with the same categories/κ/routing
    settings — different FMMD iteration counts frequently activate the
    same link set, so a grid sweep rarely re-routes.
    """
    if scenario is not None and overlay is None:
        raise ValueError("scenario pricing requires the overlay")
    links = design.activated_links
    demands = demands_from_links(links, kappa, num_agents) if links else []
    if demands:
        cache_key = frozenset(links)
        sol = (
            routing_cache.get(cache_key)
            if routing_cache is not None else None
        )
        if sol is None:
            if optimize_routing:
                sol = route(
                    demands, categories, kappa, num_agents,
                    time_limit=milp_time_limit, incidence=incidence,
                    heuristic_rounds=heuristic_rounds,
                )
            else:
                sol = route_direct(demands, categories, kappa)
            if routing_cache is not None:
                routing_cache[cache_key] = sol
    else:
        sol = RoutingSolution(
            demands=(), trees=(), completion_time=0.0,
            method="empty", solve_seconds=0.0,
        )
    sim = None
    tau = sol.completion_time
    if scenario is not None and demands:
        sim = simulate(sol, overlay, scenario=scenario)
        # A truncated run, or one where churn cancelled everything before
        # a single branch finished, must not price as cheap/free.
        undelivered = sim.makespan == 0.0 and sim.cancelled_branches > 0
        tau = (
            np.inf if sim.unfinished_branches or undelivered
            else sim.makespan
        )
    rho_v = design.rho
    k_eps = mixing.iterations_to_converge(rho_v, num_agents, constants)
    return DesignOutcome(
        design=design,
        routing=sol,
        tau=tau,
        tau_bar=_tau_bar(frozenset(links), categories, kappa),
        rho=rho_v,
        iterations_to_eps=k_eps,
        total_time=tau * k_eps,
        sim=sim,
    )


def design(
    method: str,
    categories: Categories,
    kappa: float,
    num_agents: int,
    overlay: OverlayNetwork | None = None,
    iterations: int = 12,
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    optimize_routing: bool = True,
    scenario: Scenario | None = None,
    milp_time_limit: float = 60.0,
    incidence: CategoryIncidence | None = None,
    routing_cache: MutableMapping | None = None,
    heuristic_rounds: int = 8,
) -> DesignOutcome:
    """Produce and price one named design.

    method ∈ {"fmmd", "fmmd-w", "fmmd-p", "fmmd-wp", "clique", "ring",
              "prim", "sca"}. ``scenario`` prices the design under a
    degraded/time-varying network (requires ``overlay``);
    ``incidence``/``routing_cache`` amortize routing across repeated
    calls (see ``evaluate_design``).
    """
    m = num_agents
    method = method.lower()
    if method == "fmmd":
        d = fmmd(m, iterations)
    elif method == "fmmd-w":
        d = fmmd(m, iterations, weight_opt=True)
    elif method == "fmmd-p":
        d = fmmd(m, iterations, categories=categories, kappa=kappa,
                 priority=True, incidence=incidence)
    elif method == "fmmd-wp":
        d = fmmd_wp(m, iterations, categories, kappa, incidence=incidence)
    elif method == "clique":
        d = clique_design(m)
    elif method == "ring":
        d = ring_design(m)
    elif method == "prim":
        if overlay is None:
            raise ValueError("prim needs the overlay (path structure)")
        d = prim_design(overlay)
    elif method == "sca":
        d = sca_design(m, categories, kappa, constants)
    else:
        raise ValueError(f"unknown design method: {method}")
    return evaluate_design(
        d, categories, kappa, m, constants, optimize_routing,
        milp_time_limit=milp_time_limit, overlay=overlay,
        scenario=scenario, incidence=incidence,
        routing_cache=routing_cache, heuristic_rounds=heuristic_rounds,
    )


def sweep_iterations(
    categories: Categories,
    kappa: float,
    num_agents: int,
    iteration_grid: Sequence[int] = (4, 8, 12, 16, 24, 32),
    constants: mixing.ConvergenceConstants = mixing.ConvergenceConstants(),
    method: str = "fmmd-wp",
    overlay: OverlayNetwork | None = None,
    scenario: Scenario | None = None,
    optimize_routing: bool = True,
    milp_time_limit: float = 60.0,
    heuristic_rounds: int = 8,
) -> DesignOutcome:
    """Outer search over the design method's T for the best total time.

    ``overlay``/``scenario`` price every grid point under a degraded or
    time-varying network; ``optimize_routing=False`` skips the routing
    optimizer (default paths only), ``milp_time_limit`` caps each
    point's MILP, and ``heuristic_rounds`` tunes the congestion-aware
    re-routing budget. The link×category incidence is compiled once and
    the routing solutions are cached by activated-link set, so grid
    points whose designs activate the same links are routed exactly
    once.
    """
    # One compilation serves both the routing heuristic and the FMMD-P
    # priority filter across every grid point.
    incidence = (
        compile_category_incidence(categories, num_agents, kappa)
        if optimize_routing or method.lower() in ("fmmd-p", "fmmd-wp")
        else None
    )
    routing_cache: dict = {}
    best: DesignOutcome | None = None
    for t in iteration_grid:
        out = design(
            method, categories, kappa, num_agents, overlay=overlay,
            iterations=t, constants=constants,
            optimize_routing=optimize_routing, scenario=scenario,
            milp_time_limit=milp_time_limit, incidence=incidence,
            routing_cache=routing_cache,
            heuristic_rounds=heuristic_rounds,
        )
        if np.isfinite(out.total_time) and (
            best is None or out.total_time < best.total_time
        ):
            best = out
    if best is None:
        raise RuntimeError("no finite design found; widen iteration_grid")
    return best
