"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q, k, v, *, causal=True, window=None, softcap=None
):
    """q: [B,H,Sq,D]; k/v: [B,KV,Sk,D] → [B,H,Sq,D] (fp32 math)."""
    b, h, sq, d = q.shape
    kv = k.shape[1]
    group = h // kv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (d**-0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    sk = kk.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, softcap=None):
    """q: [B,H,1,D]; k/v: [B,KV,S,D]; length: [] or [B]."""
    b, h, _, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    group = h // kv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    sc = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * (d**-0.5)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # [B, S]
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def mixing_sgd_combine_ref(x, recv, weights, momentum, *, lr):
    acc = x.astype(jnp.float32) * weights[0]
    acc += jnp.einsum(
        "r,rn->n", weights[1:].astype(jnp.float32),
        recv.astype(jnp.float32),
    )
    acc -= lr * momentum.astype(jnp.float32)
    return acc.astype(x.dtype)
