"""Pallas TPU flash attention: causal, sliding-window, softcap, GQA.

Online-softmax blocked attention (Rabe-Staats / FlashAttention) with
explicit BlockSpec VMEM tiling for the MXU:

  grid = (batch·q_heads, S_q/block_q, S_k/block_k), k innermost;
  q/o blocks [block_q, head_dim] and k/v blocks [block_k, head_dim] live
  in VMEM; the running (max, sum, acc) state lives in VMEM scratch and is
  carried across the k-block sweep; fully-masked k blocks are skipped.

Block sizes default to (128, 128) — MXU-aligned (≥8×128 tiles) and small
enough that q+k+v+o+acc ≈ 5·128·head_dim·4B ≲ 0.5 MB ≪ 16 MB VMEM for
head_dim ≤ 256.

Targets TPU; validated on CPU via interpret=True against ref.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, window: int | None, softcap: float | None,
    block_q: int, block_k: int, num_kb: int, causal: bool,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    # Skip blocks that are fully masked (beyond causal/window reach).
    live = jnp.any(mask) if (causal or window is not None) else True

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ik == num_kb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention(
    q: jnp.ndarray,   # [B, H, Sq, D]
    k: jnp.ndarray,   # [B, KV, Sk, D]
    v: jnp.ndarray,   # [B, KV, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    if h % kv:
        raise ValueError("q heads must be divisible by kv heads")
    group = h // kv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    nq, nk = sq // block_q, sk // block_k
    scale = d**-0.5

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * kv, sk, d)
    vf = v.reshape(b * kv, sk, d)

    def q_index(bh, iq, ik):
        return (bh, iq, 0)

    def kv_index(bh, iq, ik):
        kv_bh = (bh // h) * kv + (bh % h) // group
        return (kv_bh, ik, 0)

    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, num_kb=nk, causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32), # running numerator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
