"""Pallas TPU kernels + jnp oracles. Entry points in repro.kernels.ops."""
