"""Public jit'd entry points for the Pallas kernels.

On TPU the Pallas path compiles to Mosaic; elsewhere (CPU CI, this
container) ``interpret=True`` executes the kernel body with the same
block decomposition. ``use_pallas(False)`` routes everything to the jnp
reference — the mode used for the dry-run lowering.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mixing_combine import mixing_sgd_combine as _mix_pallas

_USE_PALLAS = True


def use_pallas(enabled: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = enabled


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    block_q=128, block_k=128):
    if not _USE_PALLAS:
        return ref.flash_attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    return _flash_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=_interpret_default(),
    )


def decode_attention(q, k, v, length, *, softcap=None, block_k=512):
    if not _USE_PALLAS:
        return ref.decode_attention_ref(q, k, v, length, softcap=softcap)
    return _decode_pallas(
        q, k, v, length, softcap=softcap, block_k=block_k,
        interpret=_interpret_default(),
    )


def mixing_sgd_combine(x, recv, weights, momentum, *, lr, block_n=65536):
    if not _USE_PALLAS:
        return ref.mixing_sgd_combine_ref(x, recv, weights, momentum, lr=lr)
    return _mix_pallas(
        x, recv, weights, momentum, lr=lr, block_n=block_n,
        interpret=_interpret_default(),
    )
