"""Pallas TPU decode attention: one query vs a long KV cache.

The long_500k serving shape is dominated by streaming the KV cache from
HBM; this kernel reads K/V exactly once in [block_k, head_dim] VMEM tiles
with an online-softmax accumulator, so the op runs at HBM bandwidth.

grid = (batch·q_heads, S_cache/block_k); the (1, head_dim) query block is
revisited across the k sweep; `length` masks invalid (unwritten) cache
slots so ring buffers and partially-filled caches work unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, softcap: float | None, block_k: int, num_kb: int,
):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1
    )
    mask = k_pos < length

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale       # [1, d]
        k = k_ref[0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [1, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)               # [bk, d]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == num_kb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "block_k", "interpret")
)
def decode_attention(
    q: jnp.ndarray,        # [B, H, 1, D]
    k: jnp.ndarray,        # [B, KV, S, D]
    v: jnp.ndarray,        # [B, KV, S, D]
    length: jnp.ndarray,   # [] or [B] — number of valid cache slots
    *,
    softcap: float | None = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    from jax.experimental.pallas import tpu as pltpu

    b, h, one, d = q.shape
    _, kv, s, _ = k.shape
    group = h // kv
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError("cache length must divide block_k")
    nk = s // block_k
    lengths = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))

    qf = q.reshape(b * h, 1, d)
    kf = k.reshape(b * kv, s, d)
    vf = v.reshape(b * kv, s, d)

    def q_index(bh, ik):
        return (bh, 0, 0)

    def kv_index(bh, ik):
        return ((bh // h) * kv + (bh % h) // group, ik, 0)

    def len_index(bh, ik):
        return (bh // h,)

    kernel = functools.partial(
        _decode_kernel,
        scale=d**-0.5, softcap=softcap, block_k=block_k, num_kb=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec((1,), len_index, memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), q_index),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qf, kf, vf)
    return out.reshape(b, h, 1, d)
