"""Pallas TPU fused gossip-combine + SGD update (the paper's hot loop).

Each D-PSGD iteration ends with

    x_i ← W_ii·x_i + Σ_{j∈N(i)} W_ij·recv_j − η·v_i            (eq. (2))

where recv_j are the neighbor parameter shards delivered by the ppermute
schedule and v_i the momentum buffer. Done naively this is R+2 separate
HBM passes over κ-sized buffers; fused it is a single streaming pass —
the op is purely memory-bound, so the fusion is worth ~(R+2)× on the
mixing step's HBM time.

grid = (N / block_n); every operand is tiled [block_n] in VMEM; the
neighbor dim R is unrolled in-kernel (R = active degree, small by design
— that is the whole point of the sparse mixing matrix).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(w_ref, x_ref, recv_ref, mom_ref, o_ref, *, num_recv, lr):
    acc = x_ref[...].astype(jnp.float32) * w_ref[0]
    for r in range(num_recv):
        acc += recv_ref[r].astype(jnp.float32) * w_ref[r + 1]
    acc -= lr * mom_ref[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("lr", "block_n", "interpret")
)
def mixing_sgd_combine(
    x: jnp.ndarray,        # [N] own parameters (flat shard)
    recv: jnp.ndarray,     # [R, N] received neighbor shards
    weights: jnp.ndarray,  # [R+1]: [W_ii, W_i,j1, ..., W_i,jR]
    momentum: jnp.ndarray, # [N]
    *,
    lr: float,
    block_n: int = 65536,
    interpret: bool = False,
) -> jnp.ndarray:
    from jax.experimental.pallas import tpu as pltpu

    (n,) = x.shape
    r = recv.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError("N must divide block_n")
    kernel = functools.partial(_combine_kernel, num_recv=r, lr=lr)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((r + 1,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((r, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32), x, recv, momentum)
