"""Version shims for the jax API surface this repo relies on.

The codebase targets the modern jax API (``jax.make_mesh(axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``) but must also run on jax 0.4.x, where

  * ``jax.sharding.AxisType`` does not exist (explicit-sharding mesh axis
    types landed in 0.5),
  * ``jax.set_mesh`` does not exist (``Mesh`` itself is the context
    manager),
  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
    replication-check knob ``check_rep`` instead of ``check_vma``.

All call sites go through this module so the rest of the tree stays
version-agnostic.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(shape, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(shape, axis_names)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    On jax >= 0.5 this is ``jax.set_mesh``; on 0.4.x a ``Mesh`` is itself
    a context manager with the same effect for the tracing APIs we use.
    """
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


@contextlib.contextmanager
def maybe_set_mesh(mesh: jax.sharding.Mesh | None):
    """``set_mesh`` that tolerates ``None`` (no ambient mesh)."""
    if mesh is None:
        yield None
    else:
        with set_mesh(mesh) as m:
            yield m


def shard_map(
    f,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
):
    """``jax.shard_map`` with the replication check disabled.

    Falls back to ``jax.experimental.shard_map.shard_map(check_rep=False)``
    on jax 0.4.x.
    """
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
