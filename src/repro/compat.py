"""Version shims for the jax API surface this repo relies on.

The codebase targets the modern jax API (``jax.make_mesh(axis_types=...)``,
``jax.set_mesh``, ``jax.shard_map``) but must also run on jax 0.4.x, where

  * ``jax.sharding.AxisType`` does not exist (explicit-sharding mesh axis
    types landed in 0.5),
  * ``jax.set_mesh`` does not exist (``Mesh`` itself is the context
    manager),
  * ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
    replication-check knob ``check_rep`` instead of ``check_vma``.

All call sites go through this module so the rest of the tree stays
version-agnostic.

This module is also the single owner of the ``jax_enable_x64`` flag:
the pricing path (``repro.net.jax_engine``) is float64 end to end, and
jax silently truncates to float32 unless x64 is enabled *before* the
first trace. ``ensure_x64()`` turns the flag on idempotently;
``require_x64()`` is the import-order guard every device-pricing entry
point calls before tracing — it raises the named ``X64NotEnabledError``
instead of letting a misconfigured process price designs in float32.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


class X64NotEnabledError(RuntimeError):
    """float64 was not enabled before a pricing trace.

    Raised by ``require_x64()`` when ``jax_enable_x64`` is off at the
    point a device-pricing entry is about to trace: continuing would
    silently downcast the simulator's float64 capacities/volumes (and
    int64 CSR indices) to 32-bit, and the rtol=1e-9 parity gates against
    the numpy engines would be meaningless. The fix is to import
    ``repro.net.jax_engine`` (which calls ``ensure_x64()`` at import)
    or call ``repro.compat.ensure_x64()`` yourself before any jax
    tracing happens in the process.
    """


def x64_enabled() -> bool:
    """Whether ``jax_enable_x64`` is currently on."""
    return bool(jax.config.read("jax_enable_x64"))


def ensure_x64() -> None:
    """Idempotently enable float64. Safe to call any number of times.

    jax keys its trace caches on the x64 flag, so enabling it here never
    corrupts earlier float32 traces — they simply stop being reused. If
    the flag cannot take effect (e.g. a build that hard-disables x64),
    raise ``X64NotEnabledError`` now rather than mis-pricing later.
    """
    if not x64_enabled():
        jax.config.update("jax_enable_x64", True)
    if not x64_enabled():
        raise X64NotEnabledError(
            "jax_enable_x64 could not be enabled; the jax pricing "
            "engine requires float64"
        )


def require_x64() -> None:
    """Import-order guard: raise ``X64NotEnabledError`` if x64 is off.

    Called by every ``repro.net.jax_engine`` entry point before it
    traces, so pricing can never silently run float32 — even if some
    caller disabled the flag after ``ensure_x64()`` ran.
    """
    if not x64_enabled():
        raise X64NotEnabledError(
            "jax_enable_x64 is off: device pricing would silently run "
            "float32. Call repro.compat.ensure_x64() before the first "
            "trace (repro.net.jax_engine does so at import)."
        )


def make_mesh(shape, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            shape,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(shape, axis_names)


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    On jax >= 0.5 this is ``jax.set_mesh``; on 0.4.x a ``Mesh`` is itself
    a context manager with the same effect for the tracing APIs we use.
    """
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


@contextlib.contextmanager
def maybe_set_mesh(mesh: jax.sharding.Mesh | None):
    """``set_mesh`` that tolerates ``None`` (no ambient mesh)."""
    if mesh is None:
        yield None
    else:
        with set_mesh(mesh) as m:
            yield m


def shard_map(
    f,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
):
    """``jax.shard_map`` with the replication check disabled.

    Falls back to ``jax.experimental.shard_map.shard_map(check_rep=False)``
    on jax 0.4.x.
    """
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
