"""repro: overlay-aware decentralized learning framework (JAX/TPU).

Reproduction + extension of "Communication Optimization for Decentralized
Learning atop Bandwidth-limited Edge Networks" (Sun, Nguyen, He; 2025).

Layers:
  repro.core      — mixing-matrix design (FMMD family), D-PSGD, gossip collectives
  repro.net       — underlay/overlay network model, categories, routing (MILP + heuristic)
  repro.models    — assigned LM architectures (dense/MoE/SSM/hybrid/audio/VLM backbones)
  repro.data      — synthetic non-IID data pipeline
  repro.optim     — optimizers and schedules
  repro.checkpoint— checkpoint/restore
  repro.runtime   — fault tolerance, stragglers, compression
  repro.kernels   — Pallas TPU kernels (flash attention, decode, mixing combine)
  repro.launch    — mesh, dry-run, training/serving drivers
  repro.roofline  — roofline analysis from compiled artifacts
"""

__version__ = "1.0.0"
