"""Learning-rate schedules (paper §IV-A1 uses step decay: 0.1 / 0.05 / 0.01)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def step_decay(boundaries_values):
    """Piecewise-constant: [(boundary_step, value), ...] sorted ascending.

    ``paper_schedule`` below reproduces the paper's 0.1/0.05/0.01 decay.
    """
    bounds = [b for b, _ in boundaries_values]
    vals = [v for _, v in boundaries_values]

    def fn(step):
        lr = jnp.asarray(vals[-1], jnp.float32)
        for b, v in reversed(list(zip(bounds, vals))):
            lr = jnp.where(step < b, jnp.asarray(v, jnp.float32), lr)
        return lr

    return fn


def paper_schedule(steps_per_epoch: int):
    """0.1 for 30 epochs, 0.05 for 30, 0.01 after (paper §IV-A1)."""
    return step_decay(
        [(30 * steps_per_epoch, 0.1), (60 * steps_per_epoch, 0.05), (10**9, 0.01)]
    )


def cosine(base_lr: float, total_steps: int, warmup: int = 0):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        return base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))

    return fn
