"""Optimizers and schedules (hand-rolled; optax is not shipped offline)."""

from repro.optim import adamw, schedule, sgd
