"""AdamW for the non-D-PSGD training paths (examples, ablations)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, dtype=jnp.float32):
    z = lambda p: jnp.zeros_like(p, dtype=dtype)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "count": jnp.zeros((), jnp.int32),
    }


def update(
    grads, state, params, lr,
    b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
):
    count = state["count"] + 1
    t = count.astype(jnp.float32)
    m = jax.tree.map(
        lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
        state["m"], grads,
    )
    v = jax.tree.map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
        state["v"], grads,
    )
    def upd(p, m_, v_):
        mh = m_ / (1 - b1**t)
        vh = v_ / (1 - b2**t)
        step = lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(mh.dtype))
        return (p.astype(jnp.float32) - step).astype(p.dtype)
    return (
        jax.tree.map(upd, params, m, v),
        {"m": m, "v": v, "count": count},
    )
