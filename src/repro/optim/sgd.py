"""SGD with momentum — the D-PSGD base optimizer. Functional optax-style."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(params, momentum_dtype=None):
    return {
        "momentum": jax.tree.map(
            lambda p: jnp.zeros_like(
                p, dtype=momentum_dtype or p.dtype
            ),
            params,
        )
    }


def update(grads, state, params, lr, momentum: float = 0.9):
    """Returns (new_params, new_state). lr may be a traced scalar."""
    new_m = jax.tree.map(
        lambda m, g: momentum * m.astype(jnp.float32) + g.astype(jnp.float32),
        state["momentum"],
        grads,
    )
    new_p = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
        params,
        new_m,
    )
    new_m = jax.tree.map(
        lambda m, old: m.astype(old.dtype), new_m, state["momentum"]
    )
    return new_p, {"momentum": new_m}
