"""Roofline analysis from compiled XLA artifacts + analytic cell models."""

from repro.roofline.analysis import (
    RooflineReport,
    parse_collectives,
    parse_collectives_nested,
    report,
)
