"""Roofline terms from compiled XLA artifacts (no hardware required).

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_global   / (chips × peak_FLOPs)
    memory     = HLO_bytes_global   / (chips × HBM_bw)
    collective = collective_bytes_per_chip / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (with an analytic
fallback — XLA:CPU sometimes reports no flops); collective bytes are
parsed from the (per-device SPMD) HLO text by summing operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op.

TPU v5e-like constants: 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+"
    r"([\w\-]+)(?:\.\d+)?\(([^)]*)\)"
)


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes appearing in ``text``."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in an HLO module dump."""
    # First pass: instruction name -> output bytes.
    out_bytes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        mm = _INSTR_RE.match(ln)
        if mm:
            name, shape_txt, _, _ = mm.groups()
            out_bytes[name] = _shape_bytes(shape_txt)

    bytes_by_kind = {k: 0 for k in _COLLECTIVES}
    count_by_kind = {k: 0 for k in _COLLECTIVES}
    for ln in lines:
        mm = _INSTR_RE.match(ln)
        if not mm:
            continue
        name, shape_txt, op, args = mm.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                kind = c
                break
        if kind is None:
            continue
        # Operand sizes: look up named operands; fall back to output size.
        operands = 0
        for a in args.split(","):
            a = a.strip().lstrip("%")
            a = a.split(" ")[-1].lstrip("%")
            if a in out_bytes:
                operands += out_bytes[a]
        if operands == 0:
            operands = _shape_bytes(shape_txt)
        bytes_by_kind[kind] += operands
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    current = None
    header = re.compile(
        r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$"
    )
    for ln in hlo_text.splitlines():
        m = header.match(ln)
        if m:
            current = m.group(1)
            comps[current] = []
            continue
        if ln.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(ln)
    return comps


_WHILE_RE = re.compile(
    r"while\(.*?\).*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)"
)
_CALL_COMP_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)"
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _trip_count(cond_lines: list[str]) -> int:
    """Largest s32 scalar constant in a while condition ≈ the trip count."""
    best = 1
    for ln in cond_lines:
        for c in _CONST_RE.findall(ln):
            best = max(best, int(c))
    return best


def parse_collectives_nested(hlo_text: str) -> CollectiveStats:
    """Collective bytes with while-body (scan) trip-count multiplication.

    XLA lowers lax.scan to `while`; a naive line scan counts each body
    once. Here every computation's collective bytes are weighted by the
    product of enclosing loop trip counts (trip parsed from the largest
    s32 constant in the loop condition — exact for jax scans).
    """
    comps = _split_computations(hlo_text)
    if not comps:
        return parse_collectives(hlo_text)

    # instruction name -> bytes (across all computations)
    out_bytes: dict[str, int] = {}
    for lines in comps.values():
        for ln in lines:
            mm = _INSTR_RE.match(ln)
            if mm:
                out_bytes[mm.group(1)] = _shape_bytes(mm.group(2))

    # computation -> multiplicity, propagated from callers. Iterate to a
    # fixpoint over the call graph (it is a DAG).
    mult: dict[str, float] = {name: 0.0 for name in comps}
    # Roots: computations never referenced by others.
    referenced = set()
    for lines in comps.values():
        for ln in lines:
            for grp in _CALL_COMP_RE.findall(ln):
                for nm in grp.split(","):
                    referenced.add(nm.strip().lstrip("%"))
    for name in comps:
        if name not in referenced:
            mult[name] = 1.0
    for _ in range(len(comps)):
        changed = False
        for name, lines in comps.items():
            m_self = mult.get(name, 0.0)
            if m_self <= 0:
                continue
            for ln in lines:
                wm = _WHILE_RE.search(ln)
                if wm:
                    cond, body = wm.groups()
                    trips = _trip_count(comps.get(cond, []))
                    for target, factor in ((body, trips), (cond, trips + 1)):
                        new = m_self * factor
                        if target in mult and new > mult[target]:
                            mult[target] = new
                            changed = True
                else:
                    for grp in _CALL_COMP_RE.findall(ln):
                        for nm in grp.split(","):
                            nm = nm.strip().lstrip("%")
                            if nm in mult and m_self > mult[nm]:
                                mult[nm] = m_self
                                changed = True
        if not changed:
            break

    bytes_by_kind = {k: 0.0 for k in _COLLECTIVES}
    count_by_kind = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        w = mult.get(name, 0.0) or 1.0
        for ln in lines:
            mm = _INSTR_RE.match(ln)
            if not mm:
                continue
            _, shape_txt, op, args = mm.groups()
            kind = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    kind = c
                    break
            if kind is None:
                continue
            operands = 0
            for a in args.split(","):
                a = a.strip().lstrip("%").split(" ")[-1].lstrip("%")
                if a in out_bytes:
                    operands += out_bytes[a]
            if operands == 0:
                operands = _shape_bytes(shape_txt)
            bytes_by_kind[kind] += w * operands
            count_by_kind[kind] += 1
    return CollectiveStats(
        {k: int(v) for k, v in bytes_by_kind.items()}, count_by_kind
    )


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_global: float
    hlo_bytes_global: float
    collective_bytes_per_chip: float
    collective_breakdown: dict
    model_flops_global: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        if self.hlo_flops_global <= 0:
            return float("nan")
        return self.model_flops_global / self.hlo_flops_global

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak compute achieved when running at the modeled
        bound: (model FLOPs / chips / peak) / bound-time."""
        if self.bound_s <= 0:
            return float("nan")
        ideal = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["bound_s"] = self.bound_s
        d["useful_flops_fraction"] = self.useful_flops_fraction
        d["roofline_fraction"] = self.roofline_fraction
        return d


def model_flops(cfg, shape, num_agents: int = 1) -> float:
    """MODEL_FLOPS: 6·N·D for training (N = active params), 2·N·D for
    forward-only shapes, per the standard rule; D = total tokens."""
    from repro.models import model as M

    n_total = M.parameter_count(cfg)
    # Active params for MoE: replace expert FFN params with top_k experts.
    n_active = n_total
    if cfg.num_experts > 0:
        moe_layers = sum(
            1 for k in cfg.block_pattern if k.endswith("_moe")
        ) * cfg.num_groups
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_active = (
            n_total
            - moe_layers * cfg.num_experts * per_expert
            + moe_layers * cfg.num_experts_per_token * per_expert
        )
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence.
    return 2.0 * n_active * shape.global_batch


def analytic_hlo_flops(cfg, shape, remat: bool) -> float:
    """Fallback when cost_analysis() reports no flops (XLA:CPU).

    Matmul-only estimate incl. attention score/value matmuls and MoE
    capacity compute; training multiplies by 3 (fwd + 2×bwd) and adds one
    extra forward when full remat is on.
    """
    s = shape.seq_len
    b = shape.global_batch
    hd = cfg.resolved_head_dim
    flops_tok = 0.0  # per token, forward, ×2 for MAC
    attn_extra = 0.0
    for kind in cfg.block_pattern:
        is_attn = kind in ("attn", "attn_moe", "swa", "swa_moe", "local", "global")
        if is_attn:
            qkv = cfg.d_model * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
            out = cfg.num_heads * hd * cfg.d_model
            flops_tok += qkv + out
            ctx = s
            if kind in ("swa", "swa_moe", "local") and cfg.sliding_window:
                ctx = min(s, cfg.sliding_window)
            # causal: average context s/2 for full, ctx for windowed
            avg_ctx = ctx / 2 if ctx == s else ctx
            attn_extra += 2 * cfg.num_heads * hd * avg_ctx
        elif kind.startswith("mamba"):
            di = cfg.ssm_expand * cfg.d_model
            flops_tok += cfg.d_model * 2 * di + di * cfg.d_model
            flops_tok += di * (2 * cfg.ssm_state_dim + 1)
            attn_extra += 2 * di * cfg.ssm_state_dim  # scan update+readout
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            flops_tok += cfg.d_model * 2 * di + di * cfg.d_model
            flops_tok += 3 * di * (di // max(cfg.mlstm_heads, 1))
            attn_extra += 2 * (di // max(cfg.mlstm_heads, 1)) * (s / 2) * cfg.mlstm_heads
        elif kind == "slstm":
            flops_tok += 8 * cfg.d_model * cfg.d_model
        if kind.endswith("_moe"):
            cap_factor = cfg.capacity_factor * cfg.num_experts_per_token
            flops_tok += 3 * cfg.d_model * cfg.d_ff * cap_factor
        elif kind in ("attn", "swa", "local", "global") or kind == "mamba":
            if cfg.d_ff > 0:
                flops_tok += 3 * cfg.d_model * cfg.d_ff
    flops_tok *= cfg.num_groups
    attn_extra *= cfg.num_groups
    flops_tok += cfg.vocab_size * cfg.d_model  # lm head
    total_fwd = 2.0 * (flops_tok + attn_extra) * b * s
    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat else 0.0)
        return total_fwd * mult
    if shape.kind == "prefill":
        return total_fwd
    # decode: context-length attention reads, single token
    return 2.0 * flops_tok * b + 2.0 * attn_extra * b / max(s, 1) * 2


def report(
    *,
    arch: str,
    shape,
    cfg,
    mesh_name: str,
    chips: int,
    cost: dict | None,
    hlo_text: str,
    num_agents: int = 1,
    remat: bool = True,
    tcfg=None,
    mesh_shape: dict | None = None,
    gossip_directed_edges: int = 0,
) -> RooflineReport:
    """Primary numbers come from the analytic cell model (repro.roofline.
    analytic) — XLA cost_analysis counts scan bodies once, so it is kept
    only as recorded metadata. Collective bytes take the max of the
    analytic model and the trip-aware HLO parse."""
    from repro.roofline import analytic

    coll = parse_collectives_nested(hlo_text)
    mesh_shape = mesh_shape or {"total": chips}

    if shape.kind == "train":
        from repro.configs.base import TrainConfig

        cell = analytic.train_model(
            cfg, shape, tcfg or TrainConfig(), mesh_shape, num_agents,
            gossip_directed_edges,
        )
    else:
        cell = analytic.serve_model(cfg, shape, mesh_shape)

    flops_global = cell.flops_global
    bytes_global = cell.hbm_bytes_global
    coll_per_chip = max(cell.collective_bytes_per_chip, float(coll.total_bytes))
    breakdown = dict(coll.bytes_by_kind)
    breakdown["analytic"] = dict(cell.collective_detail)
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_global=flops_global,
        hlo_bytes_global=bytes_global,
        collective_bytes_per_chip=coll_per_chip,
        collective_breakdown=breakdown,
        model_flops_global=model_flops(cfg, shape, num_agents),
        compute_s=flops_global / (chips * PEAK_FLOPS),
        memory_s=bytes_global / (chips * HBM_BW),
        collective_s=coll_per_chip / ICI_BW,
    )
