"""Analytic FLOPs / HBM-bytes / collective-bytes models per cell.

XLA's ``cost_analysis()`` counts each while-loop (scan) body ONCE, so a
scan-over-layers train step under-reports FLOPs by ~L×k. These closed-form
models — functions of the architecture, shape, layout and mesh — are the
primary roofline inputs; the HLO-parsed numbers (with while-body trip
multiplication, see analysis.parse_collectives_nested) serve as a
cross-check.

Conventions: "global" quantities sum over all chips; per-chip = global /
chips. All byte counts are logical payload bytes (collective algorithm
factors like ring 2(n−1)/n are folded into an EFFICIENCY constant).
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig

BF16 = 2
F32 = 4

# HBM passes per activation boundary in a remat'd train step:
# fwd write + bwd read + recompute write/read + grad pass ≈ 6.
ACT_PASSES_TRAIN = 6.0
ACT_PASSES_FWD = 2.0
ALLREDUCE_FACTOR = 2.0  # ring all-reduce moves ~2× payload per chip


def _attn_kinds(cfg: ModelConfig):
    return [
        k for k in cfg.block_pattern
        if k in ("attn", "attn_moe", "swa", "swa_moe", "local", "global")
    ]


def _layer_param_bytes(cfg: ModelConfig) -> float:
    """Parameter bytes of one repeating group / len(pattern) (avg layer)."""
    from repro.models import model as M

    return M.parameter_count(cfg) * BF16 / cfg.num_layers


@dataclasses.dataclass(frozen=True)
class CellModel:
    flops_global: float
    hbm_bytes_global: float
    collective_bytes_per_chip: float
    collective_detail: dict


def _flops_forward_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """Matmul MACs×2 per token, full depth, incl. attention quadratic."""
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in cfg.block_pattern:
        is_attn = kind in ("attn", "attn_moe", "swa", "swa_moe", "local",
                           "global")
        if is_attn:
            total += 2 * cfg.d_model * hd * (
                cfg.num_heads + 2 * cfg.num_kv_heads
            )
            total += 2 * cfg.num_heads * hd * cfg.d_model
            ctx = seq_len
            if kind in ("swa", "swa_moe", "local") and cfg.sliding_window:
                ctx = min(seq_len, cfg.sliding_window)
            avg_ctx = ctx / 2 if ctx == seq_len else ctx
            total += 2 * 2 * cfg.num_heads * hd * avg_ctx  # QKᵀ and PV
        elif kind.startswith("mamba"):
            di = cfg.ssm_expand * cfg.d_model
            total += 2 * (cfg.d_model * 2 * di + di * cfg.d_model)
            total += 2 * di * (2 * cfg.ssm_state_dim + 1)
            total += 8 * di * cfg.ssm_state_dim  # scan combine + readout
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            hd_m = di // max(cfg.mlstm_heads, 1)
            total += 2 * (cfg.d_model * 2 * di + di * cfg.d_model)
            total += 2 * 3 * di * hd_m
            total += 2 * 2 * hd_m * (seq_len / 2) * cfg.mlstm_heads
        elif kind == "slstm":
            total += 2 * 8 * cfg.d_model * cfg.d_model
        if kind.endswith("_moe"):
            total += (
                2 * 3 * cfg.d_model * cfg.d_ff
                * cfg.num_experts_per_token * cfg.capacity_factor
            )
        elif kind in ("attn", "swa", "local", "global", "mamba") and cfg.d_ff:
            total += 2 * 3 * cfg.d_model * cfg.d_ff
    total *= cfg.num_groups
    total += 2 * cfg.vocab_size * cfg.d_model  # LM head
    return total


def train_model(
    cfg: ModelConfig,
    shape: ShapeConfig,
    tcfg: TrainConfig,
    mesh_shape: dict,
    num_agents: int,
    gossip_directed_edges: int,
) -> CellModel:
    from repro.models import model as M

    chips = 1
    for v in mesh_shape.values():
        chips *= v
    tokens = shape.global_batch * shape.seq_len
    n_params = M.parameter_count(cfg)
    k_mb = max(tcfg.microbatch, 1)
    tp = mesh_shape.get("model", 1)
    fsdp = mesh_shape.get("data", 1) if tcfg.agent_layout == "pod" else 1
    dp_inner = 1
    if tcfg.agent_layout == "data_dp":
        # "model" axis repurposed as intra-agent DP: no TP collectives;
        # instead one fp32 gradient all-reduce per step over that axis.
        dp_inner, tp = tp, 1

    # FLOPs: fwd + 2×bwd + remat refwd.
    remat_mult = 4.0 if tcfg.remat != "none" else 3.0
    flops = _flops_forward_per_token(cfg, shape.seq_len) * tokens * remat_mult

    # HBM bytes (global).
    param_passes = (3.0 if tcfg.remat != "none" else 2.0) * k_mb + 6.0
    params_bytes = num_agents * n_params * BF16 * param_passes
    acts_bytes = (
        tokens * cfg.d_model * BF16 * cfg.num_layers * ACT_PASSES_TRAIN
    )
    hbm = params_bytes + acts_bytes

    # Collectives (per chip). Payloads are the chip-LOCAL activation
    # shard: tokens / (agents × microbatches × fsdp).
    detail = {}
    tokens_local_mb = tokens / max(num_agents, 1) / k_mb / fsdp
    # TP activation all-reduces: ~2 fwd + 2 bwd per layer per microbatch.
    if tp > 1:
        detail["tp_allreduce"] = (
            4.0 * cfg.num_layers * k_mb
            * tokens_local_mb * cfg.d_model * BF16
            * ALLREDUCE_FACTOR * (tp - 1) / tp
        )
    # FSDP: all-gather params fwd+bwd(+remat) and reduce-scatter grads,
    # per microbatch.
    if fsdp > 1:
        passes = (3.0 if tcfg.remat != "none" else 2.0) + 1.0
        detail["fsdp"] = (
            passes * k_mb * (n_params * BF16 / tp) * (fsdp - 1) / fsdp
        )
    # Intra-agent DP (data_dp): per-step bf16 gradient all-reduce
    # (fp32 local accumulation, bf16 on the wire).
    if dp_inner > 1:
        detail["dp_grad_allreduce"] = (
            n_params * BF16 * ALLREDUCE_FACTOR * (dp_inner - 1) / dp_inner
        )
    # Gossip: each directed activated edge ships the agent's param shard
    # (data_dp ravels the replicated tree and slices it over "model").
    if num_agents > 1 and gossip_directed_edges:
        kappa_shard = n_params * BF16 / (max(tp, dp_inner) * fsdp)
        per_agent_edges = gossip_directed_edges / num_agents
        detail["gossip"] = per_agent_edges * kappa_shard
        if dp_inner > 1:
            # write-back all-gather of the mixed flat tree
            detail["gossip"] += n_params * BF16 * (dp_inner - 1) / dp_inner
    coll = sum(detail.values())
    return CellModel(flops, hbm, coll, detail)


def serve_model(
    cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict
) -> CellModel:
    from repro.models import model as M

    chips = 1
    for v in mesh_shape.values():
        chips *= v
    n_params = M.parameter_count(cfg)
    tp = mesh_shape.get("model", 1)
    dp = chips // tp
    hd = cfg.resolved_head_dim
    attn_layers = len(_attn_kinds(cfg)) * cfg.num_groups

    def cache_tokens(seq):
        """KV slots read per attention layer (window-limited)."""
        full = seq
        tot = 0.0
        for k in _attn_kinds(cfg):
            ctx = full
            if k in ("swa", "swa_moe", "local") and cfg.sliding_window:
                ctx = min(full, cfg.sliding_window)
            tot += ctx
        return tot * cfg.num_groups

    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = _flops_forward_per_token(cfg, shape.seq_len) * tokens
        hbm = (
            n_params * BF16 * max(dp, 1)
            + tokens * cfg.d_model * BF16 * cfg.num_layers * ACT_PASSES_FWD
            + shape.global_batch * cache_tokens(shape.seq_len)
            * 2 * cfg.num_kv_heads * hd * BF16  # cache writes
        )
        detail = {}
        if tp > 1:  # seq-parallel prefill boundaries: ~1x payload
            detail["tp_allreduce"] = (
                4.0 * cfg.num_layers
                * (tokens / max(dp, 1)) * cfg.d_model * BF16
                * 1.0 * (tp - 1) / tp
            )
        return CellModel(flops, hbm, sum(detail.values()), detail)

    # decode: one token per sequence.
    b = shape.global_batch
    # Active params (MoE: top-k experts per token; small b may not touch all)
    n_active = n_params
    if cfg.num_experts > 0:
        moe_layers = sum(
            1 for k in cfg.block_pattern if k.endswith("_moe")
        ) * cfg.num_groups
        per_expert = 3 * cfg.d_model * cfg.d_ff
        experts_hit = min(
            cfg.num_experts, b * cfg.num_experts_per_token
        )
        n_active = (
            n_params
            - moe_layers * cfg.num_experts * per_expert
            + moe_layers * experts_hit * per_expert
        )
    flops = 2.0 * (n_active / max(1, 1)) * b  # matmul flops ≈ 2·N per token
    cache_bytes = (
        b * cache_tokens(shape.seq_len) * 2 * cfg.num_kv_heads * hd * BF16
    )
    # Recurrent state reads: mamba/mlstm states per layer.
    state_bytes = 0.0
    for kind in cfg.block_pattern:
        if kind.startswith("mamba"):
            di = cfg.ssm_expand * cfg.d_model
            state_bytes += b * di * cfg.ssm_state_dim * F32
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            hd_m = di // max(cfg.mlstm_heads, 1)
            state_bytes += b * cfg.mlstm_heads * hd_m * hd_m * F32
    state_bytes *= cfg.num_groups
    hbm = n_active * BF16 + cache_bytes + 2 * state_bytes
    flops += 2 * cache_bytes / BF16  # attention reads ≈ 2 FLOPs per elem
    detail = {}
    if tp > 1:
        detail["tp_allreduce"] = (
            4.0 * cfg.num_layers * b / max(dp, 1) * cfg.d_model * BF16
            * ALLREDUCE_FACTOR * (tp - 1) / tp
        )
    return CellModel(flops, hbm, sum(detail.values()), detail)
