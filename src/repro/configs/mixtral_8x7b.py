"""Mixtral 8x7B — 32L, d4096, 32H (GQA kv=8), d_ff 14336, 8 experts top-2,
sliding-window attention. [arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("swa_moe",),
    sliding_window=4096,
    num_experts=8,
    num_experts_per_token=2,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-8x7b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("swa_moe",),
    sliding_window=16,
    num_experts=4,
    num_experts_per_token=2,
    capacity_factor=8.0,  # droppless: decode≡train for consistency tests
    rope_theta=1e4,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="pod", microbatch=16)
