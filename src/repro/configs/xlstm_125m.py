"""xLSTM-125M — 12 blocks, d768, mLSTM:sLSTM 3:1, GPT-2 vocabulary.
[arXiv:2405.04517; unverified]. d_ff=0: xLSTM blocks carry their own
projections; no separate FFN (DESIGN.md §5)."""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_heads=4,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-125m-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_heads=2,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="data", microbatch=4)
