"""Jamba-1.5-Large — 72L, d8192, 64H (GQA kv=8), d_ff 24576, Mamba:attn
7:1 interleave, MoE (16 experts top-2) on every other layer. Attention
layers use NoPE (rope_theta=0). [arXiv:2403.19887; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=(
        "mamba", "mamba_moe", "mamba", "mamba_moe",
        "attn", "mamba_moe", "mamba", "mamba_moe",
    ),
    num_experts=16,
    num_experts_per_token=2,
    rope_theta=0.0,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=(
        "mamba", "mamba_moe", "mamba", "mamba_moe",
        "attn", "mamba_moe", "mamba", "mamba_moe",
    ),
    num_experts=4,
    num_experts_per_token=2,
    capacity_factor=8.0,  # droppless: decode≡train for consistency tests
    rope_theta=0.0,
    ssm_state_dim=4,
    ssm_conv_dim=2,
    ssm_expand=2,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="pod", microbatch=16)
