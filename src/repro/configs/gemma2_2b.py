"""Gemma2-2B — 26L, d2304, 8H (GQA kv=4, head_dim 256), d_ff 9216,
alternating local(4096)/global attention, logit softcaps, tied + scaled
embeddings. [arXiv:2408.00118; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("local", "global"),
    head_dim=256,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma2-2b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("local", "global"),
    head_dim=32,
    sliding_window=16,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="data", microbatch=8)
