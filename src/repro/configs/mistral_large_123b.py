"""Mistral-Large-2407 — 88L, d12288, 96H (GQA kv=8), d_ff 28672.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    block_pattern=("attn",),
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="mistral-large-123b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    block_pattern=("attn",),
    rope_theta=1e4,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="pod", microbatch=16)
