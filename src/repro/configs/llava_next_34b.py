"""LLaVA-NeXT-34B backbone (Yi-34B-style LM) — 60L, d7168, 56H (GQA kv=8),
d_ff 20480. The anyres vision tower is the stubbed frontend: inputs carry
precomputed patch embeddings [B, P, d_model].
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=("attn",),
    rope_theta=5e6,
    frontend="vision_patches",
    num_patches=576,
)

SMOKE_CONFIG = ModelConfig(
    name="llava-next-34b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    rope_theta=1e4,
    frontend="vision_patches",
    num_patches=8,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="pod", microbatch=16)
