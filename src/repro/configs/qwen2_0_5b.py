"""Qwen2-0.5B — 24L, d896, 14H (GQA kv=2), d_ff 4864, QKV bias.
[arXiv:2407.10671; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1e4,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="data_dp", microbatch=1)
