"""MusicGen-large backbone — 48L, d2048, 32H (MHA), d_ff 8192, decoder-only
over EnCodec tokens (vocab 2048). The EnCodec codec is the stubbed
frontend: inputs are precomputed audio-code token ids.
[arXiv:2306.05284; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=("attn",),
    rope_theta=1e4,
    frontend="audio_codec",
)

SMOKE_CONFIG = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    block_pattern=("attn",),
    rope_theta=1e4,
    frontend="audio_codec",
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="data", microbatch=8)
