"""Model / training / serving configuration schema and registry.

Each assigned architecture gets a module ``repro/configs/<id>.py`` that
exports ``CONFIG`` (the exact published configuration) and
``SMOKE_CONFIG`` (a reduced same-family config for CPU tests). The
registry maps the CLI ``--arch`` ids to those modules.

``block_pattern`` is the central abstraction: the repeating group of
heterogeneous layer kinds; the model scans over ``num_layers /
len(block_pattern)`` groups. Kinds:

  attn / attn_moe      — full causal attention + MLP / MoE
  swa / swa_moe        — sliding-window attention + MLP / MoE
  local / global       — gemma2-style alternating SWA / full attention
  mamba / mamba_moe    — Mamba mixer + MLP / MoE
  mlstm / slstm        — xLSTM blocks (no FFN, per the architecture)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

ATTN_KINDS = {"attn", "attn_moe", "swa", "swa_moe", "local", "global"}
MOE_KINDS = {"attn_moe", "swa_moe", "mamba_moe"}
RECURRENT_KINDS = {"mamba", "mamba_moe", "mlstm", "slstm"}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: tuple[str, ...] = ("attn",)
    head_dim: int | None = None
    sliding_window: int | None = None    # for swa/local kinds
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False            # gemma: scale embeddings by √d
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    # xLSTM
    mlstm_heads: int = 4
    # Modality frontends (STUBS — input_specs provides embeddings)
    frontend: str | None = None          # None | "audio_codec" | "vision_patches"
    num_patches: int = 576
    # Numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.block_pattern)}"
            )
        if any(k in MOE_KINDS for k in self.block_pattern):
            if self.num_experts <= 0 or self.num_experts_per_token <= 0:
                raise ValueError(f"{self.name}: MoE kinds need expert counts")
        for k in self.block_pattern:
            if k not in ATTN_KINDS | RECURRENT_KINDS:
                raise ValueError(f"{self.name}: unknown block kind {k!r}")
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: heads not divisible by kv heads")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """True if every layer is sub-quadratic in context (SSM or SWA)."""
        return all(
            k in RECURRENT_KINDS or k in ("swa", "swa_moe")
            for k in self.block_pattern
        ) or self.family in ("ssm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Distributed-training knobs for one (arch × shape) cell."""

    agent_layout: str = "data"     # "data": agents on (pod×)data axis, TP on
                                   # model; "pod": agents on pod axis,
                                   # FSDP on data + TP on model (big archs)
    remat: str = "full"            # "none" | "full" — activation ckpt policy
    learning_rate: float = 0.01
    momentum: float = 0.9
    gossip: str = "auto"           # "auto" | "sparse" | "allreduce"
    microbatch: int = 0            # >0: gradient accumulation steps
    moe_aux_weight: float = 1e-2
    router_z_weight: float = 1e-3


ARCH_IDS = (
    "mixtral-8x22b",
    "mixtral-8x7b",
    "xlstm-125m",
    "qwen1.5-0.5b",
    "mistral-large-123b",
    "gemma2-2b",
    "qwen2-0.5b",
    "musicgen-large",
    "jamba-1.5-large-398b",
    "llava-next-34b",
)

_MODULE_FOR_ARCH = {
    "mixtral-8x22b": "mixtral_8x22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "xlstm-125m": "xlstm_125m",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma2-2b": "gemma2_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "musicgen-large": "musicgen_large",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-34b": "llava_next_34b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    import importlib

    if arch not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[arch]}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_train_config(arch: str) -> TrainConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[arch]}")
    return getattr(mod, "TRAIN_CONFIG", TrainConfig())


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def cell_is_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch × shape) runs; reason recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} has full-attention layers (DESIGN.md §5)"
        )
    return True, ""
