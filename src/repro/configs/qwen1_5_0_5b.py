"""Qwen1.5-0.5B — 24L, d1024, 16H (MHA), d_ff 2816, QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.base import ModelConfig, TrainConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1e4,
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)

TRAIN_CONFIG = TrainConfig(agent_layout="data_dp", microbatch=1)
