"""Underlay-link categories (paper Definition 1) and their inference.

A *category* Γ_F, for a set F of overlay links, is the set of underlay
links traversed by **exactly** the overlay links in F. All links in one
category carry identical overlay traffic, so the per-iteration time only
depends on category-level quantities (Lemma III.2):

    τ = max_{F ∈ 𝓕} (κ / C_F) · t_F,   C_F = min_{e ∈ Γ_F} C_e .

We compute categories on **directed** overlay links (the paper's footnote:
capacity constraints are per direction), which generalizes (12) cleanly:
a directed underlay edge (u, v) belongs to the category of the set of
directed overlay links whose routing path traverses (u, v).

Two access paths:
  * ``compute_categories``   — ground truth from full underlay knowledge.
  * ``infer_categories``     — what an uncooperative underlay permits: the
    overlay can consistently estimate (𝓕, C_F) via tomography [17]. We
    model the estimator output (optionally capacity noise); on real
    deployments this would be replaced by the measurement pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class Categories:
    """Nonempty categories 𝓕 over *directed* overlay links.

    ``members[F]``  — the underlay directed edges in Γ_F (may be empty in
                      inferred mode, where only capacities are known).
    ``capacity[F]`` — bottleneck capacity C_F = min_{e ∈ Γ_F} C_e.
    Keys F are frozensets of directed overlay links (agent-index pairs).
    """

    members: Mapping[frozenset, tuple[tuple[int, int], ...]]
    capacity: Mapping[frozenset, float]

    @property
    def families(self) -> tuple[frozenset, ...]:
        return tuple(self.capacity.keys())

    def min_capacity(self) -> float:
        """C_min := min_F C_F (Theorem III.5)."""
        return min(self.capacity.values())

    def load_vector(self, link_uses: Mapping[tuple[int, int], int]) -> dict:
        """t_F for a map of directed-overlay-link -> #activated flows (10)."""
        return {
            F: sum(link_uses.get(l, 0) for l in F) for F in self.families
        }

    def completion_time(
        self, link_uses: Mapping[tuple[int, int], int], kappa: float
    ) -> float:
        """Closed-form optimal completion time (Lemma III.2, eq. (11))."""
        t = self.load_vector(link_uses)
        return max(
            (kappa * t[F] / self.capacity[F] for F in self.families),
            default=0.0,
        )


def compute_categories(overlay: OverlayNetwork) -> Categories:
    """Ground-truth categories from full knowledge of the underlay.

    For every directed underlay edge, collect the set of directed overlay
    links routed over it; group edges by that set.
    """
    edge_to_links: dict[tuple[int, int], set] = {}
    for i, j in overlay.directed_overlay_links:
        for e in overlay.path_edges(i, j):
            edge_to_links.setdefault(e, set()).add((i, j))

    members: dict[frozenset, list] = {}
    capacity: dict[frozenset, float] = {}
    for e, links in edge_to_links.items():
        F = frozenset(links)
        members.setdefault(F, []).append(e)
        c = overlay.underlay.capacity(*e)
        capacity[F] = min(capacity.get(F, np.inf), c)

    return Categories(
        members={F: tuple(v) for F, v in members.items()},
        capacity=capacity,
    )


def infer_categories(
    overlay: OverlayNetwork,
    capacity_noise: float = 0.0,
    seed: int = 0,
) -> Categories:
    """Tomography-style estimate of (𝓕, C_F) available to the overlay.

    [17] shows the overlay can *consistently* estimate the nonempty
    categories and each category's bottleneck capacity from end-to-end
    measurements alone. We model the estimator's output: exact category
    structure, with optional multiplicative noise on capacities to stress
    designs against estimation error (``capacity_noise`` = relative std).
    Members are withheld — the overlay never learns which physical links
    form Γ_F, matching the information model of §III-A3.
    """
    truth = compute_categories(overlay)
    rng = np.random.default_rng(seed)
    cap = {}
    for F, c in truth.capacity.items():
        noise = 1.0 + capacity_noise * rng.standard_normal()
        cap[F] = float(max(c * noise, 1e-9))
    return Categories(
        members={F: () for F in truth.capacity}, capacity=cap
    )
