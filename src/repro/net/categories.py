"""Underlay-link categories (paper Definition 1) and their inference.

A *category* Γ_F, for a set F of overlay links, is the set of underlay
links traversed by **exactly** the overlay links in F. All links in one
category carry identical overlay traffic, so the per-iteration time only
depends on category-level quantities (Lemma III.2):

    τ = max_{F ∈ 𝓕} (κ / C_F) · t_F,   C_F = min_{e ∈ Γ_F} C_e .

We compute categories on **directed** overlay links (the paper's footnote:
capacity constraints are per direction), which generalizes (12) cleanly:
a directed underlay edge (u, v) belongs to the category of the set of
directed overlay links whose routing path traverses (u, v).

Two access paths:
  * ``compute_categories``   — ground truth from full underlay knowledge.
  * ``infer_categories``     — what an uncooperative underlay permits: the
    overlay can consistently estimate (𝓕, C_F) via tomography [17]. We
    model the estimator output (optionally capacity noise); on real
    deployments this would be replaced by the measurement pipeline.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping

import numpy as np

from repro.analysis.contracts import maybe_validate
from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class _FlatCategories:
    """Precompiled link×category CSR structure riding along a
    ``Categories`` built by the vectorized ``compute_categories``.

    Exactly the capacity-independent half of a ``CategoryIncidence`` —
    entries sorted by (dense link id ``i·m + j``, family index) with the
    bincount-cumsum ``link_ptr`` — so ``compile_category_incidence``
    only has to assemble the capacity vector and κ/C_F coefficients
    (the ``CategoryIncidence.rescaled`` pattern, applied at compile
    time: structure shared, coefficients rebuilt). Capacity-independent,
    hence ``Categories.scaled`` propagates it unchanged.
    """

    num_agents: int
    num_categories: int
    entry_link: np.ndarray  # [nnz] dense link id i·m + j, link-major
    entry_cat: np.ndarray  # [nnz] family index per entry
    link_ptr: np.ndarray  # [m²+1] CSR slices per link id

    def __post_init__(self):
        # CSR well-formedness contract; no-op unless REPRO_VALIDATE=1
        # (repro.analysis.contracts.validate_flat_categories).
        maybe_validate(self)


@dataclasses.dataclass(frozen=True)
class Categories:
    """Nonempty categories 𝓕 over *directed* overlay links.

    ``members[F]``  — the underlay directed edges in Γ_F (may be empty in
                      inferred mode, where only capacities are known).
    ``capacity[F]`` — bottleneck capacity C_F = min_{e ∈ Γ_F} C_e.
    ``edge_capacity`` — base capacity per member underlay edge (set by
                      ``compute_categories``; None in inferred mode).
                      Needed to re-derive C_F under per-edge capacity
                      scaling, where the bottleneck edge may change.
    Keys F are frozensets of directed overlay links (agent-index pairs).
    """

    members: Mapping[frozenset, tuple[tuple[int, int], ...]]
    capacity: Mapping[frozenset, float]
    edge_capacity: Mapping[tuple[int, int], float] | None = None
    # Private acceleration payload (see _FlatCategories); never part of
    # equality — two Categories with the same mappings are the same
    # categories whether or not one carries the arrays.
    flat: _FlatCategories | None = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def families(self) -> tuple[frozenset, ...]:
        return tuple(self.capacity.keys())

    def min_capacity(self) -> float:
        """C_min := min_F C_F (Theorem III.5)."""
        return min(self.capacity.values())

    def load_vector(self, link_uses: Mapping[tuple[int, int], int]) -> dict:
        """t_F for a map of directed-overlay-link -> #activated flows (10)."""
        return {
            F: sum(link_uses.get(l, 0) for l in F) for F in self.families
        }

    def completion_time(
        self, link_uses: Mapping[tuple[int, int], int], kappa: float
    ) -> float:
        """Closed-form optimal completion time (Lemma III.2, eq. (11))."""
        t = self.load_vector(link_uses)
        return max(
            (kappa * t[F] / self.capacity[F] for F in self.families),
            default=0.0,
        )

    def scaled(
        self, scale: "float | Mapping[tuple[int, int], float]"
    ) -> "Categories":
        """Categories under phase-scaled capacities (C_F of one
        ``CapacityPhase``).

        Routing paths are capacity-independent, so the family structure
        is unchanged; only C_F moves. A scalar ``scale`` multiplies every
        C_F directly (min commutes with a uniform positive factor) —
        ``scale == 1.0`` returns ``self`` so callers keep object
        identity on the trivial phase. A per-edge Mapping (keyed like
        ``CapacityPhase.scale``, either direction, missing edges 1.0)
        re-derives C_F = min_{e ∈ Γ_F} f_e·C_e from the member edges,
        which requires ground-truth ``members``/``edge_capacity``
        (``compute_categories``; inferred categories raise).
        """
        if not isinstance(scale, Mapping):
            f = float(scale)
            if f <= 0:
                raise ValueError("capacity scale must be positive")
            if f == 1.0:
                return self
            return Categories(
                members=self.members,
                capacity={F: c * f for F, c in self.capacity.items()},
                edge_capacity=(
                    {e: c * f for e, c in self.edge_capacity.items()}
                    if self.edge_capacity is not None else None
                ),
                flat=self.flat,  # family structure is unchanged
            )
        if self.edge_capacity is None or not all(self.members.values()):
            raise ValueError(
                "per-edge capacity scaling needs ground-truth members "
                "and edge capacities (compute_categories); inferred "
                "categories only support scalar scales"
            )

        def factor(e: tuple[int, int]) -> float:
            return float(scale.get(e, scale.get((e[1], e[0]), 1.0)))

        capacity = {
            F: min(self.edge_capacity[e] * factor(e) for e in edges)
            for F, edges in self.members.items()
        }
        if any(c <= 0 for c in capacity.values()):
            raise ValueError("capacity scale must be positive")
        return Categories(
            members=self.members,
            capacity=capacity,
            edge_capacity={
                e: c * factor(e) for e, c in self.edge_capacity.items()
            },
            flat=self.flat,  # family structure is unchanged
        )


@dataclasses.dataclass(frozen=True)
class CategoryIncidence:
    """Precompiled link×category incidence for vectorized engines.

    The analogue of the simulator's ``BranchIncidence``: every directed
    overlay link (i, j) gets the dense id ``i·m + j``; flat-entry arrays
    list, link-major and within each link in ``families`` order (the
    order ``_link_category_costs``-style dict loops would encounter
    them), the categories the link belongs to and their κ/C_F
    coefficients. Compile once per (categories, κ, m); reuse across
    routing calls and design-sweep grid points.
    """

    num_agents: int
    kappa: float
    capacity: np.ndarray  # [nF] C_F in ``families`` order
    entry_link: np.ndarray  # [nnz] dense link id i·m + j, link-major
    entry_cat: np.ndarray  # [nnz] category index per entry
    entry_coef: np.ndarray  # [nnz] κ / C_F per entry
    link_ptr: np.ndarray  # [m²+1] CSR slices into entry_* per link id
    source: "Categories | None" = None  # what this was compiled from

    def __post_init__(self):
        # CSR well-formedness contract; no-op unless REPRO_VALIDATE=1
        # (repro.analysis.contracts.validate_category_incidence).
        # ``rescaled``/``dataclasses.replace`` re-run it, so per-phase
        # recompiles are covered too.
        maybe_validate(self)

    def matches(self, categories: "Categories") -> bool:
        """Cheap fingerprint check that this incidence was compiled from
        ``categories``: object identity (the amortizing call paths pass
        the same object through), else an O(nF) capacity-vector
        comparison. Equal capacities with different memberships would
        slip through the fallback — pass the same object to be exact."""
        if self.source is categories:
            return True
        caps = list(categories.capacity.values())
        return len(caps) == self.num_categories and np.array_equal(
            self.capacity, np.asarray(caps, dtype=np.float64)
        )

    @property
    def num_categories(self) -> int:
        return self.capacity.size

    def link_id(self, i: int, j: int) -> int:
        return i * self.num_agents + j

    def link_categories(self, link_id: int) -> np.ndarray:
        """Category indices of one dense link id (CSR slice)."""
        return self.entry_cat[self.link_ptr[link_id]:self.link_ptr[link_id + 1]]

    def link_costs(self, cat_weights: np.ndarray) -> np.ndarray:
        """Per-link Σ_F (κ/C_F)·w_F as a flat [m²] array.

        ``np.bincount`` accumulates in entry order, so each link's sum is
        added in exactly the per-link order a Python ``sum`` over its
        category list would use — bit-identical costs.
        """
        return np.bincount(
            self.entry_link,
            weights=self.entry_coef * cat_weights[self.entry_cat],
            minlength=self.num_agents * self.num_agents,
        )

    def loads_from_uses(
        self, link_uses: Mapping[tuple[int, int], int]
    ) -> np.ndarray:
        """t_F vector (``Categories.load_vector`` as an array)."""
        loads = np.zeros(self.num_categories)
        for (i, j), n in link_uses.items():
            if n:
                loads[self.link_categories(self.link_id(i, j))] += float(n)
        return loads

    def completion_time(self, loads: np.ndarray) -> float:
        """max_F κ·t_F/C_F — same per-element arithmetic as the
        dict-based ``Categories.completion_time``."""
        if not self.num_categories:
            return 0.0
        return float(np.max(self.kappa * loads / self.capacity))

    def rescaled(self, categories: "Categories") -> "CategoryIncidence":
        """Same link×category structure under phase-scaled capacities.

        ``categories`` must be a capacity-only rescale of the categories
        this incidence was compiled from (``Categories.scaled``): the
        families — and their iteration order — are unchanged, so the
        flat entry arrays are shared and only ``capacity``/``entry_coef``
        are rebuilt. This is how per-phase incidences are compiled once
        per scenario instead of once per (phase, call).
        """
        cap = np.asarray(list(categories.capacity.values()), dtype=np.float64)
        if cap.size != self.num_categories:
            raise ValueError(
                f"rescaled categories have {cap.size} families, "
                f"incidence was compiled for {self.num_categories}"
            )
        coef = self.kappa / cap
        return dataclasses.replace(
            self,
            capacity=cap,
            entry_coef=coef[self.entry_cat] if self.entry_cat.size
            else np.empty(0),
            source=categories,
        )


def _compile_category_incidence_reference(
    categories: Categories, num_agents: int, kappa: float
) -> CategoryIncidence:
    """Per-link Python-append compiler (retained ground truth).

    The original implementation: iterate every family's frozenset,
    append dense link ids, stable-sort by link. The vectorized
    ``compile_category_incidence`` is property-tested bitwise-identical
    to this on the same ``Categories``.
    """
    m = num_agents
    fams = categories.families
    cap = np.array([categories.capacity[F] for F in fams], dtype=np.float64)
    link_ids: list[int] = []
    cat_ids: list[int] = []
    for fi, F in enumerate(fams):
        for (i, j) in F:
            if not (0 <= i < m and 0 <= j < m):
                raise ValueError(
                    f"category link ({i},{j}) out of range for m={m}"
                )
            link_ids.append(i * m + j)
            cat_ids.append(fi)
    link = np.asarray(link_ids, dtype=np.int64)
    cat = np.asarray(cat_ids, dtype=np.int64)
    order = np.argsort(link, kind="stable")
    link, cat = link[order], cat[order]
    coef = kappa / cap
    return CategoryIncidence(
        num_agents=m,
        kappa=kappa,
        capacity=cap,
        entry_link=link,
        entry_cat=cat,
        entry_coef=coef[cat] if cat.size else np.empty(0),
        link_ptr=np.searchsorted(link, np.arange(m * m + 1)),
        source=categories,
    )


def compile_category_incidence(
    categories: Categories, num_agents: int, kappa: float
) -> CategoryIncidence:
    """Build the flat link×category entry arrays for ``categories``.

    Entries are sorted by (dense link id, family index) — exactly the
    order the reference's stable by-link sort of its family-major append
    sequence produces, since each (link, family) pair occurs at most
    once. When ``categories`` carries the ``_FlatCategories`` payload
    (the vectorized ``compute_categories`` output, propagated through
    ``Categories.scaled``), the entry arrays come straight from it with
    no per-link Python; otherwise this falls back to the retained
    reference loop. ``link_ptr`` is a bincount+cumsum CSR pointer —
    identical to (and cheaper than) the reference's O(m² log nnz)
    ``searchsorted`` scan over every dense link id.
    """
    m = num_agents
    fams = categories.families
    flat = categories.flat
    if (
        flat is None
        or flat.num_agents != m
        or flat.num_categories != len(fams)
    ):
        return _compile_category_incidence_reference(
            categories, num_agents, kappa
        )
    cap = np.array([categories.capacity[F] for F in fams], dtype=np.float64)
    cat = flat.entry_cat
    coef = kappa / cap
    return CategoryIncidence(
        num_agents=m,
        kappa=kappa,
        capacity=cap,
        entry_link=flat.entry_link,
        entry_cat=cat,
        entry_coef=coef[cat] if cat.size else np.empty(0),
        link_ptr=flat.link_ptr,
        source=categories,
    )


def _compute_categories_reference(overlay: OverlayNetwork) -> Categories:
    """Dict-of-set grouping (retained ground truth).

    The original per-(link, hop) Python loop. The vectorized
    ``compute_categories`` is property-tested bitwise-identical to this:
    same family keys in the same order, same member-edge order, same
    capacities.
    """
    edge_to_links: dict[tuple[int, int], set] = {}
    for i, j in overlay.directed_overlay_links:
        for e in overlay.path_edges(i, j):
            edge_to_links.setdefault(e, set()).add((i, j))

    members: dict[frozenset, list] = {}
    capacity: dict[frozenset, float] = {}
    edge_capacity: dict[tuple[int, int], float] = {}
    for e, links in edge_to_links.items():
        F = frozenset(links)
        members.setdefault(F, []).append(e)
        c = overlay.underlay.capacity(*e)
        edge_capacity[e] = c
        capacity[F] = min(capacity.get(F, np.inf), c)

    return Categories(
        members={F: tuple(v) for F, v in members.items()},
        capacity=capacity,
        edge_capacity=edge_capacity,
    )


def compute_categories(overlay: OverlayNetwork) -> Categories:
    """Ground-truth categories from full knowledge of the underlay.

    For every directed underlay edge, collect the set of directed overlay
    links routed over it; group edges by that set.

    Vectorized: all (overlay-link, underlay-edge) incidence pairs come
    from one ``OverlayNetwork.batched_path_edges`` call as flat int
    arrays, then ``_group_category_pairs`` groups them per directed edge
    and collapses equal link-set signatures into families — bitwise
    identical to ``_compute_categories_reference`` (property-tested)
    including family-key iteration order. The result carries the
    ``_FlatCategories`` payload that lets ``compile_category_incidence``
    skip its Python loop.
    """
    m = overlay.num_agents
    # The array path encodes node ids into int64 edge codes; anything
    # outside nonnegative machine ints must take the reference path
    # *before* the arrays are built — np.asarray(dtype=int64) would
    # truncate float ids silently and huge ids would overflow
    # ``u · n_nodes + v``, surfacing later as a bogus-edge KeyError (or,
    # worse, a silent collision) instead of an importable error.
    if not all(
        isinstance(n, (int, np.integer)) and 0 <= int(n) <= 2**31 - 1
        for n in overlay.underlay.graph.nodes
    ):
        return _compute_categories_reference(overlay)
    link_arr, eu, ev, rank = overlay.batched_path_edges()
    return _group_category_pairs(
        m, link_arr, eu, ev, rank, overlay.underlay.capacity
    )


def _group_category_pairs(
    m: int,
    link_arr: np.ndarray,
    eu: np.ndarray,
    ev: np.ndarray,
    rank: np.ndarray,
    cap_of,
) -> Categories:
    """Group flat (directed-overlay-link, directed-underlay-edge) pairs
    into ``Categories`` — the vectorized core of ``compute_categories``.

    ``link_arr`` holds dense directed-link ids ``i·(m−1) + j − [j > i]``,
    ``(eu, ev)`` the traversed directed edge per pair, and ``rank`` any
    key whose *order* reproduces the reference's link-major per-hop
    traversal order (only relative order matters — edges are ranked by
    first traversal, families by first edge). ``cap_of(u, v)`` returns
    the effective capacity of a directed edge.

    One fused-key sort groups pairs per directed edge (links ascending
    within each edge), and edges sharing a link-set signature — compared
    as the sorted-id byte string, which is set equality — collapse into
    one family. Exposed separately from ``compute_categories`` so the
    incremental-redesign service (``runtime/design_service.py``) can
    regroup a *cached* pair set after membership churn without
    recomputing any routing paths: filtering the pair arrays of a
    departed agent (or appending a joiner's) and regrouping is
    bitwise-identical to recomputing on the rebuilt overlay
    (property-tested in tests/test_design_service.py).
    """
    if not link_arr.size:
        return Categories(members={}, capacity={}, edge_capacity={})
    n_nodes = int(max(eu.max(), ev.max())) + 1
    code = eu * n_nodes + ev
    num_links = m * (m - 1)
    # Sort once by (edge, link); keys are unique after fusing, so the
    # default sort is deterministic. Pairs may repeat only for
    # non-simple hand-built paths; a unique pass collapses them (min
    # rank kept).
    if code.max() <= (2**62) // max(num_links, 1):
        order = np.argsort(code * num_links + link_arr)
    else:  # fused key would overflow int64: two-key lexsort
        order = np.lexsort((link_arr, code))
    code_s, link_s, rank_s = code[order], link_arr[order], rank[order]
    keep = np.ones(code_s.size, dtype=bool)
    keep[1:] = (code_s[1:] != code_s[:-1]) | (link_s[1:] != link_s[:-1])
    if not keep.all():
        first = np.flatnonzero(keep)
        seg_min = np.minimum.reduceat(rank_s, first)
        code_s, link_s, rank_s = code_s[first], link_s[first], seg_min
    # Segment per directed edge; edges ordered by first traversal.
    starts = np.flatnonzero(
        np.concatenate(([True], code_s[1:] != code_s[:-1]))
    )
    ends = np.concatenate((starts[1:], [code_s.size]))
    edge_order = np.argsort(np.minimum.reduceat(rank_s, starts))

    # Decode directed-link ids (i·(m−1) + j − [j>i]) to shared tuples:
    # itertools.product emits exactly the i-major (i, j) order with the
    # diagonal, which one object-array mask removes.
    grid = np.empty(m * m, dtype=object)
    grid[:] = list(itertools.product(range(m), repeat=2))
    link_obj = grid[~np.eye(m, dtype=bool).ravel()]
    # Per unique directed edge (in sorted-segment position): node pair
    # as Python ints, decoded in one vector pass.
    seg_code = code_s[starts]
    seg_u = (seg_code // n_nodes).tolist()
    seg_v = (seg_code % n_nodes).tolist()
    starts_l, ends_l = starts.tolist(), ends.tolist()

    fam_of_sig: dict[bytes, int] = {}
    fam_keys: list[frozenset] = []
    fam_members: list[list] = []
    fam_cap: list[float] = []
    fam_ids: list[np.ndarray] = []
    edge_capacity: dict[tuple[int, int], float] = {}
    for pos in edge_order.tolist():
        ids = link_s[starts_l[pos]:ends_l[pos]]
        sig = ids.tobytes()
        fi = fam_of_sig.get(sig)
        if fi is None:
            fi = len(fam_keys)
            fam_of_sig[sig] = fi
            fam_keys.append(frozenset(link_obj[ids].tolist()))
            fam_members.append([])
            fam_cap.append(np.inf)
            fam_ids.append(ids)
        edge = (seg_u[pos], seg_v[pos])
        cval = cap_of(*edge)
        fam_members[fi].append(edge)
        edge_capacity[edge] = cval
        fam_cap[fi] = min(fam_cap[fi], cval)

    # Precompile the capacity-independent CSR half of the incidence:
    # decode family-major ids ℓ = i·(m−1) + j − [j>i] to dense i·m + j,
    # then sort by the fused unique (link, family) key — the order the
    # reference compiler's stable by-link sort produces.
    all_ids = np.concatenate(fam_ids)
    li = all_ids // (m - 1)
    lj = all_ids % (m - 1)
    lj += lj >= li
    dense = li * m + lj
    nf = len(fam_keys)
    cat = np.repeat(
        np.arange(nf, dtype=np.int64),
        np.asarray([a.size for a in fam_ids], dtype=np.int64),
    )
    if dense.size and int(dense.max()) <= (2**62) // max(nf, 1):
        csr = np.argsort(dense * nf + cat)
    else:
        csr = np.lexsort((cat, dense))
    entry_link, entry_cat = dense[csr], cat[csr]
    flat = _FlatCategories(
        num_agents=m,
        num_categories=nf,
        entry_link=entry_link,
        entry_cat=entry_cat,
        link_ptr=np.concatenate(
            (
                np.zeros(1, dtype=np.int64),
                np.cumsum(
                    np.bincount(entry_link, minlength=m * m),
                    dtype=np.int64,
                ),
            )
        ),
    )
    return Categories(
        members={
            F: tuple(v) for F, v in zip(fam_keys, fam_members)
        },
        capacity=dict(zip(fam_keys, fam_cap)),
        edge_capacity=edge_capacity,
        flat=flat,
    )


def edge_category_index(categories: Categories) -> dict:
    """Directed member edge → tuple of family indices containing it.

    The lookup structure incremental capacity patching runs off: a
    ``LinkStateChange`` names underlay edges, and only the families that
    contain a changed edge need their C_F re-derived. Built once per
    category *structure* (membership epoch); capacity-only patches keep
    it valid because ``patch_categories_capacity`` never moves an edge
    between families.
    """
    index: dict[tuple[int, int], list[int]] = {}
    for fi, edges in enumerate(categories.members.values()):
        for e in edges:
            index.setdefault(e, []).append(fi)
    return {e: tuple(v) for e, v in index.items()}


def patch_categories_capacity(
    categories: Categories,
    changed: Mapping,
    edge_index: Mapping | None = None,
) -> "tuple[Categories, np.ndarray]":
    """Re-derive only the touched C_F after a per-edge capacity change.

    ``changed`` maps directed underlay edges (as stored in
    ``categories.edge_capacity``) to their new *absolute* effective
    capacities. Family structure is capacity-independent (routing is
    hop-count), so ``members``/``flat`` are shared unchanged and only the
    families containing a changed edge — found via ``edge_index``
    (``edge_category_index``; rebuilt here when not supplied) — get
    their bottleneck min re-derived, in stored member-edge order, which
    is the reference's traversal order. The result is bitwise-identical
    to ``compute_categories`` on the mutated underlay (property-tested),
    at O(changed members) instead of O(all pairs).

    Returns ``(patched, touched)`` where ``touched`` is the sorted int64
    array of re-derived family indices (what
    ``patch_category_incidence`` needs). Requires ground-truth
    categories (``compute_categories``); inferred categories withhold
    members/edge capacities and raise.
    """
    if categories.edge_capacity is None or not all(
        categories.members.values()
    ):
        raise ValueError(
            "capacity patching needs ground-truth members and edge "
            "capacities (compute_categories); inferred categories "
            "cannot re-derive per-family bottlenecks"
        )
    unknown = [e for e in changed if e not in categories.edge_capacity]
    if unknown:
        raise ValueError(
            f"changed edges {unknown[:4]} are not member edges of any "
            "category — non-traversed edges never constrain and need no "
            "patch (filter against edge_category_index first)"
        )
    if edge_index is None:
        edge_index = edge_category_index(categories)
    touched_set = {
        fi for e in changed for fi in edge_index.get(e, ())
    }
    touched = np.asarray(sorted(touched_set), dtype=np.int64)
    edge_capacity = dict(categories.edge_capacity)
    for e, c in changed.items():
        edge_capacity[e] = float(c)
    if any(edge_capacity[e] <= 0 for e in changed):
        raise ValueError("patched capacities must be positive")
    members = list(categories.members.items())
    capacity = dict(categories.capacity)
    for fi in touched.tolist():
        F, edges = members[fi]
        # Same incremental min, in member (= traversal) order, as the
        # from-scratch grouping loop.
        c = np.inf
        for e in edges:
            c = min(c, edge_capacity[e])
        capacity[F] = c
    return (
        Categories(
            members=categories.members,
            capacity=capacity,
            edge_capacity=edge_capacity,
            flat=categories.flat,  # capacity-independent, shared
        ),
        touched,
    )


def category_entry_order(
    incidence: CategoryIncidence,
) -> tuple[np.ndarray, np.ndarray]:
    """Category-major CSR view over an incidence's entry positions.

    Returns ``(order, ptr)``: ``order[ptr[F]:ptr[F+1]]`` are the entry
    positions of family F. Built once per structure epoch so
    ``patch_category_incidence`` touches exactly the entries of the
    families a capacity event changed instead of re-gathering all nnz.
    """
    order = np.argsort(incidence.entry_cat, kind="stable")
    ptr = np.concatenate(
        (
            np.zeros(1, dtype=np.int64),
            np.cumsum(
                np.bincount(
                    incidence.entry_cat,
                    minlength=incidence.num_categories,
                ),
                dtype=np.int64,
            ),
        )
    )
    return order, ptr


def patch_category_incidence(
    incidence: CategoryIncidence,
    categories: Categories,
    touched: np.ndarray,
    entry_index: tuple[np.ndarray, np.ndarray] | None = None,
) -> CategoryIncidence:
    """Patch an incidence in place of a full recompile after a
    capacity-only change.

    ``categories`` is the ``patch_categories_capacity`` output and
    ``touched`` its re-derived family indices: only those rows of
    ``capacity`` move, and only the entries belonging to them (located
    via ``entry_index`` from ``category_entry_order``; rebuilt here when
    not supplied) get their κ/C_F coefficient recomputed — the same
    elementwise float64 division the full compile performs, so the
    result is bitwise-identical to ``compile_category_incidence`` on the
    patched categories (property-tested). Runs through
    ``dataclasses.replace``, so the CSR contracts re-validate the
    patched structure under ``REPRO_VALIDATE=1``.
    """
    touched = np.asarray(touched, dtype=np.int64)
    caps = list(categories.capacity.values())
    if len(caps) != incidence.num_categories:
        raise ValueError(
            f"patched categories have {len(caps)} families, incidence "
            f"was compiled for {incidence.num_categories}"
        )
    if not touched.size:
        return dataclasses.replace(incidence, source=categories)
    cap = incidence.capacity.copy()
    cap[touched] = np.asarray(
        [caps[fi] for fi in touched.tolist()], dtype=np.float64
    )
    coef = incidence.entry_coef.copy()
    if entry_index is None:
        entry_index = category_entry_order(incidence)
    order, ptr = entry_index
    starts = ptr[touched]
    lens = ptr[touched + 1] - starts
    total = int(lens.sum())
    if total:
        cum = np.concatenate(([0], np.cumsum(lens)[:-1]))
        pos = order[
            np.arange(total) + np.repeat(starts - cum, lens)
        ]
        coef[pos] = incidence.kappa / cap[incidence.entry_cat[pos]]
    return dataclasses.replace(
        incidence, capacity=cap, entry_coef=coef, source=categories
    )


def infer_categories(
    overlay: OverlayNetwork,
    capacity_noise: float = 0.0,
    seed: int = 0,
) -> Categories:
    """Tomography-style estimate of (𝓕, C_F) available to the overlay.

    [17] shows the overlay can *consistently* estimate the nonempty
    categories and each category's bottleneck capacity from end-to-end
    measurements alone. We model the estimator's output: exact category
    structure, with optional multiplicative noise on capacities to stress
    designs against estimation error (``capacity_noise`` = relative std).
    Members are withheld — the overlay never learns which physical links
    form Γ_F, matching the information model of §III-A3.
    """
    truth = compute_categories(overlay)
    rng = np.random.default_rng(seed)
    cap = {}
    for F, c in truth.capacity.items():
        noise = 1.0 + capacity_noise * rng.standard_normal()
        # Clamp to a *relative* floor (1% of the true C_F): an absolute
        # epsilon floor would let a large negative noise draw shrink a
        # capacity by ~9 orders of magnitude, silently blowing up every
        # κ/C_F term and poisoning sweep comparisons. No consistent
        # tomography estimator is off by 100×; cap the modeled error
        # there and keep τ finite and sane.
        cap[F] = float(max(c * noise, 0.01 * c))
    return Categories(
        members={F: () for F in truth.capacity},
        capacity=cap,
        flat=truth.flat,  # same families: the incidence structure holds
    )
