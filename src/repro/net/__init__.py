"""Network substrate: underlay/overlay model, categories, routing, simulation."""

from repro.net.categories import (
    Categories,
    CategoryIncidence,
    compile_category_incidence,
    compute_categories,
    infer_categories,
)
from repro.net.demands import (
    MulticastDemand,
    activated_links_from_matrix,
    demands_from_links,
)
from repro.net.routing import (
    PhasedRoutingSolution,
    RoutingSolution,
    route,
    route_congestion_aware,
    route_direct,
    route_milp,
    route_time_expanded,
)
from repro.net.simulator import (
    BranchIncidence,
    CapacityPhase,
    CarryoverState,
    ChurnEvent,
    CrossTraffic,
    Scenario,
    SimResult,
    StragglerEvent,
    carryover_state,
    compile_incidence,
    lemma31_time,
    simulate,
    simulate_phased,
)
from repro.net.stochastic import (
    CorrelatedOutages,
    MarkovLinkModel,
    StochasticScenario,
)
from repro.net.topology import (
    MBPS,
    PAPER_MODEL_BYTES,
    OverlayNetwork,
    Underlay,
    build_overlay,
    dumbbell_underlay,
    grid_underlay,
    ici_torus_underlay,
    line_underlay,
    lowest_degree_nodes,
    mid_path_edges,
    random_geometric_underlay,
    roofnet_like,
)
