"""Overlay routing: minimize per-iteration communication time (paper §III-A).

Given multicast demands H (from the activated links of a mixing matrix),
choose for each flow h a directed Steiner tree in the overlay such that
the makespan under equal bandwidth sharing,

    τ(z) = max_{F ∈ 𝓕} (κ / C_F) · t_F(z),          (Lemma III.2, eq. 11)

is minimized, where t_F(z) counts activated unicast traversals of
category F's links. Two solvers:

  * ``route_milp``       — the paper's MILP (8) with category constraints
    (12), solved exactly by HiGHS (``scipy.optimize.milp``), including the
    Steiner-arborescence constraints (5d)-(5e).
  * ``route_congestion_aware`` — sequential cheapest-path Steiner insertion
    with exponential-potential re-routing; scales past MILP reach and is
    validated against the MILP on small instances. The default engine is
    vectorized: a precompiled ``CategoryIncidence`` (link×category CSR
    flat-entry arrays, the analogue of the simulator's
    ``BranchIncidence``) yields per-link costs in one ``bincount``, t_F
    loads and the completion time are maintained incrementally as numpy
    arrays on link add/remove, and each destination's cheapest path
    comes from a dense numpy Dijkstra whose relaxation is one vector op
    per settled node. The retained pure-Python original,
    ``_route_congestion_aware_reference``, is the ground truth the
    vectorized engine is property-tested against (identical trees on
    the same seed, hence τ_vec ≤ τ_ref).

``route`` picks MILP when the instance is small enough, else the
heuristic (the heuristic is skipped when the MILP proves optimality
within its budget), and always returns the better of
{solution, direct routing}; every candidate's completion time is kept in
``RoutingSolution.metadata["candidate_times"]``.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Iterable, Mapping, MutableMapping, Sequence

import numpy as np

from repro.net.categories import (
    Categories,
    CategoryIncidence,
    compile_category_incidence,
)
from repro.net.demands import MulticastDemand


@dataclasses.dataclass(frozen=True)
class RoutingSolution:
    """Per-flow directed Steiner trees + derived quantities.

    ``trees[h]`` is the set of directed overlay links used by flow h
    (z^h_{ij} = 1), guaranteed to connect ``demands[h].source`` to every
    destination. ``metadata`` carries solver debugging detail (candidate
    completion times, MILP status) and never affects equality/hashing.
    """

    demands: tuple[MulticastDemand, ...]
    trees: tuple[frozenset, ...]
    completion_time: float
    method: str
    solve_seconds: float
    metadata: Mapping | None = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def link_uses(self) -> dict[tuple[int, int], int]:
        """Σ_h z^h_{ij} per directed overlay link (input to t_F)."""
        uses: dict[tuple[int, int], int] = {}
        for tree in self.trees:
            for l in tree:
                uses[l] = uses.get(l, 0) + 1
        return uses

    def flow_rate(self, categories: Categories) -> float:
        """Equal-share optimal per-flow rate d_h ≡ min_F C_F / t_F."""
        uses = self.link_uses()
        t = categories.load_vector(uses)
        return min(
            (categories.capacity[F] / t[F] for F in t if t[F] > 0),
            default=math.inf,
        )

    def unicast_branches(
        self, overlay
    ) -> tuple[tuple[int, tuple[int, int], tuple[tuple[int, int], ...]], ...]:
        """Expand every flow tree into activated unicast branches.

        Each directed overlay link (i, j) in flow h's tree is an activated
        unicast flow carrying h's content over the underlay path p_{i,j}
        (paper Lemma III.1's definition). Returns
        ``(flow, (i, j), underlay_edge_path)`` triples; the enumeration
        order is shared by every simulator engine so their event arithmetic
        is comparable term by term.
        """
        out = []
        for h, tree in enumerate(self.trees):
            for (i, j) in tree:
                out.append((h, (i, j), overlay.path_edges(i, j)))
        return tuple(out)


def _tree_connects(
    tree: frozenset, demand: MulticastDemand, num_agents: int
) -> bool:
    """Check s_h reaches every k ∈ T_h along directed tree edges."""
    adj: dict[int, list[int]] = {}
    for i, j in tree:
        adj.setdefault(i, []).append(j)
    seen = {demand.source}
    stack = [demand.source]
    while stack:
        u = stack.pop()
        for v in adj.get(u, ()):  # BFS/DFS over directed edges
            if v not in seen:
                seen.add(v)
                stack.append(v)
    return demand.destinations <= seen


def validate_solution(sol: RoutingSolution, num_agents: int) -> None:
    for h, demand in enumerate(sol.demands):
        if not _tree_connects(sol.trees[h], demand, num_agents):
            raise ValueError(f"flow {h} tree does not span its destinations")


def completion_time(
    trees: Sequence[frozenset], categories: Categories, kappa: float
) -> float:
    uses: dict[tuple[int, int], int] = {}
    for tree in trees:
        for l in tree:
            uses[l] = uses.get(l, 0) + 1
    return categories.completion_time(uses, kappa)


# ---------------------------------------------------------------------------
# Direct (default-path) routing — the τ̄ upper bound of eq. (22)
# ---------------------------------------------------------------------------


def route_direct(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    kappa: float,
) -> RoutingSolution:
    """Route each branch on its default overlay link (underlay default path)."""
    t0 = time.perf_counter()
    trees = tuple(
        frozenset((d.source, k) for k in d.destinations) for d in demands
    )
    return RoutingSolution(
        demands=tuple(demands),
        trees=trees,
        completion_time=completion_time(trees, categories, kappa),
        method="direct",
        solve_seconds=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Exact MILP (paper eq. (8) with category constraints (12))
# ---------------------------------------------------------------------------


def route_milp(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    kappa: float,
    num_agents: int,
    time_limit: float = 120.0,
    sparsity_eps: float = 1e-6,
) -> RoutingSolution | None:
    """Solve the routing MILP exactly with HiGHS.

    Variables: τ; z^h_{ij} ∈ {0,1} per flow × directed link; r^{h,k}_{ij}
    ∈ {0,1} per flow × destination × directed link. Constraints (5d), (5e),
    (12). A tiny ε·Σz term breaks ties toward sparse trees (removes cycles
    that flow conservation alone permits). Returns None on failure.
    """
    from scipy import sparse
    from scipy.optimize import Bounds, LinearConstraint, milp

    t0 = time.perf_counter()
    m = num_agents
    links = [(i, j) for i in range(m) for j in range(m) if i != j]
    L = len(links)
    link_idx = {l: a for a, l in enumerate(links)}
    H = len(demands)
    dests = [sorted(d.destinations) for d in demands]

    # Variable layout: [τ] ++ z (H×L) ++ r (Σ_h |T_h| × L)
    n_z = H * L
    r_offsets = []
    off = 1 + n_z
    for h in range(H):
        r_offsets.append(off)
        off += len(dests[h]) * L
    n_var = off

    def zvar(h: int, l: int) -> int:
        return 1 + h * L + l

    def rvar(h: int, ki: int, l: int) -> int:
        return r_offsets[h] + ki * L + l

    rows, cols, vals, lo, hi = [], [], [], [], []
    row = 0

    def add(entries, lb, ub):
        nonlocal row
        for c, v in entries:
            rows.append(row)
            cols.append(c)
            vals.append(v)
        lo.append(lb)
        hi.append(ub)
        row += 1

    # (5d) flow conservation per (h, k, node i).
    for h, d in enumerate(demands):
        for ki, k in enumerate(dests[h]):
            for i in range(m):
                b = 1.0 if i == d.source else (-1.0 if i == k else 0.0)
                entries = []
                for j in range(m):
                    if j == i:
                        continue
                    entries.append((rvar(h, ki, link_idx[(i, j)]), 1.0))
                    entries.append((rvar(h, ki, link_idx[(j, i)]), -1.0))
                add(entries, b, b)

    # (5e) r ≤ z.
    for h in range(H):
        for ki in range(len(dests[h])):
            for l in range(L):
                add([(rvar(h, ki, l), 1.0), (zvar(h, l), -1.0)], -np.inf, 0.0)

    # (12) τ ≥ (κ/C_F)·Σ_{(i,j)∈F} Σ_h z^h_{ij}.
    for F in categories.families:
        coef = kappa / categories.capacity[F]
        entries = [(0, 1.0)]
        for l_dir in F:
            if l_dir in link_idx:
                for h in range(H):
                    entries.append((zvar(h, link_idx[l_dir]), -coef))
        add(entries, 0.0, np.inf)

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(row, n_var))
    c = np.full(n_var, 0.0)
    c[0] = 1.0
    c[1 : 1 + n_z] = sparsity_eps  # tie-break toward sparse trees
    integrality = np.ones(n_var)
    integrality[0] = 0
    lb = np.zeros(n_var)
    ub = np.ones(n_var)
    ub[0] = np.inf

    try:
        res = milp(
            c=c,
            constraints=LinearConstraint(A, lo, hi),
            integrality=integrality,
            bounds=Bounds(lb, ub),
            options={"time_limit": time_limit, "presolve": True},
        )
    except Exception:
        return None
    if res.x is None:
        return None

    trees = []
    for h in range(H):
        z = res.x[1 + h * L : 1 + (h + 1) * L]
        tree = frozenset(links[a] for a in range(L) if z[a] > 0.5)
        trees.append(_prune_tree(tree, demands[h]))
    trees = tuple(trees)
    return RoutingSolution(
        demands=tuple(demands),
        trees=trees,
        completion_time=completion_time(trees, categories, kappa),
        method="milp",
        solve_seconds=time.perf_counter() - t0,
        metadata={"milp_status": int(res.status)},
    )


def _prune_tree(tree: frozenset, demand: MulticastDemand) -> frozenset:
    """Drop edges not on any source→destination directed path."""
    adj: dict[int, list[int]] = {}
    for i, j in tree:
        adj.setdefault(i, []).append(j)
    # Keep edges reachable from source AND from which a destination is
    # reachable. Compute reach-from-source and co-reach-to-dests.
    reach = {demand.source}
    stack = [demand.source]
    while stack:
        u = stack.pop()
        for v in adj.get(u, ()):
            if v not in reach:
                reach.add(v)
                stack.append(v)
    radj: dict[int, list[int]] = {}
    for i, j in tree:
        radj.setdefault(j, []).append(i)
    coreach = set(demand.destinations)
    stack = list(demand.destinations)
    while stack:
        u = stack.pop()
        for v in radj.get(u, ()):
            if v not in coreach:
                coreach.add(v)
                stack.append(v)
    return frozenset(
        (i, j) for (i, j) in tree if i in reach and j in coreach
    )


# ---------------------------------------------------------------------------
# Congestion-aware heuristic (exponential potential, cheapest-path Steiner)
# ---------------------------------------------------------------------------


def _link_category_costs(
    categories: Categories, num_agents: int, kappa: float
) -> dict[tuple[int, int], list[tuple[int, float]]]:
    """Per directed overlay link: [(category index, κ/C_F), ...]."""
    fams = categories.families
    out: dict[tuple[int, int], list[tuple[int, float]]] = {}
    for fi, F in enumerate(fams):
        coef = kappa / categories.capacity[F]
        for l in F:
            out.setdefault(l, []).append((fi, coef))
    return out


def _route_congestion_aware_reference(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    kappa: float,
    num_agents: int,
    rounds: int = 8,
    seed: int = 0,
) -> RoutingSolution:
    """Pure-Python congestion-aware routing (retained ground truth).

    The original O(m²)-per-destination dict-loop implementation. Kept —
    like the simulator's reference engine — as the oracle the vectorized
    ``route_congestion_aware`` is property-tested against: on the same
    seed both engines must produce identical trees.
    """
    t0 = time.perf_counter()
    m = num_agents
    rng = np.random.default_rng(seed)
    fams = categories.families
    nF = len(fams)
    cat_cost = _link_category_costs(categories, m, kappa)
    cap = np.array(
        [categories.capacity[F] for F in fams], dtype=np.float64
    )

    # t_F loads, maintained incrementally.
    loads = np.zeros(nF)
    trees: list[set] = [set() for _ in demands]
    # link -> category indices + coefs as arrays for speed
    link_cats = {
        l: (np.array([fi for fi, _ in cc], dtype=np.int64),)
        for l, cc in cat_cost.items()
    }

    def add_link(h: int, l: tuple[int, int]) -> None:
        if l not in trees[h]:
            trees[h].add(l)
            idx = link_cats.get(l)
            if idx is not None:
                loads[idx[0]] += 1

    def remove_flow(h: int) -> None:
        for l in trees[h]:
            idx = link_cats.get(l)
            if idx is not None:
                loads[idx[0]] -= 1
        trees[h].clear()

    def route_flow(h: int, theta: float) -> None:
        d = demands[h]
        # Utilization per category (seconds) under current loads.
        util = kappa * loads / cap
        peak = max(util.max(), 1e-12)
        w = np.exp(theta * (util / peak))  # bounded exponent
        for k in sorted(d.destinations, key=lambda _: rng.random()):
            # Dijkstra from source over directed links; links already in
            # tree are free (shared multicast traffic).
            dist = np.full(m, np.inf)
            prev = np.full(m, -1, dtype=np.int64)
            dist[d.source] = 0.0
            done = np.zeros(m, dtype=bool)
            for _ in range(m):
                u = int(np.argmin(np.where(done, np.inf, dist)))
                if done[u] or not np.isfinite(dist[u]):
                    break
                done[u] = True
                for v in range(m):
                    if v == u:
                        continue
                    l = (u, v)
                    if l in trees[h]:
                        c = 0.0
                    else:
                        cc = cat_cost.get(l, ())
                        c = sum(
                            coef * w[fi] for fi, coef in cc
                        ) + 1e-12  # strictly positive off-tree
                    if dist[u] + c < dist[v]:
                        dist[v] = dist[u] + c
                        prev[v] = u
            # Walk back from k, adding links.
            node = k
            chain = []
            while node != d.source and prev[node] >= 0:
                chain.append((int(prev[node]), int(node)))
                node = int(prev[node])
            if node != d.source:
                # Unreachable (should not happen on a full overlay): direct.
                chain = [(d.source, k)]
            for l in chain:
                add_link(h, l)

    best_trees: tuple[frozenset, ...] | None = None
    best_tau = math.inf

    # Initial: direct routing.
    for h, d in enumerate(demands):
        for k in d.destinations:
            add_link(h, (d.source, k))

    order = list(range(len(demands)))
    for rnd in range(rounds):
        theta = 2.0 + 3.0 * rnd  # anneal toward harder bottleneck avoidance
        rng.shuffle(order)
        for h in order:
            remove_flow(h)
            route_flow(h, theta)
        tau = completion_time([frozenset(t) for t in trees], categories, kappa)
        if tau < best_tau - 1e-15:
            best_tau = tau
            best_trees = tuple(frozenset(t) for t in trees)

    assert best_trees is not None
    return RoutingSolution(
        demands=tuple(demands),
        trees=best_trees,
        completion_time=best_tau,
        method="congestion_aware_reference",
        solve_seconds=time.perf_counter() - t0,
    )


def route_congestion_aware(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    kappa: float,
    num_agents: int,
    rounds: int = 8,
    seed: int = 0,
    incidence: CategoryIncidence | None = None,
) -> RoutingSolution:
    """Potential-based multicast routing (scales beyond the MILP).

    Each flow's tree is built by *cheapest-path Steiner insertion*: route
    to destinations one at a time over link costs that (a) are zero for
    links already in the flow's tree (multicast branches share traffic)
    and (b) grow exponentially with category utilization, so bottleneck
    categories repel new flows. Several re-routing rounds with annealed
    temperature; the best τ seen wins.

    Vectorized engine: per-link costs come from one ``bincount`` over the
    precompiled ``CategoryIncidence`` flat entries (pass ``incidence`` to
    amortize compilation across calls, e.g. over a design-sweep grid),
    t_F loads update incrementally on link add/remove, the per-round τ is
    read straight off the load vector, and each destination's cheapest
    path is a dense numpy Dijkstra (one vectorized relaxation per settled
    node, early exit at the destination). Produces trees identical to
    ``_route_congestion_aware_reference`` on the same seed: the RNG draw
    sequence, cost arithmetic (same per-link summation order), argmin
    tie-breaks, and annealing schedule are replicated exactly.
    """
    t0 = time.perf_counter()
    m = num_agents
    rng = np.random.default_rng(seed)
    inc = (
        incidence
        if incidence is not None
        else compile_category_incidence(categories, m, kappa)
    )
    if inc.num_agents != m or inc.kappa != kappa:
        raise ValueError(
            f"incidence compiled for (m={inc.num_agents}, κ={inc.kappa}), "
            f"got (m={m}, κ={kappa})"
        )
    if not inc.matches(categories):
        raise ValueError(
            "incidence was compiled from different categories; recompile "
            "with compile_category_incidence(categories, m, kappa)"
        )
    cap = inc.capacity
    ecat, eptr = inc.entry_cat, inc.link_ptr

    # t_F loads, maintained incrementally (integer-valued float64).
    loads = np.zeros(inc.num_categories)
    trees: list[set] = [set() for _ in demands]

    def add_link(h: int, l: tuple[int, int]) -> None:
        if l not in trees[h]:
            trees[h].add(l)
            a = l[0] * m + l[1]
            loads[ecat[eptr[a]:eptr[a + 1]]] += 1.0

    def remove_flow(h: int) -> None:
        for (i, j) in trees[h]:
            a = i * m + j
            loads[ecat[eptr[a]:eptr[a + 1]]] -= 1.0
        trees[h].clear()

    def route_flow(h: int, theta: float) -> None:
        d = demands[h]
        # Utilization per category (seconds) under current loads.
        util = kappa * loads / cap
        peak = max(util.max(), 1e-12) if util.size else 1e-12
        w = np.exp(theta * (util / peak))  # bounded exponent
        # Off-tree link costs: one bincount over the flat entries, plus
        # the reference's strictly-positive 1e-12 floor.
        cost = (inc.link_costs(w) + 1e-12).reshape(m, m)
        np.fill_diagonal(cost, np.inf)
        # trees[h] is empty here (route_flow always follows remove_flow);
        # in-tree links become free via the chain zeroing below.
        for k in sorted(d.destinations, key=lambda _: rng.random()):
            # Dense Dijkstra from the source: one vectorized relaxation
            # per settled node. ``work`` is dist with settled nodes
            # masked to inf, so argmin doubles as the frontier pop.
            dist = np.full(m, np.inf)
            dist[d.source] = 0.0
            work = dist.copy()
            prev = np.full(m, -1, dtype=np.int64)
            for _ in range(m):
                u = int(np.argmin(work))
                if not np.isfinite(work[u]):
                    break
                work[u] = np.inf
                if u == k:
                    break  # dist/prev along k's chain are already final
                cand = dist[u] + cost[u]
                upd = cand < dist  # settled nodes can never improve
                if upd.any():
                    dist[upd] = cand[upd]
                    work[upd] = cand[upd]
                    prev[upd] = u
            # Walk back from k, adding links (free for later siblings).
            node = k
            chain = []
            while node != d.source and prev[node] >= 0:
                chain.append((int(prev[node]), int(node)))
                node = int(prev[node])
            if node != d.source:
                # Unreachable (should not happen on a full overlay): direct.
                chain = [(d.source, k)]
            for l in chain:
                add_link(h, l)
                cost[l] = 0.0

    best_trees: tuple[frozenset, ...] | None = None
    best_tau = math.inf

    # Initial: direct routing.
    for h, d in enumerate(demands):
        for k in d.destinations:
            add_link(h, (d.source, k))

    order = list(range(len(demands)))
    for rnd in range(rounds):
        theta = 2.0 + 3.0 * rnd  # anneal toward harder bottleneck avoidance
        rng.shuffle(order)
        for h in order:
            remove_flow(h)
            route_flow(h, theta)
        # Incremental completion time: read τ off the maintained loads
        # instead of rebuilding the link-uses dict from every tree.
        tau = inc.completion_time(loads)
        if tau < best_tau - 1e-15:
            best_tau = tau
            best_trees = tuple(frozenset(t) for t in trees)

    assert best_trees is not None
    return RoutingSolution(
        demands=tuple(demands),
        trees=best_trees,
        completion_time=best_tau,
        method="congestion_aware",
        solve_seconds=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# Front door
# ---------------------------------------------------------------------------


def route(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    kappa: float,
    num_agents: int,
    milp_var_budget: int = 40_000,
    time_limit: float = 60.0,
    seed: int = 0,
    incidence: CategoryIncidence | None = None,
    heuristic_rounds: int = 8,
) -> RoutingSolution:
    """Best-effort optimal routing.

    Uses the exact MILP when the variable count is within budget, else the
    congestion-aware heuristic; always returns the best of the candidate
    solutions (never worse than direct routing — paper footnote 6). When
    the MILP covers the instance and proves optimality within its time
    limit, the (then redundant) heuristic is skipped entirely. Every
    candidate's completion time lands in
    ``metadata["candidate_times"]`` for debugging; ``incidence`` (a
    precompiled ``CategoryIncidence``) and ``heuristic_rounds`` tune the
    heuristic for repeated calls, e.g. across a design-sweep grid.
    """
    if not demands:
        return RoutingSolution(
            demands=(), trees=(), completion_time=0.0, method="empty",
            solve_seconds=0.0, metadata={"candidate_times": {}},
        )
    m = num_agents
    L = m * (m - 1)
    n_r = sum(len(d.destinations) for d in demands) * L
    n_var = 1 + len(demands) * L + n_r

    candidates = [route_direct(demands, categories, kappa)]
    milp_sol = None
    if n_var <= milp_var_budget:
        milp_sol = route_milp(
            demands, categories, kappa, m, time_limit=time_limit
        )
        if milp_sol is not None:
            candidates.append(milp_sol)
    milp_optimal = (
        milp_sol is not None
        and milp_sol.metadata is not None
        and milp_sol.metadata.get("milp_status") == 0  # HiGHS: proven opt
    )
    if not milp_optimal:
        candidates.append(
            route_congestion_aware(
                demands, categories, kappa, m, rounds=heuristic_rounds,
                seed=seed, incidence=incidence,
            )
        )
    best = min(candidates, key=lambda s: s.completion_time)
    validate_solution(best, m)
    meta = dict(best.metadata or {})
    meta["candidate_times"] = {
        s.method: s.completion_time for s in candidates
    }
    return dataclasses.replace(best, metadata=meta)


# ---------------------------------------------------------------------------
# Phase-adaptive (time-expanded) routing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PhasedRoutingSolution:
    """One routing per capacity phase plus the breakpoint schedule.

    ``solutions[k]`` is the routing used on ``[boundaries[k],
    boundaries[k+1])`` (the last segment runs to ∞); ``boundaries[0]``
    is always 0.0. Segment 0 routes against the base categories, so on
    a trivial scenario the whole object degenerates to the static
    ``route()`` answer (bitwise — property-tested).
    ``completion_time`` is segment 0's closed-form τ (the static value
    if phase 0 capacities held forever); the exact phased makespan
    comes from ``repro.net.simulate_phased``.
    """

    demands: tuple[MulticastDemand, ...]
    boundaries: tuple[float, ...]
    solutions: tuple["RoutingSolution", ...]
    completion_time: float
    method: str
    solve_seconds: float
    metadata: Mapping | None = dataclasses.field(
        default=None, compare=False, repr=False
    )

    def __post_init__(self):
        if len(self.boundaries) != len(self.solutions):
            raise ValueError("one routing solution per boundary required")
        if not self.boundaries or self.boundaries[0] != 0.0:
            raise ValueError("first segment must start at t=0")
        if any(
            b <= a for a, b in zip(self.boundaries, self.boundaries[1:])
        ):
            raise ValueError("boundaries must be strictly increasing")

    @property
    def num_segments(self) -> int:
        return len(self.solutions)

    @property
    def is_static(self) -> bool:
        """True when every segment reuses segment 0's trees."""
        return all(s.trees == self.solutions[0].trees for s in self.solutions)

    def active_solution(self, t: float) -> "RoutingSolution":
        """The routing in force at time ``t`` (piecewise-constant)."""
        k = 0
        for seg, start in enumerate(self.boundaries):
            if start <= t:
                k = seg
        return self.solutions[k]


def _phase_segments(scenario) -> list[tuple[float, object]]:
    """(start, scale) per routing segment from a scenario's capacity
    phases: segment 0 covers t=0 under the latest phase with start ≤ 0
    (base scale 1.0 if none); every later phase start opens a segment.
    Consecutive segments with identical scales are merged."""
    by_start: dict[float, object] = {0.0: 1.0}
    for ph in sorted(scenario.capacity_phases, key=lambda p: p.start):
        # Duplicate starts: the last phase in sorted order wins, matching
        # the simulator's event loop (it applies every phase with
        # start <= t in order, so the final one sticks).
        by_start[max(float(ph.start), 0.0)] = ph.scale
    segs = sorted(by_start.items())
    merged = [segs[0]]
    for start, scale in segs[1:]:
        if _scale_key(scale) != _scale_key(merged[-1][1]):
            merged.append((start, scale))
    return merged


def _scale_key(scale) -> object:
    """Hashable fingerprint of a CapacityPhase scale (for caching)."""
    if isinstance(scale, Mapping):
        return tuple(
            sorted((tuple(e), float(f)) for e, f in scale.items())
        )
    return float(scale)


def _carryover_completion_time(
    trees: Sequence[frozenset],
    demands: Sequence[MulticastDemand],
    categories: Categories,
    state,
) -> float:
    """Remaining completion time of ``trees`` given realized per-branch
    state — the carryover-aware segment objective of online re-routing.

    Generalizes Lemma III.2's τ to heterogeneous residual volumes: per
    category F the bottleneck must still carry
    Σ_{(i,j)∈F} Σ_h v_{h,(i,j)} bytes, where a branch's volume v is the
    flow's full size for a *fresh* overlay link (the restart cost a
    swap incurs — mid-flight data on abandoned links is lost), the
    carried remainder for a surviving in-flight link, and 0 for a
    branch that already finished. Branches of delivered flows,
    cancelled branches, and branches touching departed agents carry
    nothing. ``state`` is a ``repro.net.simulator.CarryoverState``.
    """
    departed = set(state.departed)
    vol: dict[tuple[int, int], float] = {}
    for h, tree in enumerate(trees):
        if not math.isnan(state.flow_done[h]):
            continue  # flow already delivered everywhere it could
        if demands[h].source in departed:
            continue  # nothing left to send; churn cancelled the flow
        for (i, j) in tree:
            key = (h, i, j)
            if key in state.cancelled or i in departed or j in departed:
                continue
            v = state.remaining.get(key)
            if v is None:
                v = 0.0 if key in state.done else float(demands[h].size)
            if v > 0.0:
                vol[(i, j)] = vol.get((i, j), 0.0) + v
    if not vol:
        return 0.0
    return max(
        (
            sum(vol.get(l, 0.0) for l in F) / categories.capacity[F]
            for F in categories.families
        ),
        default=0.0,
    )


def route_time_expanded(
    demands: Sequence[MulticastDemand],
    categories: Categories,
    scenario,
    kappa: float,
    num_agents: int,
    milp_var_budget: int = 40_000,
    time_limit: float = 60.0,
    seed: int = 0,
    incidence: CategoryIncidence | None = None,
    heuristic_rounds: int = 8,
    routing_cache: "MutableMapping | None" = None,
    cache_key=None,
    base_solution: "RoutingSolution | None" = None,
    online: bool = False,
    overlay=None,
) -> PhasedRoutingSolution:
    """Time-expanded routing: one ``route()`` per capacity phase.

    The scenario's piecewise-constant ``capacity_phases`` partition time
    into segments; each segment is routed against the phase-scaled
    categories (``Categories.scaled``), so the schedule tracks where the
    bottlenecks actually are in each phase instead of optimizing once
    for capacities that stop being true at the first boundary. Segments
    with equal scales share one solution, and ``routing_cache`` (with a
    ``cache_key`` identifying the demand set, e.g. the activated-link
    frozenset) memoizes per-(demands, scale) across calls — a design
    sweep rarely re-routes. ``incidence`` is rescaled per phase
    (``CategoryIncidence.rescaled``) rather than recompiled, and
    ``base_solution`` (a static ``route()`` result the caller already
    holds) is reused for unscaled segments instead of being re-solved.

    Re-routing is guarded against pointless swaps: a segment only
    abandons the previous segment's trees when the re-route is
    *strictly* better in closed form under the new phase's categories.
    Swapping restarts the branches on fresh overlay links from zero
    (mid-flight data on abandoned links is lost), so a zero-predicted-
    gain swap can only cost time.

    On a trivial scenario (no capacity phases) this returns a single
    segment that is bitwise-identical to static ``route()`` with the
    same arguments. ``metadata['routed_segments']`` counts the segments
    actually solved this call (vs. served from the cache).

    ``online=True`` switches to *observed-state* re-routing (requires
    ``overlay``): the scenario is a realized sample (e.g. from
    ``StochasticScenario.sample``) that the router pretends to discover
    phase by phase — at each boundary it sees only the capacities
    realized so far (``carryover_state`` simulates the committed prefix,
    which applies no condition beyond the boundary, so there is no
    lookahead), and the keep-vs-switch decision uses the
    *carryover-aware* objective ``_carryover_completion_time``: the
    restart cost of abandoning in-flight links (their volume restarts
    from full κ) is charged explicitly instead of the offline swap
    guard's full-volume closed form. A switch happens only when it is
    strictly better under that objective; ``metadata['reroutes']``
    counts the boundaries that actually switched trees. Each decision
    re-simulates the committed prefix from t=0 (O(boundaries²) segment
    events per realization — fine for the diurnal-scale scenarios the
    benchmarks use; an incremental-resume snapshot is the known
    optimization if realizations ever have hundreds of distinct-tree
    boundaries).
    """
    t0 = time.perf_counter()
    if online and overlay is None:
        raise ValueError(
            "online re-routing needs the overlay to snapshot realized "
            "state (carryover_state)"
        )
    segs = _phase_segments(scenario)
    boundaries = tuple(start for start, _ in segs)
    solutions: list[RoutingSolution] = []
    by_scale: dict = {}
    routed = 0
    reroutes = 0
    for si, (start, scale) in enumerate(segs):
        key = _scale_key(scale)
        seg_cats = categories.scaled(scale)
        # The raw per-scale solution is what gets cached; the swap guard
        # below is applied per call (its outcome depends on the previous
        # segment, which differs between phase sequences).
        sol = by_scale.get(key)
        if sol is None and routing_cache is not None and cache_key is not None:
            sol = routing_cache.get((cache_key, key))
        if sol is None and base_solution is not None and seg_cats is categories:
            # A caller that already solved the static routing (the base,
            # unscaled categories) supplies it — segment 0 of a no-
            # phase-at-t≤0 schedule would otherwise re-solve it bitwise.
            sol = base_solution
        if sol is None:
            seg_inc = None
            if incidence is not None:
                seg_inc = (
                    incidence if seg_cats is categories
                    else incidence.rescaled(seg_cats)
                )
            sol = route(
                demands, seg_cats, kappa, num_agents,
                milp_var_budget=milp_var_budget, time_limit=time_limit,
                seed=seed, incidence=seg_inc,
                heuristic_rounds=heuristic_rounds,
            )
            routed += 1
        by_scale[key] = sol
        if routing_cache is not None and cache_key is not None:
            routing_cache[(cache_key, key)] = sol
        if solutions:
            prev = solutions[-1]
            if sol is prev or sol.trees == prev.trees:
                sol = prev  # same trees: never a swap, share the object
            elif online:
                # Observed-state decision: simulate the committed
                # schedule up to this boundary (no condition beyond it
                # is applied — no lookahead) and compare the carryover-
                # aware remaining times. Switching charges the restart
                # of every in-flight branch whose link the new trees
                # abandon; keeping charges only the carried remainders.
                from repro.net.simulator import carryover_state

                prefix = PhasedRoutingSolution(
                    demands=tuple(demands),
                    boundaries=boundaries[:si],
                    solutions=tuple(solutions),
                    completion_time=solutions[0].completion_time,
                    method="online_prefix",
                    solve_seconds=0.0,
                )
                state = carryover_state(
                    prefix, overlay, start, scenario=scenario
                )
                t_keep = _carryover_completion_time(
                    prev.trees, demands, seg_cats, state
                )
                t_switch = _carryover_completion_time(
                    sol.trees, demands, seg_cats, state
                )
                if t_switch < t_keep:
                    reroutes += 1
                else:
                    sol = prev
            else:
                # Offline swap guard: keep the in-flight trees unless
                # the re-route strictly improves the closed-form τ
                # under this phase's capacities.
                if (
                    completion_time(prev.trees, seg_cats, kappa)
                    <= sol.completion_time
                ):
                    sol = prev
                else:
                    reroutes += 1
        solutions.append(sol)
    return PhasedRoutingSolution(
        demands=tuple(demands),
        boundaries=boundaries,
        solutions=tuple(solutions),
        completion_time=solutions[0].completion_time,
        method="time_expanded_online" if online else "time_expanded",
        solve_seconds=time.perf_counter() - t0,
        metadata={
            "segment_times": tuple(s.completion_time for s in solutions),
            "segment_methods": tuple(s.method for s in solutions),
            "routed_segments": routed,
            "reroutes": reroutes,
        },
    )
