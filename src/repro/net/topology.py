"""Underlay / overlay network model.

The *underlay* is the physical communication network (e.g. a WiFi mesh);
the *overlay* is the logical network formed by the learning agents, where
each overlay link (i, j) is realized by an underlay routing path p_{i,j}.

Conventions
-----------
* Underlay nodes are integers (networkx node ids).
* Agents are referenced by **index** 0..m-1 in all algorithm-facing code;
  ``OverlayNetwork.agents[idx]`` maps back to the underlay node id.
* Overlay links are unordered pairs ``(i, j)`` with ``i < j`` of agent
  indices. Directed overlay links are ordered pairs ``(i, j)``, i != j.
* Underlay links are undirected with symmetric capacity ``capacity``
  (bytes/second); each *direction* has the full capacity (paper §II-B).
* Routing paths are symmetric: ``p[i,j] == reversed(p[j,i])``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Mapping, Sequence

import networkx as nx
import numpy as np

# 1 Mbps in bytes/second (Roofnet data rate, paper §IV-A2).
MBPS = 125_000.0

# ResNet-50 model size used in the paper (94.47 MB), bytes.
PAPER_MODEL_BYTES = 94.47e6


@dataclasses.dataclass(frozen=True)
class Underlay:
    """Physical network: an undirected capacitated graph."""

    graph: nx.Graph

    @property
    def num_nodes(self) -> int:
        return self.graph.number_of_nodes()

    @property
    def num_links(self) -> int:
        return self.graph.number_of_edges()

    def capacity(self, u: int, v: int) -> float:
        return float(self.graph.edges[u, v]["capacity"])

    def shortest_path(self, src: int, dst: int) -> tuple[int, ...]:
        """Hop-count shortest path (paper assumes hop-count routing)."""
        return tuple(nx.shortest_path(self.graph, src, dst))

    def directed_capacities(self) -> dict[tuple[int, int], float]:
        """Capacity per *directed* underlay edge (each direction full)."""
        caps: dict[tuple[int, int], float] = {}
        for u, v, data in self.graph.edges(data=True):
            caps[(u, v)] = float(data["capacity"])
            caps[(v, u)] = float(data["capacity"])
        return caps

    def with_scaled_capacities(
        self, scale: float | Mapping[tuple[int, int], float]
    ) -> "Underlay":
        """New underlay with capacities multiplied by ``scale``.

        ``scale`` is a global factor or a per-undirected-edge map (either
        key order accepted; missing edges keep factor 1.0). Used to build
        statically degraded networks for scenario pricing.
        """
        g = self.graph.copy()
        for u, v, data in g.edges(data=True):
            if isinstance(scale, Mapping):
                f = scale.get((u, v), scale.get((v, u), 1.0))
            else:
                f = scale
            data["capacity"] = float(data["capacity"]) * float(f)
        out = Underlay(graph=g)
        out.validate()
        return out

    def validate(self) -> None:
        if not nx.is_connected(self.graph):
            raise ValueError("underlay must be connected")
        for u, v, data in self.graph.edges(data=True):
            if data.get("capacity", 0) <= 0:
                raise ValueError(f"link ({u},{v}) has non-positive capacity")


def _path_edges_directed(path: Sequence[int]) -> tuple[tuple[int, int], ...]:
    """Directed underlay edges along a node path."""
    return tuple((path[k], path[k + 1]) for k in range(len(path) - 1))


@dataclasses.dataclass(frozen=True)
class OverlayNetwork:
    """Overlay of m agents atop an underlay, with fixed symmetric routing.

    ``paths[(i, j)]`` (agent indices, any order) is the underlay node path
    from agent i's node to agent j's node.
    """

    underlay: Underlay
    agents: tuple[int, ...]  # agent index -> underlay node id
    paths: Mapping[tuple[int, int], tuple[int, ...]]

    @property
    def num_agents(self) -> int:
        return len(self.agents)

    @property
    def overlay_links(self) -> tuple[tuple[int, int], ...]:
        """All undirected overlay links (full overlay), i < j, agent indices."""
        m = self.num_agents
        return tuple((i, j) for i in range(m) for j in range(i + 1, m))

    @property
    def directed_overlay_links(self) -> tuple[tuple[int, int], ...]:
        m = self.num_agents
        return tuple((i, j) for i in range(m) for j in range(m) if i != j)

    def path(self, i: int, j: int) -> tuple[int, ...]:
        """Underlay node path for directed overlay link i -> j."""
        if (i, j) in self.paths:
            return self.paths[(i, j)]
        return tuple(reversed(self.paths[(j, i)]))

    def path_edges(self, i: int, j: int) -> tuple[tuple[int, int], ...]:
        """Directed underlay edges traversed by directed overlay link i->j."""
        return _path_edges_directed(self.path(i, j))

    def propagation_delay(self, i: int, j: int) -> float:
        """Edge networks: negligible propagation delay (paper §III-A2)."""
        return 0.0

    def batched_path_edges(
        self,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """All (overlay-link, underlay-edge) incidence pairs as flat arrays.

        Returns ``(link, u, v, rank)`` int64 arrays with one row per
        directed underlay edge ``(u, v)`` traversed by a directed overlay
        link: ``link`` indexes ``directed_overlay_links`` (i-major order,
        ``i·(m−1) + j − [j > i]``), and ``rank`` is a strictly increasing
        key along each link's path and across links in that order —
        ``argsort(rank)`` recovers the exact per-hop traversal order a
        ``for (i, j) in directed_overlay_links: for e in path_edges(i, j)``
        double loop would visit. Rows are *emitted* batched by path
        length (each bucket is one stacked-matrix slice), not in
        traversal order; consumers that need order sort by ``rank``.

        This is the array replacement for the per-link ``path_edges``
        loop: the Python work is O(#pairs) dict lookups plus a few dozen
        per-length batches, while the per-hop work is numpy.
        """
        m = self.num_agents
        empty = np.empty(0, dtype=np.int64)
        if m < 2:
            return empty, empty, empty, empty
        # Bucket the m(m−1)/2 stored paths by length so each bucket
        # vectorizes as one [n, k+1] node matrix. When the paths mapping
        # holds exactly one entry per unordered pair (any key order),
        # iterate it directly; otherwise walk the pairs through
        # ``path()`` (which resolves reversed keys).
        by_len: dict[int, tuple[list, list, list]] = {}
        if len(self.paths) == m * (m - 1) // 2:
            for (a, b_), p in self.paths.items():
                if a > b_:
                    a, b_, p = b_, a, tuple(reversed(p))
                b = by_len.get(len(p))
                if b is None:
                    b = by_len[len(p)] = ([], [], [])
                b[0].append(a)
                b[1].append(b_)
                b[2].append(p)
        else:
            for i in range(m):
                for j in range(i + 1, m):
                    p = self.path(i, j)
                    b = by_len.get(len(p))
                    if b is None:
                        b = by_len[len(p)] = ([], [], [])
                    b[0].append(i)
                    b[1].append(j)
                    b[2].append(p)
        stride = max(by_len) - 1  # ≥ every path's edge count
        links, us, vs, ranks = [], [], [], []
        for npath, (ilist, jlist, plist) in sorted(by_len.items()):
            k = npath - 1
            if k <= 0:
                continue  # duplicate placement is rejected by validate()
            nodes = np.asarray(plist, dtype=np.int64)  # [n, k+1]
            li = np.asarray(ilist, dtype=np.int64)
            lj = np.asarray(jlist, dtype=np.int64)
            t = np.arange(k, dtype=np.int64)
            # Forward direction i→j: edges (p_t, p_{t+1}) in path order.
            lf = li * (m - 1) + lj - 1  # j > i
            links.append(np.repeat(lf, k))
            us.append(nodes[:, :-1].ravel())
            vs.append(nodes[:, 1:].ravel())
            ranks.append((lf[:, None] * stride + t).ravel())
            # Reverse direction j→i traverses the reversed node path.
            lr = lj * (m - 1) + li  # i < j
            links.append(np.repeat(lr, k))
            us.append(nodes[:, :0:-1].ravel())
            vs.append(nodes[:, -2::-1].ravel())
            ranks.append((lr[:, None] * stride + t).ravel())
        if not links:
            return empty, empty, empty, empty
        return (
            np.concatenate(links),
            np.concatenate(us),
            np.concatenate(vs),
            np.concatenate(ranks),
        )

    def validate(self) -> None:
        self.underlay.validate()
        if len(set(self.agents)) != len(self.agents):
            raise ValueError("duplicate agent placement")
        for i, j in self.overlay_links:
            p = self.path(i, j)
            if p[0] != self.agents[i] or p[-1] != self.agents[j]:
                raise ValueError(f"path for ({i},{j}) has wrong endpoints")
            rev = self.path(j, i)
            if tuple(reversed(rev)) != p:
                raise ValueError(f"asymmetric path for ({i},{j})")


def build_overlay(
    underlay: Underlay, agent_nodes: Sequence[int], method: str = "pairwise"
) -> OverlayNetwork:
    """Place agents on ``agent_nodes`` and route via hop-count shortest paths.

    Symmetry is enforced by computing each path once per unordered pair.
    ``method="bfs"`` runs one single-source BFS per agent instead of one
    search per pair — the only way to build 500+-agent overlays in
    reasonable time (m BFS sweeps vs m²/2 searches). Hop counts are
    identical; among equal-length paths the BFS tie-break may differ from
    the pairwise search, so the default stays "pairwise" for
    reproducibility of existing category structures.
    """
    agents = tuple(agent_nodes)
    paths: dict[tuple[int, int], tuple[int, ...]] = {}
    if method == "pairwise":
        for i in range(len(agents)):
            for j in range(i + 1, len(agents)):
                paths[(i, j)] = underlay.shortest_path(agents[i], agents[j])
    elif method == "bfs":
        for i in range(len(agents)):
            sp = nx.single_source_shortest_path(underlay.graph, agents[i])
            for j in range(i + 1, len(agents)):
                paths[(i, j)] = tuple(sp[agents[j]])
    else:
        raise ValueError(f"unknown overlay build method {method!r}")
    ov = OverlayNetwork(underlay=underlay, agents=agents, paths=paths)
    ov.validate()
    return ov


def lowest_degree_nodes(underlay: Underlay, m: int) -> list[int]:
    """The paper selects the m lowest-degree underlay nodes as agents."""
    deg = sorted(underlay.graph.degree, key=lambda kv: (kv[1], kv[0]))
    return [n for n, _ in deg[:m]]


def mid_path_edges(
    overlay: OverlayNetwork, pairs: Sequence[tuple[int, int]]
) -> tuple[tuple[int, int], ...]:
    """Undirected mid-path underlay hops of the given overlay links'
    default paths — the hops a re-route can actually avoid (agent access
    edges, which every schedule must cross, are excluded). The canonical
    edge set for localized-degradation scenarios; sorted (min, max)
    pairs, deduplicated across links."""
    return tuple(sorted({
        (min(e), max(e))
        for (i, j) in pairs
        for e in overlay.path_edges(i, j)[1:-1]
    }))


# ---------------------------------------------------------------------------
# Topology generators
# ---------------------------------------------------------------------------


def roofnet_like(
    seed: int = 0,
    num_nodes: int = 38,
    num_links: int = 219,
    capacity: float = MBPS,
) -> Underlay:
    """Roofnet-statistics-matched surrogate (38 nodes, 219 links, 1 Mbps).

    The real Roofnet link-level measurement data is not shipped offline;
    we generate a random geometric mesh with the same node/link counts and
    uniform 1 Mbps capacity (paper §IV-A2), deterministic in ``seed``.
    """
    rng = np.random.default_rng(seed)
    pts = rng.random((num_nodes, 2))
    # Distance-ranked candidate edges; take the shortest ones that keep the
    # graph simple, then repair connectivity, then trim back to num_links.
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    order = sorted(
        ((i, j) for i in range(num_nodes) for j in range(i + 1, num_nodes)),
        key=lambda e: d2[e[0], e[1]],
    )
    g = nx.Graph()
    g.add_nodes_from(range(num_nodes))
    g.add_edges_from(order[:num_links])
    # Repair connectivity by linking components with their closest node pair.
    while not nx.is_connected(g):
        comps = list(nx.connected_components(g))
        best = None
        for a, b in itertools.combinations(range(len(comps)), 2):
            for u in comps[a]:
                for v in comps[b]:
                    if best is None or d2[u, v] < d2[best[0], best[1]]:
                        best = (u, v)
        g.add_edge(*best)
    # Trim longest non-bridge edges back down to num_links.
    extra = g.number_of_edges() - num_links
    if extra > 0:
        for u, v in sorted(g.edges, key=lambda e: -d2[e[0], e[1]]):
            if extra == 0:
                break
            g.remove_edge(u, v)
            if nx.is_connected(g):
                extra -= 1
            else:
                g.add_edge(u, v)
    nx.set_edge_attributes(g, capacity, "capacity")
    u = Underlay(graph=g)
    u.validate()
    return u


def line_underlay(n: int, capacity: float = MBPS) -> Underlay:
    g = nx.path_graph(n)
    nx.set_edge_attributes(g, capacity, "capacity")
    return Underlay(graph=g)


def grid_underlay(rows: int, cols: int, capacity: float = MBPS) -> Underlay:
    g = nx.convert_node_labels_to_integers(nx.grid_2d_graph(rows, cols))
    nx.set_edge_attributes(g, capacity, "capacity")
    return Underlay(graph=g)


def random_geometric_underlay(
    n: int, radius: float = 0.35, seed: int = 0, capacity: float = MBPS
) -> Underlay:
    """Connected random geometric graph (generic edge-network surrogate)."""
    for attempt in range(100):
        g = nx.random_geometric_graph(n, radius, seed=seed + attempt)
        if nx.is_connected(g):
            nx.set_edge_attributes(g, capacity, "capacity")
            return Underlay(graph=nx.Graph(g))
    raise RuntimeError("could not generate a connected geometric graph")


def dumbbell_underlay(
    left: int = 2, right: int = 2, capacity: float = MBPS
) -> Underlay:
    """Two stars joined by one shared bottleneck link (Fig. 2 scenario).

    Nodes 0..left-1 attach to hub L; nodes left..left+right-1 attach to hub
    R; L—R is the shared bottleneck. Useful for unit tests of link sharing.
    """
    g = nx.Graph()
    hub_l, hub_r = left + right, left + right + 1
    for i in range(left):
        g.add_edge(i, hub_l, capacity=capacity)
    for i in range(left, left + right):
        g.add_edge(i, hub_r, capacity=capacity)
    g.add_edge(hub_l, hub_r, capacity=capacity)
    return Underlay(graph=g)


def ici_torus_underlay(
    x: int, y: int, capacity: float = 50e9
) -> Underlay:
    """TPU ICI 2-D torus as an 'underlay' (hardware adaptation, DESIGN §4).

    Each chip is a node; wrap-around links with ~50 GB/s per direction.
    Lets the paper's congestion machinery reason about gossip schedules on
    the pod fabric itself.
    """
    g = nx.Graph()
    for i in range(x):
        for j in range(y):
            n = i * y + j
            g.add_edge(n, ((i + 1) % x) * y + j, capacity=capacity)
            g.add_edge(n, i * y + (j + 1) % y, capacity=capacity)
    return Underlay(graph=g)
