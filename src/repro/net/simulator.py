"""Fluid-level simulation of multicast completion times on the underlay.

Validates Lemma III.1/III.2 numerically: under equal bandwidth sharing at
every underlay link, the makespan for equal-size demands equals

    τ = max_e κ · t_e / C_e .

The simulator is event-driven with max-min fair rate allocation (what TCP
approximates): at each event, remaining flows receive max-min fair rates
given the underlay capacities; the next completion is advanced to. The
multicast flow h completes when its slowest unicast branch finishes; a
branch's traffic occupies every underlay edge of its (possibly relayed)
overlay path.

Three engines share the same event arithmetic:

  * ``engine="batched"`` (default) — water-filling variant that freezes
    all tied bottlenecks per round instead of one; fewer allocation
    rounds and the fastest at 200+ agents, but a different fp drain
    order, so the makespan matches "vectorized" only to rtol=1e-9
    (property-tested at small sizes, nightly-gated at 220 agents by
    ``benchmarks/engine_parity.py``).
  * ``engine="vectorized"`` — precomputes a branch×edge incidence
    matrix once per routing solution and runs progressive filling as
    numpy matrix/mask operations, freezing one bottleneck per round in
    the reference's first-encounter tie-break order — bitwise-identical
    to the reference engine (property-tested).
  * ``engine="reference"``  — the original pure-Python dict loops, kept
    as the ground-truth escape hatch the vectorized engine is
    property-tested against.

The ``Scenario`` layer models operating conditions beyond the paper's
static network: piecewise-constant time-varying link capacities,
background cross-traffic flows, straggling agents (throttled below their
fair share), and agent churn (departures cancel the affected branches).
Scenario timeline breakpoints become simulation events, so allocations
stay piecewise-constant and the fluid model remains exact.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from repro.analysis.contracts import maybe_validate
from repro.net.routing import RoutingSolution
from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Simulation outcome.

    ``makespan`` is the completion time of the slowest *finished* branch
    (churn-cancelled branches are excluded — they represent exchanges the
    surviving agents renormalize away, not time spent waiting).
    ``flow_completion[h]`` is NaN when flow h cannot report a completion
    time: it still had unfinished branches at loop exit (``max_events``
    truncation — check ``unfinished_branches`` before trusting a run
    that may have been cut short), or *all* of its branches were
    churn-cancelled (nothing was delivered; a finite time always means
    the surviving branches actually finished).
    """

    makespan: float
    flow_completion: tuple[float, ...]  # per multicast demand
    num_events: int
    cancelled_branches: int = 0  # churned away before completing
    unfinished_branches: int = 0  # still active when the loop stopped


# ---------------------------------------------------------------------------
# Scenario layer — time-varying operating conditions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CapacityPhase:
    """From ``start`` onward, underlay capacities are ``base × scale``.

    ``scale`` is a global multiplier or a per-edge map keyed by underlay
    edge (either direction; missing edges keep multiplier 1.0). Phases are
    piecewise-constant: the latest phase with ``start <= t`` applies.
    """

    start: float
    scale: float | Mapping[tuple[int, int], float] = 1.0


@dataclasses.dataclass(frozen=True)
class CrossTraffic:
    """Background flow occupying ``rate`` bytes/s on every underlay edge
    of the shortest path ``src → dst`` during [start, stop)."""

    src: int  # underlay node id
    dst: int  # underlay node id
    rate: float  # bytes/s
    start: float = 0.0
    stop: float = math.inf


@dataclasses.dataclass(frozen=True)
class StragglerEvent:
    """Agent ``agent`` is slowed by ``slowdown``× during [start, stop).

    A straggler's endpoint (CPU/NIC) throttles every branch incident to
    it to 1/slowdown of its fair share — the freed capacity is *not*
    redistributed (the bottleneck is the host, not the links)."""

    agent: int  # overlay agent index
    slowdown: float  # >= 1
    start: float = 0.0
    stop: float = math.inf


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """Agent ``agent`` leaves at ``time``; branches on overlay links
    touching it — and all branches of flows it sources — are cancelled."""

    agent: int
    time: float


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Time-varying operating conditions consumed by ``simulate()``.

    The default instance is the paper's static network. ``floor_frac``
    bounds effective capacity from below at ``floor_frac × base`` so
    cross-traffic can congest but never fully dead-lock an edge.
    """

    capacity_phases: tuple[CapacityPhase, ...] = ()
    cross_traffic: tuple[CrossTraffic, ...] = ()
    stragglers: tuple[StragglerEvent, ...] = ()
    churn: tuple[ChurnEvent, ...] = ()
    floor_frac: float = 1e-9

    @property
    def is_trivial(self) -> bool:
        return not (
            self.capacity_phases
            or self.cross_traffic
            or self.stragglers
            or self.churn
        )

    def validate(self) -> None:
        for ph in self.capacity_phases:
            if isinstance(ph.scale, Mapping):
                for edge, f in ph.scale.items():
                    if f <= 0:
                        raise ValueError(
                            f"capacity scale for edge {edge} must be "
                            "positive"
                        )
            elif ph.scale <= 0:
                raise ValueError("capacity scale must be positive")
        for ct in self.cross_traffic:
            if ct.rate < 0 or ct.stop < ct.start:
                raise ValueError(f"invalid cross-traffic window: {ct}")
        for ev in self.stragglers:
            if ev.slowdown < 1.0 or ev.stop < ev.start:
                raise ValueError(f"invalid straggler event: {ev}")
        for c in self.churn:
            if c.time < 0:
                raise ValueError(f"negative churn time: {c}")

    def breakpoints(self) -> tuple[float, ...]:
        """Sorted finite times at which conditions change."""
        ts: set[float] = set()
        for ph in self.capacity_phases:
            ts.add(ph.start)
        for ct in self.cross_traffic:
            ts.add(ct.start)
            if math.isfinite(ct.stop):
                ts.add(ct.stop)
        for ev in self.stragglers:
            ts.add(ev.start)
            if math.isfinite(ev.stop):
                ts.add(ev.stop)
        for c in self.churn:
            ts.add(c.time)
        return tuple(sorted(t for t in ts if t > 0 and math.isfinite(t)))

    def shifted(self, t0: float) -> "Scenario":
        """The conditions as seen by a simulation *starting* at
        wall-clock ``t0`` — the per-round pricing primitive of
        ``repro.core.priced_training``: gossip round k of a training
        run begins at the accumulated wall-clock of rounds 0..k-1, and
        its network time is ``simulate(..., scenario=sc.shifted(t_k))``.

        Capacity phases are piecewise-constant, so the phase active at
        ``t0`` (the latest with ``start <= t0``) becomes the new t=0
        phase and later phases keep their relative offsets. Windowed
        events (cross-traffic, stragglers) are clipped to the remaining
        window; fully elapsed windows drop out. A churn departure at or
        before ``t0`` is absorbing — the agent is already gone — so it
        re-emits at time 0 and keeps cancelling that agent's exchanges
        (redesigning on the survivors, which removes those flows
        outright, is the trainer's job, not the pricer's).
        ``shifted(0.0)`` returns ``self`` unchanged.
        """
        if t0 < 0:
            raise ValueError(f"shift origin must be nonnegative: {t0}")
        if t0 == 0.0:
            return self
        active = None
        phases: list[CapacityPhase] = []
        for ph in sorted(self.capacity_phases, key=lambda p: p.start):
            if ph.start <= t0:
                active = ph
            else:
                phases.append(
                    CapacityPhase(start=ph.start - t0, scale=ph.scale)
                )
        if active is not None:
            phases.insert(0, CapacityPhase(start=0.0, scale=active.scale))
        cross = tuple(
            CrossTraffic(
                src=ct.src, dst=ct.dst, rate=ct.rate,
                start=max(0.0, ct.start - t0), stop=ct.stop - t0,
            )
            for ct in self.cross_traffic
            if ct.stop > t0
        )
        stragglers = tuple(
            StragglerEvent(
                agent=ev.agent, slowdown=ev.slowdown,
                start=max(0.0, ev.start - t0), stop=ev.stop - t0,
            )
            for ev in self.stragglers
            if ev.stop > t0
        )
        churn = tuple(
            ChurnEvent(agent=c.agent, time=max(0.0, c.time - t0))
            for c in self.churn
        )
        return Scenario(
            capacity_phases=tuple(phases),
            cross_traffic=cross,
            stragglers=stragglers,
            churn=churn,
            floor_frac=self.floor_frac,
        )


# ---------------------------------------------------------------------------
# Incidence compilation — done once per routing solution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BranchIncidence:
    """Precomputed branch×edge structure for the vectorized engine.

    Only underlay edges crossed by at least one branch are indexed (others
    can never constrain). ``flat_branch``/``flat_edge`` list every
    (branch, edge) traversal in branch-major path order — the same order
    the reference engine's dict loops encounter them, which pins down
    bottleneck tie-breaking and capacity-subtraction order bit for bit.
    """

    edges: tuple[tuple[int, int], ...]  # directed underlay edges
    edge_index: Mapping[tuple[int, int], int]
    base_capacity: np.ndarray  # [E] float64
    flows: np.ndarray  # [B] flow id per branch
    links: np.ndarray  # [B, 2] overlay endpoints (i, j) per branch
    flat_branch: np.ndarray  # [entries] branch-major, path order
    flat_edge: np.ndarray  # [entries]
    branch_ptr: np.ndarray  # [B+1] CSR slices into flat_edge per branch
    edge_branch: np.ndarray  # [entries] branches sorted by (edge, branch)
    edge_ptr: np.ndarray  # [E+1] CSC slices into edge_branch per edge

    def __post_init__(self):
        # CSR well-formedness contract; no-op unless REPRO_VALIDATE=1
        # (repro.analysis.contracts.validate_branch_incidence).
        maybe_validate(self)

    @property
    def num_branches(self) -> int:
        return self.flows.size

    @property
    def num_edges(self) -> int:
        return self.base_capacity.size

    def edge_counts(self, active: np.ndarray) -> np.ndarray:
        """Active-branch crossings per edge (integer-valued float64)."""
        mask = active[self.flat_branch]
        return np.bincount(
            self.flat_edge[mask], minlength=self.num_edges
        ).astype(np.float64)

    def with_capacities(self, changed: Mapping) -> "BranchIncidence":
        """Patch base capacities of the named directed edges in place of
        a full recompile.

        ``changed`` maps directed underlay edges to new *absolute*
        capacities; edges this incidence never indexes (no branch
        crosses them, so they can never constrain) are ignored. The
        branch×edge structure — the expensive Python half of
        ``compile_incidence`` — is shared untouched; only the [E]
        capacity vector is rebuilt, so the incremental-redesign service
        re-prices an in-flight round under a ``LinkStateChange`` at
        O(changed edges). Runs through ``dataclasses.replace``, so the
        CSR contracts re-validate under ``REPRO_VALIDATE=1``.
        """
        cap = self.base_capacity.copy()
        for e, c in changed.items():
            idx = self.edge_index.get(e)
            if idx is not None:
                if c <= 0:
                    raise ValueError(
                        f"patched capacity for edge {e} must be positive"
                    )
                cap[idx] = float(c)
        return dataclasses.replace(self, base_capacity=cap)


def compile_incidence(
    sol: RoutingSolution,
    overlay: OverlayNetwork,
    branches: Sequence[tuple] | None = None,
) -> BranchIncidence:
    """Build the sparse branch×edge incidence for ``sol`` on ``overlay``.

    ``branches`` (from ``sol.unicast_branches``) may be supplied to avoid
    re-expanding the trees when the caller already holds them.
    """
    if branches is None:
        branches = sol.unicast_branches(overlay)
    edge_index: dict[tuple[int, int], int] = {}
    flat_branch: list[int] = []
    flat_edge: list[int] = []
    flows = np.empty(len(branches), dtype=np.int64)
    links = np.empty((len(branches), 2), dtype=np.int64)
    for b, (h, (i, j), path) in enumerate(branches):
        flows[b] = h
        links[b] = (i, j)
        for e in path:
            idx = edge_index.setdefault(e, len(edge_index))
            flat_branch.append(b)
            flat_edge.append(idx)
    edges = tuple(edge_index)
    caps = np.array(
        [overlay.underlay.capacity(*e) for e in edges], dtype=np.float64
    )
    fb = np.asarray(flat_branch, dtype=np.int64)
    fe = np.asarray(flat_edge, dtype=np.int64)
    order = np.argsort(fe, kind="stable")  # (edge, branch) lexicographic
    return BranchIncidence(
        edges=edges,
        edge_index=edge_index,
        base_capacity=caps,
        flows=flows,
        links=links,
        flat_branch=fb,
        flat_edge=fe,
        branch_ptr=np.searchsorted(fb, np.arange(len(branches) + 1)),
        edge_branch=fb[order],
        edge_ptr=np.searchsorted(fe[order], np.arange(len(edges) + 1)),
    )


# ---------------------------------------------------------------------------
# Vectorized rate allocation
# ---------------------------------------------------------------------------


def _first_encounter_tie_break(
    tied: np.ndarray, unfrozen: np.ndarray, inc: BranchIncidence
) -> int:
    """Among ``tied`` edges, the one an unfrozen branch traverses first
    in branch-major path order (cheap CSC walk for small tie sets)."""
    best_edge = -1
    best_branch = -1
    for e in tied:
        crossers = inc.edge_branch[inc.edge_ptr[e] : inc.edge_ptr[e + 1]]
        live = crossers[unfrozen[crossers]]
        if not live.size:
            continue
        b = int(live[0])  # ascending branch order
        if best_branch < 0 or b < best_branch:
            best_branch, best_edge = b, int(e)
        elif b == best_branch:
            # Same first branch: earlier position within its path wins.
            path = inc.flat_edge[
                inc.branch_ptr[b] : inc.branch_ptr[b + 1]
            ]
            for pe in path:
                if pe == e:
                    best_edge = int(e)
                    break
                if pe == best_edge:
                    break
    return best_edge


def _branch_entries(inc: BranchIncidence, idx: np.ndarray) -> np.ndarray:
    """Edge indices traversed by branches ``idx`` (ascending), in
    branch-major path order — a multi-slice gather without a Python loop."""
    fe, bptr = inc.flat_edge, inc.branch_ptr
    if idx.size == 1:
        b = int(idx[0])
        return fe[bptr[b] : bptr[b + 1]]
    starts = bptr[idx]
    lens = bptr[idx + 1] - starts
    cum = np.concatenate(([0], np.cumsum(lens)[:-1]))
    flat_pos = np.arange(int(lens.sum())) + np.repeat(starts - cum, lens)
    return fe[flat_pos]


def _edge_crossers(inc: BranchIncidence, idx: np.ndarray) -> np.ndarray:
    """Branches crossing edges ``idx`` — the CSC multi-slice gather
    (duplicates retained; the analogue of ``_branch_entries``)."""
    eb, eptr = inc.edge_branch, inc.edge_ptr
    if idx.size == 1:
        e = int(idx[0])
        return eb[eptr[e] : eptr[e + 1]]
    starts = eptr[idx]
    lens = eptr[idx + 1] - starts
    cum = np.concatenate(([0], np.cumsum(lens)[:-1]))
    flat_pos = np.arange(int(lens.sum())) + np.repeat(starts - cum, lens)
    return eb[flat_pos]


def _maxmin_rates_vec(
    active: np.ndarray,
    inc: BranchIncidence,
    caps: np.ndarray,
    counts: np.ndarray | None = None,
) -> np.ndarray:
    """Progressive-filling max-min fair rates, as matrix/mask operations.

    Bitwise-identical to ``_maxmin_rates``: the bottleneck each round is
    the first minimum-share edge in branch-major traversal order (the
    reference's dict insertion order), and capacity is drained with
    ``np.subtract.at`` — an unbuffered sequential subtraction matching
    the reference's per-branch loop.

    ``counts`` (active-branch crossings per edge, integer-valued floats)
    may be supplied by a caller that maintains it incrementally; it is
    not mutated.
    """
    n = inc.num_branches
    rates = np.zeros(n)
    unfrozen = active.copy()
    n_unfrozen = int(active.sum())
    cap_left = caps.astype(np.float64, copy=True)
    # Integer-valued float counts, then incremental decrements as
    # branches freeze (each loop turn touches only the entries of newly
    # frozen branches, not the whole structure).
    if counts is None:
        counts = inc.edge_counts(unfrozen)
    else:
        counts = counts.copy()
    share = np.empty(inc.num_edges)
    valid = np.empty(inc.num_edges, dtype=bool)
    fe = inc.flat_edge
    while n_unfrozen:
        np.greater(counts, 0, out=valid)
        share.fill(np.inf)
        np.divide(cap_left, counts, out=share, where=valid)
        e_star = int(np.argmin(share))
        smin = share[e_star]
        if not np.isfinite(smin):
            break  # no edge carries an unfrozen branch
        ties = share == smin  # invalid edges hold inf, never tie
        n_ties = int(np.count_nonzero(ties))
        if n_ties > 1:
            # Tie-break: the first (branch-major, path-order) traversal
            # of any tied edge by an unfrozen branch — the reference's
            # dict-insertion first-encounter order.
            if n_ties <= 8:
                e_star = _first_encounter_tie_break(
                    np.flatnonzero(ties), unfrozen, inc
                )
            else:
                sel = unfrozen[inc.flat_branch] & ties[fe]
                e_star = int(fe[int(np.argmax(sel))])
        crossers = inc.edge_branch[
            inc.edge_ptr[e_star] : inc.edge_ptr[e_star + 1]
        ]
        idx = crossers[unfrozen[crossers]]  # ascending branch order
        rates[idx] = smin
        unfrozen[idx] = False
        n_unfrozen -= idx.size
        touched = _branch_entries(inc, idx)
        # Unbuffered sequential subtraction — bitwise-matches the
        # reference's per-branch capacity drain.
        np.subtract.at(cap_left, touched, smin)
        np.subtract.at(counts, touched, 1.0)
    return rates


def _maxmin_rates_batched(
    active: np.ndarray,
    inc: BranchIncidence,
    caps: np.ndarray,
    counts: np.ndarray | None = None,
) -> np.ndarray:
    """Batched water-filling: freeze *all* tied bottlenecks per round.

    Where ``_maxmin_rates_vec`` drains one bottleneck edge per loop turn
    (replaying the reference's first-encounter tie-break), this engine
    freezes the crossers of every edge achieving the minimum share in a
    single round — fewer loop turns on instances with many symmetric
    bottlenecks (uniform-capacity meshes freeze in O(#distinct shares)
    rounds instead of O(#edges)). The capacity drain is grouped
    differently, so results match the default engine only up to fp
    tolerance (makespan parity is property-tested at rtol=1e-9); hence
    opt-in via ``simulate(engine="batched")`` rather than the default.
    """
    n = inc.num_branches
    rates = np.zeros(n)
    unfrozen = active.copy()
    n_unfrozen = int(active.sum())
    cap_left = caps.astype(np.float64, copy=True)
    if counts is None:
        counts = inc.edge_counts(unfrozen)
    else:
        counts = counts.copy()
    share = np.empty(inc.num_edges)
    valid = np.empty(inc.num_edges, dtype=bool)
    while n_unfrozen:
        np.greater(counts, 0, out=valid)
        share.fill(np.inf)
        np.divide(cap_left, counts, out=share, where=valid)
        smin = share.min()
        if not np.isfinite(smin):
            break  # no edge carries an unfrozen branch
        tied = share == smin
        # Every unfrozen crosser of a tied edge, via CSC slices of just
        # those edges (a full entry-array scan per round would dominate
        # at 200+ agents).
        crossers = _edge_crossers(inc, np.flatnonzero(tied))
        idx = np.unique(crossers[unfrozen[crossers]])
        rates[idx] = smin
        unfrozen[idx] = False
        n_unfrozen -= idx.size
        touched = _branch_entries(inc, idx)
        np.subtract.at(cap_left, touched, smin)
        np.subtract.at(counts, touched, 1.0)
    return rates


def _equal_share_rates_vec(
    active: np.ndarray,
    inc: BranchIncidence,
    caps: np.ndarray,
    counts: np.ndarray | None = None,
) -> np.ndarray:
    """Static equal sharing: every edge splits capacity evenly among its
    crossing branches; a branch gets its min share along the path
    (the allocation of Lemma III.1's achievability argument)."""
    if counts is None:
        counts = inc.edge_counts(active)
    rates = np.full(inc.num_branches, np.inf)
    mask = active[inc.flat_branch]
    fb = inc.flat_branch[mask]
    fe = inc.flat_edge[mask]
    np.minimum.at(rates, fb, caps[fe] / counts[fe])
    rates[~active] = 0.0
    return rates


# ---------------------------------------------------------------------------
# Reference rate allocation (the original dict-loop implementation)
# ---------------------------------------------------------------------------


def _maxmin_rates(
    active: Sequence[int],
    branch_edges: Sequence[tuple[tuple[int, int], ...]],
    capacity: Mapping[tuple[int, int], float],
) -> np.ndarray:
    """Progressive-filling max-min fair rates for the active branches."""
    n = len(active)
    rates = np.zeros(n)
    frozen = np.zeros(n, dtype=bool)
    cap_left = dict(capacity)
    # Count active branches per edge.
    while not frozen.all():
        counts: dict[tuple[int, int], int] = {}
        for a in range(n):
            if frozen[a]:
                continue
            for e in branch_edges[active[a]]:
                counts[e] = counts.get(e, 0) + 1
        # Bottleneck edge: smallest fair share among remaining capacity.
        share, bottleneck = math.inf, None
        for e, cnt in counts.items():
            s = cap_left[e] / cnt
            if s < share:
                share, bottleneck = s, e
        if bottleneck is None:
            break
        # Freeze all unfrozen branches crossing the bottleneck at `share`.
        for a in range(n):
            if frozen[a]:
                continue
            if bottleneck in branch_edges[active[a]]:
                rates[a] = share
                frozen[a] = True
                for e in branch_edges[active[a]]:
                    cap_left[e] -= share
    return rates


def _equal_share_rates(
    active: Sequence[int],
    branch_edges: Sequence[tuple[tuple[int, int], ...]],
    capacity: Mapping[tuple[int, int], float],
) -> np.ndarray:
    """Static equal sharing (reference implementation)."""
    counts: dict[tuple[int, int], int] = {}
    for a in active:
        for e in branch_edges[a]:
            counts[e] = counts.get(e, 0) + 1
    rates = np.empty(len(active))
    for idx, a in enumerate(active):
        rates[idx] = min(capacity[e] / counts[e] for e in branch_edges[a])
    return rates


# ---------------------------------------------------------------------------
# Event loops
# ---------------------------------------------------------------------------


def _simulate_reference(
    sol: RoutingSolution,
    overlay: OverlayNetwork,
    branches: Sequence[tuple[int, tuple[int, int], tuple]],
    fairness: str,
    max_events: int,
) -> SimResult:
    capacity = overlay.underlay.directed_capacities()
    n = len(branches)
    # float64 explicitly: integer demand sizes would otherwise make
    # `remaining[active] -= rates * dt` silently truncate.
    remaining = np.array(
        [sol.demands[h].size for h, _, _ in branches], dtype=np.float64
    )
    done_time = np.full(n, np.nan)
    branch_edges = [edges for _, _, edges in branches]
    t = 0.0
    events = 0
    alloc = _maxmin_rates if fairness == "maxmin" else _equal_share_rates

    active = [a for a in range(n)]
    while active and events < max_events:
        rates = alloc(active, branch_edges, capacity)
        if not np.any(rates > 0):
            raise RuntimeError("starved branches; invalid routing/capacities")
        dt = np.min(remaining[active] / np.maximum(rates, 1e-300))
        t += dt
        remaining[active] -= rates * dt
        still = []
        for idx, a in enumerate(active):
            if remaining[a] <= 1e-9 * sol.demands[branches[a][0]].size:
                done_time[a] = t
            else:
                still.append(a)
        active = still
        events += 1

    return _collect_result(
        sol, np.asarray([h for h, _, _ in branches]), done_time,
        cancelled=np.zeros(n, dtype=bool), events=events,
        unfinished=len(active),
    )


def _phase_capacity_array(
    inc: BranchIncidence, phase: CapacityPhase
) -> np.ndarray:
    """Effective capacities under one phase (precomputed per phase)."""
    if isinstance(phase.scale, Mapping):
        factors = np.ones(inc.num_edges)
        for idx, (u, v) in enumerate(inc.edges):
            f = phase.scale.get((u, v), phase.scale.get((v, u), 1.0))
            factors[idx] = float(f)
        return inc.base_capacity * factors
    return inc.base_capacity * float(phase.scale)


def _branch_keys(inc: BranchIncidence) -> list[tuple[int, int, int]]:
    """(flow, overlay i, overlay j) identity per branch — stable across
    re-routed incidences, so a phase swap can carry each branch's
    remaining volume to the same branch in the next segment's trees."""
    return [
        (h, i, j)
        for h, (i, j) in zip(inc.flows.tolist(), inc.links.tolist())
    ]


@dataclasses.dataclass(frozen=True)
class CarryoverState:
    """Realized per-branch transfer state at one instant of a run.

    The observed-state snapshot an *online* re-router decides from at a
    phase boundary (``carryover_state``): ``remaining[(h, i, j)]`` is
    the volume still in flight on flow h's overlay link (i, j) —
    abandoning that link costs a restart from full κ; ``done`` maps
    finished branches to their finish times; ``cancelled`` holds
    churn-cancelled branch keys; ``flow_done[h]`` is flow h's completion
    time (NaN while unfinished); ``departed`` lists agents that have
    churned away by ``time``.
    """

    time: float
    remaining: Mapping[tuple[int, int, int], float]
    done: Mapping[tuple[int, int, int], float]
    cancelled: frozenset
    flow_done: tuple[float, ...]
    departed: tuple[int, ...]


def _simulate_vectorized(
    sol: RoutingSolution,
    overlay: OverlayNetwork,
    inc: BranchIncidence,
    fairness: str,
    max_events: int,
    scenario: Scenario | None,
    batched: bool = False,
    segments: Sequence[tuple[float, RoutingSolution, BranchIncidence]]
    | None = None,
    stop_time: float = math.inf,
    state_out: dict | None = None,
) -> SimResult:
    """Event loop, optionally swapping the active ``BranchIncidence``.

    ``segments`` (from ``simulate_phased``) lists ``(start, solution,
    incidence)`` per routing segment, first entry starting at 0.0. At a
    boundary the loop folds per-branch state out by (flow, overlay-link)
    key and back into the next segment's branch order: a branch whose
    link survives the re-route keeps its remaining volume (and its
    finish time once done), a branch on a fresh link starts with the
    flow's full κ, and branches of already-complete flows or departed
    agents never reactivate — so the phased makespan is exact under the
    same fluid model. Without ``segments`` this is the single-incidence
    loop unchanged.

    ``stop_time`` halts the run at that instant (landing on it exactly,
    like a phase breakpoint); with ``state_out`` the per-branch state at
    loop exit is folded out by branch key into the supplied dict
    (``remaining``/``done``/``cancelled``/``flow_done``/``departed``/
    ``time``) — how ``carryover_state`` snapshots a prefix of a run for
    the online re-router. A finite ``stop_time`` truncates the returned
    ``SimResult`` (in-flight branches count as unfinished).
    """
    if segments is None:
        segments = ((0.0, sol, inc),)
    n_seg = len(segments)
    H = len(sol.demands)
    # float64 explicitly (see _simulate_reference).
    flow_size = np.array([d.size for d in sol.demands], dtype=np.float64)
    if fairness == "maxmin":
        alloc = _maxmin_rates_batched if batched else _maxmin_rates_vec
    else:
        alloc = _equal_share_rates_vec

    if scenario is not None:
        scenario.validate()
        m = overlay.num_agents
        for ev in (*scenario.stragglers, *scenario.churn):
            if not 0 <= ev.agent < m:
                raise ValueError(
                    f"scenario references agent {ev.agent}, but the "
                    f"overlay has {m} agents"
                )
        phases = tuple(
            sorted(scenario.capacity_phases, key=lambda p: p.start)
        )
        churn = sorted(scenario.churn, key=lambda c: c.time)
        breakpoints = scenario.breakpoints()
    else:
        phases, churn, breakpoints = (), [], ()
    flow_source = np.array([d.source for d in sol.demands], dtype=np.int64)

    # Cross-segment state, keyed by branch identity (phased runs only).
    remaining_map: dict[tuple[int, int, int], float] = {}
    done_map: dict[tuple[int, int, int], float] = {}
    cancelled_keys: set[tuple[int, int, int]] = set()
    flow_done = np.full(H, np.nan)  # completion time once a flow finishes
    departed: list[int] = []  # churned agents already applied
    scen_prep: dict[int, tuple] = {}  # per-incidence scenario arrays

    t = 0.0
    events = 0
    churn_ptr = 0
    bp_ptr = 0
    phase_ptr = 0
    cur_phase = -1  # latest phase with start <= t (t is monotone)

    for si in range(n_seg):
        if segments[si][0] >= stop_time:
            break  # segments at/after the stop instant never start
        seg_start, seg_sol, seg_inc = segments[si]
        seg_end = segments[si + 1][0] if si + 1 < n_seg else math.inf
        # If the previous segment drained (or churned) empty before its
        # end, nothing happens until this segment's re-route takes
        # effect — its fresh branches start transmitting at seg_start.
        if t < seg_start:
            t = seg_start
        n = seg_inc.num_branches
        sizes = flow_size[seg_inc.flows]
        thresh = 1e-9 * sizes
        if si == 0:
            remaining = sizes.copy()
            done_time = np.full(n, np.nan)
            cancelled = np.zeros(n, dtype=bool)
        else:
            # Fold carried state into this segment's branch order.
            keys = _branch_keys(seg_inc)
            remaining = np.array(
                [remaining_map.get(k, s) for k, s in zip(keys, sizes)]
            )
            done_time = np.array([done_map.get(k, np.nan) for k in keys])
            cancelled = np.fromiter(
                (k in cancelled_keys for k in keys), dtype=bool, count=n
            )
            # Already-complete flows carry no fresh work into new links.
            fresh = np.isnan(done_time) & ~cancelled
            fd = flow_done[seg_inc.flows]
            settle = fresh & ~np.isnan(fd)
            done_time[settle] = fd[settle]
            # Agents that already left cancel their fresh branches too.
            for agent in departed:
                hit = np.isnan(done_time) & ~cancelled & (
                    (seg_inc.links[:, 0] == agent)
                    | (seg_inc.links[:, 1] == agent)
                    | (flow_source[seg_inc.flows] == agent)
                )
                cancelled |= hit
        active = np.isnan(done_time) & ~cancelled
        # Active-branch crossings per edge, maintained incrementally as
        # branches finish or churn away (one bincount per segment).
        counts = seg_inc.edge_counts(active)

        if scenario is not None:
            cached = scen_prep.get(id(seg_inc))
            if cached is None:
                # One effective-capacity array per phase, built once per
                # distinct incidence (the swap guard makes segments
                # sharing one incidence the common case; a per-edge
                # Mapping scale would otherwise cost an O(E) Python
                # loop per segment).
                phase_caps = [
                    _phase_capacity_array(seg_inc, ph) for ph in phases
                ]
                # Cross-traffic paths resolved to indexed edges once.
                cross: list[tuple[CrossTraffic, np.ndarray]] = []
                for ct in scenario.cross_traffic:
                    path = overlay.underlay.shortest_path(ct.src, ct.dst)
                    idxs = [
                        seg_inc.edge_index[e]
                        for k in range(len(path) - 1)
                        if (e := (path[k], path[k + 1]))
                        in seg_inc.edge_index
                    ]
                    cross.append((ct, np.asarray(idxs, dtype=np.int64)))
                scen_prep[id(seg_inc)] = (phase_caps, cross)
            else:
                phase_caps, cross = cached
        else:
            phase_caps, cross = [], []

        def drop_counts(
            gone: np.ndarray, inc=seg_inc, counts=counts
        ) -> None:
            idx = np.flatnonzero(gone)
            if idx.size:
                np.subtract.at(counts, _branch_entries(inc, idx), 1.0)

        while (
            active.any() and events < max_events
            and t < seg_end and t < stop_time
        ):
            # Apply departures due by now: cancel branches on overlay
            # links touching the agent and all branches of flows it
            # sources.
            while churn_ptr < len(churn) and churn[churn_ptr].time <= t:
                agent = churn[churn_ptr].agent
                departed.append(agent)
                hit = active & (
                    (seg_inc.links[:, 0] == agent)
                    | (seg_inc.links[:, 1] == agent)
                    | (flow_source[seg_inc.flows] == agent)
                )
                cancelled |= hit
                active &= ~hit
                drop_counts(hit)
                churn_ptr += 1
            if not active.any():
                break

            if scenario is None:
                caps = seg_inc.base_capacity
            else:
                while (
                    phase_ptr < len(phases)
                    and phases[phase_ptr].start <= t
                ):
                    cur_phase = phase_ptr
                    phase_ptr += 1
                caps = (
                    phase_caps[cur_phase] if cur_phase >= 0
                    else seg_inc.base_capacity
                )
                if cross:
                    caps = caps.copy()
                    for ct, idxs in cross:
                        if ct.start <= t < ct.stop and idxs.size:
                            caps[idxs] -= ct.rate
                    np.maximum(
                        caps, scenario.floor_frac * seg_inc.base_capacity,
                        out=caps,
                    )

            rates = alloc(active, seg_inc, caps, counts)
            if scenario is not None and scenario.stragglers:
                factor = np.ones(n)
                for ev in scenario.stragglers:
                    if ev.start <= t < ev.stop:
                        hit = (seg_inc.links[:, 0] == ev.agent) | (
                            seg_inc.links[:, 1] == ev.agent
                        )
                        np.maximum(
                            factor, np.where(hit, ev.slowdown, 1.0),
                            out=factor,
                        )
                rates = rates / factor

            while bp_ptr < len(breakpoints) and breakpoints[bp_ptr] <= t:
                bp_ptr += 1
            t_next = (
                breakpoints[bp_ptr] if bp_ptr < len(breakpoints)
                else math.inf
            )
            eff_end = seg_end if seg_end < stop_time else stop_time
            if eff_end < t_next:
                t_next = eff_end  # boundary/stop instant acts as an event

            if not np.any(rates > 0):
                if math.isinf(t_next):
                    raise RuntimeError(
                        "starved branches; invalid routing/capacities"
                    )
                t = t_next  # conditions may recover at the next breakpoint
                events += 1
                continue

            dt = np.min(
                remaining[active] / np.maximum(rates[active], 1e-300)
            )
            if t_next - t < dt:
                dt = t_next - t
                t = t_next  # land exactly on the breakpoint (no fp drift)
            else:
                t += dt
            remaining[active] -= rates[active] * dt
            finished = active & (remaining <= thresh)
            done_time[finished] = t
            active &= ~finished
            drop_counts(finished)
            events += 1

        if n_seg > 1 or state_out is not None:
            # Fold this segment's state out by branch key. The map is
            # rebuilt from scratch: a key absent from this segment's
            # trees was abandoned by the re-route, and its partial
            # progress is lost for good — a later segment restoring the
            # link restarts it from full κ ("mid-flight data on
            # abandoned links is lost", not parked).
            keys = _branch_keys(seg_inc)
            remaining_map = {}
            for b, k in enumerate(keys):
                if cancelled[b]:
                    cancelled_keys.add(k)
                elif not np.isnan(done_time[b]):
                    done_map[k] = float(done_time[b])
                else:
                    remaining_map[k] = float(remaining[b])
            seg_flows = seg_inc.flows
            for h in range(H):
                if np.isnan(flow_done[h]):
                    selm = seg_flows == h
                    if selm.any() and not (active & selm).any():
                        vals = done_time[selm & ~cancelled]
                        if vals.size and not np.isnan(vals).any():
                            flow_done[h] = float(np.max(vals))
        if (
            events >= max_events or t >= stop_time
            or (n_seg == 1 and not active.any())
        ):
            break
        # Multi-segment runs fall through even when this segment's
        # active set churned/drained empty: a later re-route can add
        # fresh branches (links avoiding the departed agents) that still
        # deliver for unfinished flows.

    if state_out is not None:
        state_out.update(
            time=t,
            remaining=dict(remaining_map),
            done=dict(done_map),
            cancelled=set(cancelled_keys),
            flow_done=flow_done.copy(),
            departed=list(departed),
        )
    result = _collect_result(
        sol, seg_inc.flows, done_time, cancelled, events,
        unfinished=int(active.sum()),
    )
    if n_seg > 1:
        # Union accounting across segments: a key cancelled in any
        # segment counts once, and branches that finished before a
        # later re-route dropped their link still count toward the
        # makespan and their flow's completion time (their data WAS
        # delivered; only the final segment's branches are visible to
        # _collect_result). A flow keeps NaN only while it still has
        # active branches (unfinished) or never finished any branch —
        # NOT when churn cancelled its final-segment branches after an
        # earlier segment already delivered some.
        best: dict[int, float] = {}
        for (h, _, _), t_done in done_map.items():  # ⊇ final-segment dones
            if t_done > best.get(h, -math.inf):
                best[h] = t_done
        fc = list(result.flow_completion)
        flows_final = seg_inc.flows
        for h in range(H):
            if h in best and not bool((active & (flows_final == h)).any()):
                fc[h] = best[h]
        result = dataclasses.replace(
            result,
            makespan=max([result.makespan, *done_map.values()]),
            flow_completion=tuple(fc),
            cancelled_branches=len(cancelled_keys),
        )
    return result


def _collect_result(
    sol: RoutingSolution,
    flows: np.ndarray,
    done_time: np.ndarray,
    cancelled: np.ndarray,
    events: int,
    unfinished: int,
) -> SimResult:
    """Fold per-branch finish times into a ``SimResult``.

    ``flow_completion[h]`` is NaN when flow h cannot report a completion
    time: either a branch was still unfinished at loop exit, or *every*
    branch of the flow was churn-cancelled (the flow delivered nothing —
    distinguishable from "finished instantly", which reports a finite
    time).
    """
    counted = done_time[~cancelled]
    finished_any = bool(np.any(~np.isnan(counted))) if counted.size else False
    flow_completion = []
    for h in range(len(sol.demands)):
        sel = (flows == h) & ~cancelled
        vals = done_time[sel]
        # All branches cancelled -> NaN, not 0.0: "nothing delivered"
        # must not read as "finished instantly".
        flow_completion.append(
            float(np.max(vals)) if vals.size else math.nan
        )
    return SimResult(
        makespan=float(np.nanmax(counted)) if finished_any else 0.0,
        flow_completion=tuple(flow_completion),
        num_events=events,
        cancelled_branches=int(cancelled.sum()),
        unfinished_branches=unfinished,
    )


# Every engine ``simulate``/``simulate_phased`` dispatch on. Keep the
# unknown-engine error below in sync when adding one.
_ENGINES = ("batched", "vectorized", "reference", "jax")


def simulate(
    sol: RoutingSolution,
    overlay: OverlayNetwork,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    scenario: Scenario | None = None,
    engine: str = "batched",
    incidence: BranchIncidence | None = None,
) -> SimResult:
    """Simulate completion of all multicast demands under ``sol``.

    fairness: "maxmin" (TCP-like, dynamic reallocation on completions) or
    "equal" (static equal split, re-evaluated on completions).
    scenario: optional time-varying conditions (vectorized engines only).
    engine: "batched" (default — water-filling that freezes all tied
    bottlenecks per round; fastest at 200+ agents, nightly-gated to
    rtol=1e-9 makespan parity by ``benchmarks/engine_parity.py``),
    "vectorized" (one bottleneck per round, replaying the reference
    tie-break order — bitwise-identical to "reference",
    property-tested), "reference" (original dict loops, the
    scenario-free pure-Python escape hatch), or "jax" (the batched
    water-filling on device — ``net/jax_engine.py``; maxmin fairness
    with capacity phases + churn, rtol=1e-9 against "batched"; its
    real payoff is ``vmap``-batched stochastic rollouts via
    ``jax_engine.simulate_rollout_batch``).
    incidence: a precompiled ``BranchIncidence`` for ``sol`` over
    ``overlay`` (possibly capacity-patched via ``with_capacities``),
    skipping branch enumeration + ``compile_incidence`` — the design
    service's repeated-transition-pricing fast path. The caller owns
    the claim that it matches ``sol``/``overlay``.

    Engine / scenario / stochastic matrix::

        engine=       scenario=                     stochastic realizations
        ------------  ----------------------------  -------------------------
        "batched"     full (capacity phases,        host loop: simulate each
                      cross-traffic, stragglers,    ``sample_many()`` draw as
                      churn)                        its ``scenario=``
        "vectorized"  full (same as "batched")      same host loop
        "reference"   RAISES on any scenario;       unsupported
                      RAISES on a precompiled
                      ``incidence=``
        "jax"         capacity phases + churn;      one XLA launch for the
                      RAISES on cross-traffic or    whole batch via
                      straggler events              ``jax_engine.
                                                    rollout_batch_results``
                                                    (see ``StochasticTau.
                                                    price`` /
                                                    ``evaluate_design``)
    """
    if fairness not in ("maxmin", "equal"):
        raise ValueError(f"unknown fairness {fairness!r}")
    if engine not in _ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}: valid engines are 'batched' "
            "(default numpy water-filling), 'vectorized' (one "
            "bottleneck per round, bitwise-matches 'reference'), "
            "'reference' (pure-Python escape hatch), and 'jax' "
            "(XLA device batching)"
        )
    if incidence is not None and engine == "reference":
        raise ValueError(
            "a precompiled incidence requires a vectorized engine"
        )
    for h, (demand, tree) in enumerate(zip(sol.demands, sol.trees)):
        if not tree:
            raise ValueError(
                f"demand {h} (source {demand.source}) has an empty routing "
                "tree; route it before simulating"
            )
    if scenario is not None and scenario.is_trivial:
        scenario = None
    if engine == "jax":
        # Deferred import: the numpy engines must stay importable (and
        # fast to import) without touching jax.
        from repro.net.jax_engine import simulate_jax

        return simulate_jax(
            sol, overlay, fairness=fairness, max_events=max_events,
            scenario=scenario, incidence=incidence,
        )
    if incidence is not None:
        if incidence.num_branches == 0:
            return SimResult(0.0, tuple(0.0 for _ in sol.demands), 0)
        return _simulate_vectorized(
            sol, overlay, incidence, fairness, max_events, scenario,
            batched=(engine == "batched"),
        )
    branches = sol.unicast_branches(overlay)
    if not branches:
        return SimResult(0.0, tuple(0.0 for _ in sol.demands), 0)
    if engine == "reference":
        if scenario is not None:
            raise ValueError(
                "scenarios require the vectorized engine "
                "(engine='vectorized')"
            )
        return _simulate_reference(
            sol, overlay, branches, fairness, max_events
        )
    inc = compile_incidence(sol, overlay, branches)
    return _simulate_vectorized(
        sol, overlay, inc, fairness, max_events, scenario,
        batched=(engine == "batched"),
    )


def simulate_phased(
    phased,
    overlay: OverlayNetwork,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    scenario: Scenario | None = None,
    engine: str = "batched",
) -> SimResult:
    """Simulate a ``PhasedRoutingSolution`` (time-expanded routing).

    Each segment's trees are compiled to a ``BranchIncidence`` (one per
    distinct tree set — segments sharing a solution share the compiled
    incidence), and the vectorized event loop swaps the active incidence
    at each boundary, carrying every branch's remaining volume across
    the swap by (flow, overlay-link) identity. ``scenario`` supplies the
    capacity phases/cross-traffic/stragglers/churn exactly as in
    ``simulate`` — pass the same scenario the schedule was routed for.
    A single-segment schedule reduces to ``simulate(phased.solutions[0],
    ...)``; one whose segments share a tree matches the single-incidence
    makespan (property-tested at rtol=1e-9). Engines: "vectorized",
    "batched", or "jax" (the reference engine has no incidence to
    swap). "jax" lowers the segment schedule to a ``lax.scan`` over
    per-phase capacity vectors on the device; it requires every segment
    to share one tree set (the swap guard's common case — volume
    carryover across an actual re-route is host-side).

    Engine / scenario / stochastic matrix::

        engine=       scenario=                     stochastic realizations
        ------------  ----------------------------  -------------------------
        "batched"     full; segments may re-route   host loop over
                      at boundaries (volume         ``sample_many()`` draws
                      carryover)
        "vectorized"  full (same as "batched")      same host loop
        "reference"   RAISES always (no incidence   unsupported
                      to swap)
        "jax"         capacity phases + churn;      via ``evaluate_design(
                      RAISES on cross-traffic /     stochastic=...,
                      stragglers and on schedules   engine="jax")`` (static
                      that re-route at a boundary   schedule only)
                      (price those with "batched")
    """
    if fairness not in ("maxmin", "equal"):
        raise ValueError(f"unknown fairness {fairness!r}")
    if engine not in ("vectorized", "batched", "jax"):
        raise ValueError(
            "phased simulation requires an incidence-swapping engine "
            "('vectorized', 'batched', or 'jax')"
        )
    for sol in phased.solutions:
        for h, (demand, tree) in enumerate(zip(sol.demands, sol.trees)):
            if not tree:
                raise ValueError(
                    f"demand {h} (source {demand.source}) has an empty "
                    "routing tree; route it before simulating"
                )
    base = phased.solutions[0]
    if not base.demands:
        return SimResult(0.0, (), 0)
    if scenario is not None and scenario.is_trivial:
        scenario = None
    compiled: dict[tuple, BranchIncidence] = {}
    segments = []
    for start, sol in zip(phased.boundaries, phased.solutions):
        inc = compiled.get(sol.trees)
        if inc is None:
            inc = compile_incidence(sol, overlay)
            compiled[sol.trees] = inc
        segments.append((start, sol, inc))
    if engine == "jax":
        if len(compiled) != 1:
            raise ValueError(
                "engine='jax' prices phased schedules whose segments "
                "all share one tree set (segment boundaries become "
                "device-side capacity-vector swaps); this schedule "
                "re-routes at a boundary, which needs the host loop's "
                "volume carryover — price it with engine='batched'"
            )
        from repro.net.jax_engine import simulate_jax

        return simulate_jax(
            base, overlay, fairness=fairness, max_events=max_events,
            scenario=scenario, incidence=segments[0][2],
            extra_boundaries=tuple(float(b) for b in phased.boundaries),
        )
    return _simulate_vectorized(
        base, overlay, segments[0][2], fairness, max_events, scenario,
        batched=(engine == "batched"), segments=tuple(segments),
    )


def carryover_state(
    phased,
    overlay: OverlayNetwork,
    stop_time: float,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    scenario: Scenario | None = None,
    engine: str = "batched",
) -> CarryoverState:
    """Snapshot the realized per-branch state of a phased run at an
    instant — what an online re-router is allowed to observe.

    Runs ``simulate_phased``'s event loop on ``phased`` (segments at or
    after ``stop_time`` never start) and halts exactly at ``stop_time``,
    folding every branch's state out by (flow, overlay-link) key. The
    scenario may extend past ``stop_time``: the loop only ever applies
    conditions with ``start <= t``, so the snapshot contains no
    lookahead — future phases cannot leak into it. A churn event at
    exactly ``stop_time`` belongs to the next segment and is *not*
    applied.
    """
    if fairness not in ("maxmin", "equal"):
        raise ValueError(f"unknown fairness {fairness!r}")
    if engine not in ("vectorized", "batched"):
        raise ValueError(
            "carryover snapshots require a vectorized engine "
            "('vectorized' or 'batched')"
        )
    if not math.isfinite(stop_time) or stop_time < 0:
        raise ValueError(f"stop_time must be finite and >= 0: {stop_time}")
    base = phased.solutions[0]
    if stop_time <= phased.boundaries[0]:
        # Nothing has run yet: every branch is fresh, no flow finished.
        return CarryoverState(
            time=float(stop_time), remaining={}, done={},
            cancelled=frozenset(),
            flow_done=tuple(math.nan for _ in base.demands),
            departed=(),
        )
    if scenario is not None and scenario.is_trivial:
        scenario = None
    compiled: dict[tuple, BranchIncidence] = {}
    segments = []
    for start, sol in zip(phased.boundaries, phased.solutions):
        inc = compiled.get(sol.trees)
        if inc is None:
            inc = compile_incidence(sol, overlay)
            compiled[sol.trees] = inc
        segments.append((start, sol, inc))
    state: dict = {}
    _simulate_vectorized(
        base, overlay, segments[0][2], fairness, max_events, scenario,
        batched=(engine == "batched"), segments=tuple(segments),
        stop_time=stop_time, state_out=state,
    )
    return CarryoverState(
        time=float(state["time"]),
        remaining=state["remaining"],
        done=state["done"],
        cancelled=frozenset(state["cancelled"]),
        flow_done=tuple(float(x) for x in state["flow_done"]),
        departed=tuple(state["departed"]),
    )


def per_edge_loads(
    sol: RoutingSolution, overlay: OverlayNetwork
) -> dict[tuple[int, int], int]:
    """t_e per directed underlay edge (eq. 6) — for Lemma III.1 checks."""
    loads: dict[tuple[int, int], int] = {}
    for h, tree in enumerate(sol.trees):
        for (i, j) in tree:
            for e in overlay.path_edges(i, j):
                loads[e] = loads.get(e, 0) + 1
    return loads


def lemma31_time(
    sol: RoutingSolution, overlay: OverlayNetwork, kappa: float
) -> float:
    """Closed-form τ = max_e κ t_e / C_e from link-level knowledge (eq. 7)."""
    loads = per_edge_loads(sol, overlay)
    return max(
        (
            kappa * t / overlay.underlay.capacity(*e)
            for e, t in loads.items()
        ),
        default=0.0,
    )
