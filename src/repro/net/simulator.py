"""Fluid-level simulation of multicast completion times on the underlay.

Validates Lemma III.1/III.2 numerically: under equal bandwidth sharing at
every underlay link, the makespan for equal-size demands equals

    τ = max_e κ · t_e / C_e .

The simulator is event-driven with max-min fair rate allocation (what TCP
approximates): at each event, remaining flows receive max-min fair rates
given the underlay capacities; the next completion is advanced to. The
multicast flow h completes when its slowest unicast branch finishes; a
branch's traffic occupies every underlay edge of its (possibly relayed)
overlay path.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from repro.net.demands import MulticastDemand
from repro.net.routing import RoutingSolution
from repro.net.topology import OverlayNetwork


@dataclasses.dataclass(frozen=True)
class SimResult:
    makespan: float
    flow_completion: tuple[float, ...]  # per multicast demand
    num_events: int


def _unicast_branches(
    sol: RoutingSolution, overlay: OverlayNetwork
) -> list[tuple[int, tuple[tuple[int, int], ...]]]:
    """Expand each flow's tree into unicast branches over underlay edges.

    Each directed overlay link (i, j) in flow h's tree is an activated
    unicast flow carrying h's content over the underlay path p_{i,j}
    (paper Lemma III.1's definition).
    """
    branches = []
    for h, tree in enumerate(sol.trees):
        for (i, j) in tree:
            branches.append((h, overlay.path_edges(i, j)))
    return branches


def _maxmin_rates(
    active: Sequence[int],
    branch_edges: Sequence[tuple[tuple[int, int], ...]],
    capacity: Mapping[tuple[int, int], float],
) -> np.ndarray:
    """Progressive-filling max-min fair rates for the active branches."""
    n = len(active)
    rates = np.zeros(n)
    frozen = np.zeros(n, dtype=bool)
    cap_left = dict(capacity)
    # Count active branches per edge.
    while not frozen.all():
        counts: dict[tuple[int, int], int] = {}
        for a in range(n):
            if frozen[a]:
                continue
            for e in branch_edges[active[a]]:
                counts[e] = counts.get(e, 0) + 1
        # Bottleneck edge: smallest fair share among remaining capacity.
        share, bottleneck = math.inf, None
        for e, cnt in counts.items():
            s = cap_left[e] / cnt
            if s < share:
                share, bottleneck = s, e
        if bottleneck is None:
            break
        # Freeze all unfrozen branches crossing the bottleneck at `share`.
        for a in range(n):
            if frozen[a]:
                continue
            if bottleneck in branch_edges[active[a]]:
                rates[a] = share
                frozen[a] = True
                for e in branch_edges[active[a]]:
                    cap_left[e] -= share
    return rates


def _equal_share_rates(
    active: Sequence[int],
    branch_edges: Sequence[tuple[tuple[int, int], ...]],
    capacity: Mapping[tuple[int, int], float],
) -> np.ndarray:
    """Static equal sharing: every edge splits capacity evenly among its
    crossing branches; a branch gets its min share along the path
    (the allocation of Lemma III.1's achievability argument)."""
    counts: dict[tuple[int, int], int] = {}
    for a in active:
        for e in branch_edges[a]:
            counts[e] = counts.get(e, 0) + 1
    rates = np.empty(len(active))
    for idx, a in enumerate(active):
        rates[idx] = min(capacity[e] / counts[e] for e in branch_edges[a])
    return rates


def simulate(
    sol: RoutingSolution,
    overlay: OverlayNetwork,
    fairness: str = "maxmin",
    max_events: int = 100_000,
) -> SimResult:
    """Simulate completion of all multicast demands under ``sol``.

    fairness: "maxmin" (TCP-like, dynamic reallocation on completions) or
    "equal" (static equal split, re-evaluated on completions).
    """
    branches = _unicast_branches(sol, overlay)
    if not branches:
        return SimResult(0.0, tuple(0.0 for _ in sol.demands), 0)

    # Directed underlay edge capacities (each direction independent).
    capacity: dict[tuple[int, int], float] = {}
    for u, v, data in overlay.underlay.graph.edges(data=True):
        capacity[(u, v)] = float(data["capacity"])
        capacity[(v, u)] = float(data["capacity"])

    n = len(branches)
    remaining = np.array([sol.demands[h].size for h, _ in branches])
    done_time = np.full(n, np.nan)
    branch_edges = [edges for _, edges in branches]
    t = 0.0
    events = 0
    alloc = _maxmin_rates if fairness == "maxmin" else _equal_share_rates

    active = [a for a in range(n)]
    while active and events < max_events:
        rates = alloc(active, branch_edges, capacity)
        if not np.any(rates > 0):
            raise RuntimeError("starved branches; invalid routing/capacities")
        dt = np.min(remaining[active] / np.maximum(rates, 1e-300))
        t += dt
        remaining[active] -= rates * dt
        still = []
        for idx, a in enumerate(active):
            if remaining[a] <= 1e-9 * sol.demands[branches[a][0]].size:
                done_time[a] = t
            else:
                still.append(a)
        active = still
        events += 1

    flow_completion = []
    for h in range(len(sol.demands)):
        ts = [done_time[a] for a in range(n) if branches[a][0] == h]
        flow_completion.append(max(ts) if ts else 0.0)
    return SimResult(
        makespan=float(np.nanmax(done_time)),
        flow_completion=tuple(float(x) for x in flow_completion),
        num_events=events,
    )


def per_edge_loads(
    sol: RoutingSolution, overlay: OverlayNetwork
) -> dict[tuple[int, int], int]:
    """t_e per directed underlay edge (eq. 6) — for Lemma III.1 checks."""
    loads: dict[tuple[int, int], int] = {}
    for h, tree in enumerate(sol.trees):
        for (i, j) in tree:
            for e in overlay.path_edges(i, j):
                loads[e] = loads.get(e, 0) + 1
    return loads


def lemma31_time(
    sol: RoutingSolution, overlay: OverlayNetwork, kappa: float
) -> float:
    """Closed-form τ = max_e κ t_e / C_e from link-level knowledge (eq. 7)."""
    loads = per_edge_loads(sol, overlay)
    return max(
        (
            kappa * t / overlay.underlay.capacity(*e)
            for e, t in loads.items()
        ),
        default=0.0,
    )
