"""XLA-batched rollout engine: Monte-Carlo design pricing in one launch.

The numpy engines in ``net/simulator.py`` price one scenario at a time
from a Python event loop. This module ports the batched water-filling
engine (``engine="batched"``, the retained parity oracle) to jax: the
progressive-filling inner loop is a ``lax.while_loop`` over the fixed
CSR ``BranchIncidence`` (padded flat-entry arrays, int64 indices,
float64 throughout), the piecewise-constant scenario timeline is a
``lax.scan`` over per-phase capacity vectors (the
``CategoryIncidence.rescaled`` idea — swap the capacity vector, keep
the structure), and the whole stochastic batch runs in lockstep with
the rollout axis stored *last* on every array — hundreds of
realizations priced per device launch instead of one per Python loop
iteration.

Segment reductions over the incidence use bounded-degree tables
rather than CSR entry passes: ``branch_table``/``edge_table`` list
each row's neighbors padded to a static power-of-two width, so a
reduction is a handful of unrolled contiguous-row gathers over
[rows, R] arrays. On single-core CPU that is the difference between a
usable and an unusable kernel — XLA lowers ``segment_sum`` to
scatter-add (~25x slower per round) and even the cumsum-based
sorted-segment idiom pays ~5 ns/entry/rollout, while a batch-last row
gather runs at memory bandwidth (~1 µs per water-fill round per lane
at R=256).

Scope: ``fairness="maxmin"``, capacity phases, and churn — the paths
stochastic pricing actually exercises. Cross-traffic and straggler
events need the host event loop; entries here reject them with the
``engine="batched"`` fallback spelled out. Parity: per-rollout
makespan/flow-completion match ``engine="batched"`` to rtol=1e-9 on
the same realizations (property-tested; nightly-gated at 220 agents by
``benchmarks/rollout_scale.py``), and the event arithmetic — tie
detection by exact fp equality, breakpoint landing (``t = t_next``,
no drift), the 1e-9·κ finish threshold — mirrors the numpy loop term
for term. The capacity *drain* per water-fill round is grouped
(``smin × crossings`` versus numpy's sequential per-entry
subtraction), the same grouping difference that already separates
"batched" from "vectorized".

float64 is load-bearing: ``repro.compat.ensure_x64()`` runs at import,
and every entry re-checks via ``compat.require_x64()`` so pricing can
never silently run float32 (``X64NotEnabledError`` otherwise).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro import compat

compat.ensure_x64()  # before any jax array/trace below

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from repro.analysis.contracts import maybe_validate  # noqa: E402
from repro.net.simulator import (  # noqa: E402
    BranchIncidence,
    ChurnEvent,
    Scenario,
    SimResult,
    _collect_result,
    compile_incidence,
)
from repro.net.stochastic import (  # noqa: E402
    RealizationBatch,
    densify_realizations,
)


# ---------------------------------------------------------------------------
# Device-CSR layout
# ---------------------------------------------------------------------------


def _bucket(n: int) -> int:
    """Smallest power-of-two >= max(8, n + 1).

    Every axis is padded to a bucket so (a) nearby design sizes share
    one compiled XLA program instead of recompiling per branch count,
    and (b) each axis keeps at least one inert padding row — padding
    entries can always point at branch ``num_branches`` / edge
    ``num_edges`` even when the real count is itself a power of two.
    """
    return max(8, 1 << int(n).bit_length())


@dataclasses.dataclass(frozen=True)
class DeviceIncidence:
    """Padded device-CSR mirror of a ``BranchIncidence``.

    Arrays are host numpy (shipped to the device per launch); shapes
    are power-of-two buckets of the real sizes. Padding is inert by
    construction: padding entries point at the padding branch
    ``num_branches`` (never active, size 0) and the padding edge
    ``num_edges`` (capacity 1.0, crossed only by padding entries, so
    its count is always zero and its share always inf).

    Two entry orderings ride along so device segment reductions are
    sorted-segment: ``flat_branch``/``flat_edge`` are branch-major (as
    in the source incidence — ``flat_branch`` ascending) and
    ``edge_branch``/``edge_edge`` are edge-major (``edge_edge``
    ascending — the source's CSC order). ``branch_ptr``/``edge_ptr``
    extend the source CSR pointers over the padded rows (the pad row
    owns exactly the pad entries, every row past it is empty).

    The kernels themselves consume the bounded-degree *tables* derived
    from those pointers: ``branch_table[b]`` lists the edges branch
    ``b`` crosses (padded with the inert edge ``E``) and
    ``edge_table[e]`` lists the branches crossing edge ``e`` (padded
    with the inert branch ``B``). With the rollout axis stored *last*
    ([rows, R] arrays), a table row lookup is one contiguous-row
    gather — on single-core CPU that is ~60x cheaper per round than
    XLA's cumsum lowering over CSR entries, and orders of magnitude
    cheaper than its scatter-add segment sum. Prefixes are
    bitwise-equal to the source arrays (validated under
    ``REPRO_VALIDATE=1`` by
    ``repro.analysis.contracts.validate_device_incidence``).
    """

    source: BranchIncidence
    num_branches: int
    num_edges: int
    num_entries: int
    flat_branch: np.ndarray  # [Z] int64, branch-major; padding -> B
    flat_edge: np.ndarray  # [Z] int64, branch-major; padding -> E
    edge_branch: np.ndarray  # [Z] int64, edge-major; padding -> B
    edge_edge: np.ndarray  # [Z] int64, edge-major ascending; padding -> E
    branch_ptr: np.ndarray  # [B_pad+1] int64 CSR ptr into flat_* arrays
    edge_ptr: np.ndarray  # [E_pad+1] int64 CSR ptr into edge_* arrays
    branch_table: np.ndarray  # [B_pad, D] int32 edges per branch; pad -> E
    edge_table: np.ndarray  # [E_pad, K] int32 branches per edge; pad -> B
    base_capacity: np.ndarray  # [E_pad] float64; padding 1.0
    sizes: np.ndarray  # [B_pad] float64 per-branch demand; padding 0.0

    def __post_init__(self):
        # Padded-layout contract; no-op unless REPRO_VALIDATE=1
        # (repro.analysis.contracts.validate_device_incidence).
        maybe_validate(self)

    @property
    def padded_branches(self) -> int:
        return self.sizes.size

    @property
    def padded_edges(self) -> int:
        return self.base_capacity.size


def _table_width(max_degree: int) -> int:
    """Smallest power-of-two >= max(2, max_degree) — bucketed so nearby
    instances share compiled programs, floored at 2 so the kernels'
    unrolled table reduction always has a fixed minimum shape."""
    return max(2, 1 << max(0, int(max_degree) - 1).bit_length())


def _pack_table(
    ptr: np.ndarray, values: np.ndarray, rows: int, fill: int
) -> np.ndarray:
    """[rows, W] int32 table of each CSR row's values.

    ``W`` is the bucketed max real row degree; short rows and pad rows
    (real row count up to ``rows``) are filled with ``fill`` — the
    inert pad index whose mask value is always False, so table padding
    contributes exactly zero to every kernel reduction."""
    deg = np.diff(ptr)
    width = _table_width(int(deg.max(initial=0)))
    table = np.full((rows, width), fill, dtype=np.int32)
    real = deg.size
    cols = np.arange(width)[None, :]
    mask = cols < deg[:, None]
    table[:real][mask] = values
    return table


def device_incidence(
    inc: BranchIncidence, flow_size: np.ndarray
) -> DeviceIncidence:
    """Pad ``inc`` into the device layout.

    ``flow_size[h]`` is demand h's size in bytes; per-branch sizes are
    gathered through ``inc.flows``. The edge-major ordering reuses the
    source's CSC arrays (``edge_branch`` + the edge ids its ``edge_ptr``
    implies), so no re-sort happens here.
    """
    nb, ne = inc.num_branches, inc.num_edges
    nnz = inc.flat_branch.size
    bp, ep, zp = _bucket(nb), _bucket(ne), _bucket(nnz)
    fb = np.full(zp, nb, dtype=np.int64)
    fb[:nnz] = inc.flat_branch
    fe = np.full(zp, ne, dtype=np.int64)
    fe[:nnz] = inc.flat_edge
    eb = np.full(zp, nb, dtype=np.int64)
    eb[:nnz] = inc.edge_branch
    ee = np.full(zp, ne, dtype=np.int64)
    ee[:nnz] = np.repeat(
        np.arange(ne, dtype=np.int64), np.diff(inc.edge_ptr)
    )
    cap = np.ones(ep, dtype=np.float64)
    cap[:ne] = inc.base_capacity
    sizes = np.zeros(bp, dtype=np.float64)
    sizes[:nb] = flow_size[inc.flows]
    # Padded CSR pointers: the pad row (branch nb / edge ne) owns the
    # pad entries [nnz, zp); every row past it is empty at zp.
    bptr = np.full(bp + 1, zp, dtype=np.int64)
    bptr[: nb + 1] = inc.branch_ptr
    eptr = np.full(ep + 1, zp, dtype=np.int64)
    eptr[: ne + 1] = inc.edge_ptr
    return DeviceIncidence(
        source=inc,
        num_branches=nb,
        num_edges=ne,
        num_entries=nnz,
        flat_branch=fb,
        flat_edge=fe,
        edge_branch=eb,
        edge_edge=ee,
        branch_ptr=bptr,
        edge_ptr=eptr,
        branch_table=_pack_table(
            inc.branch_ptr, inc.flat_edge, bp, fill=ne
        ),
        edge_table=_pack_table(
            inc.edge_ptr, inc.edge_branch, ep, fill=nb
        ),
        base_capacity=cap,
        sizes=sizes,
    )


# ---------------------------------------------------------------------------
# Scenario lowering (host side)
# ---------------------------------------------------------------------------


def _check_supported(scenario: Scenario | None, fairness: str) -> None:
    if fairness != "maxmin":
        raise ValueError(
            "engine='jax' implements fairness='maxmin' only; price "
            "equal-share allocations with engine='batched'"
        )
    if scenario is not None and (
        scenario.cross_traffic or scenario.stragglers
    ):
        raise ValueError(
            "engine='jax' lowers capacity phases and churn only; "
            "cross-traffic and straggler events need the host event "
            "loop — price this scenario with engine='batched'"
        )


def branch_cancel_times(
    inc: BranchIncidence,
    flow_source: np.ndarray,
    churn: Sequence[ChurnEvent],
) -> np.ndarray:
    """Earliest departure cancelling each branch ([B] float64, +inf when
    none) — churn lowered to a static per-branch quantity so per-rollout
    departure times stay one dense vmap axis. A departure hits branches
    on overlay links touching the agent and all branches of flows it
    sources, exactly the numpy loop's rule."""
    cancel = np.full(inc.num_branches, np.inf, dtype=np.float64)
    src = flow_source[inc.flows]
    for ev in churn:
        hit = (
            (inc.links[:, 0] == ev.agent)
            | (inc.links[:, 1] == ev.agent)
            | (src == ev.agent)
        )
        np.minimum(
            cancel, np.where(hit, float(ev.time), np.inf), out=cancel
        )
    return cancel


def batch_cancel_times(
    inc: BranchIncidence,
    flow_source: np.ndarray,
    batch: RealizationBatch,
) -> np.ndarray:
    """Per-rollout branch cancellation times ([R, B] float64, +inf when
    none): ``branch_cancel_times`` applied to each realization's churn
    schedule — the host half of the churn lowering that
    ``rollout_batch_results`` (and the trace-lint registry) feeds to
    the device launch."""
    cancel = np.empty(
        (batch.num_rollouts, inc.num_branches), dtype=np.float64
    )
    for r, churn in enumerate(batch.churn):
        cancel[r] = branch_cancel_times(inc, flow_source, churn)
    return cancel


# ---------------------------------------------------------------------------
# Device kernels
# ---------------------------------------------------------------------------


def _table_any(mask, table):
    """OR-reduce ``mask`` rows through a bounded-degree table:
    ``out[i] = any(mask[table[i, k]] for k)``, unrolled over the static
    width. With the rollout axis last, every ``mask[table[:, k], :]``
    is a contiguous-row gather — the layout trick that keeps the
    per-round cost at memory bandwidth instead of XLA's scatter or
    cumsum lowerings (≥25x slower per round on single-core CPU)."""
    out = mask[table[:, 0], :]
    for k in range(1, table.shape[1]):
        out = jnp.logical_or(out, mask[table[:, k], :])
    return out


def _table_count(mask, table, dtype):
    """Count-reduce ``mask`` rows through a bounded-degree table:
    ``out[i] = sum(mask[table[i, k]] for k)`` (exact — a count is at
    most the static table width, so int16 suffices below 32768), same
    contiguous-row-gather layout as ``_table_any``."""
    out = mask[table[:, 0], :].astype(dtype)
    for k in range(1, table.shape[1]):
        out = out + mask[table[:, k], :]
    return out


def _waterfill(active, caps, branch_table, edge_table):
    """Batched water-filling on device — ``_maxmin_rates_batched`` with
    the per-round capacity drain grouped as ``smin × crossings``, every
    array carrying the rollout axis *last* ([B_pad, R] / [E_pad, R]).

    The loop is memory-bandwidth-bound, so the carried state is the
    cheapest exact encoding of numpy's:

    - Counts (unfrozen crossers per edge) are carried across rounds:
      because every frozen branch was unfrozen the round it froze, the
      drained crossings are exactly ``counts - counts_next`` — an
      exact integer difference matching numpy's incrementally
      maintained counts, and one fewer table reduction per round.
    - The share map is carried too, computed fused with the capacity
      drain from the just-updated ``(cap_left, counts)`` — the same
      operands numpy divides at the top of its next round, so the
      values are bitwise identical while the loop saves a full
      [E_pad, R] read-modify-write.
    - Rates are stamped in place the round a branch freezes
      (``where(freeze, smin, rates)``). A round-log + gather
      reconstruction was measured too: its ``dynamic_update_slice``
      blocks fusion across the unrolled round boundary and loses ~10%
      despite carrying less state.

    Tied edges are detected by exact fp equality with the lane's
    minimum share, and every unfrozen crosser of a tied edge freezes
    at ``smin``. Lanes converge independently: a lane with nothing
    unfrozen (or a non-finite minimum share) has an all-inf share map,
    which makes ``ok`` false and every update a no-op — the same
    per-lane masking ``vmap`` of a ``while_loop`` would apply.
    """
    num_b, num_r = active.shape
    cdtype = jnp.int16 if edge_table.shape[1] < 2**15 else jnp.int32

    def cond(state):
        unfrozen, stop = state[0], state[4]
        return jnp.any(
            jnp.logical_and(jnp.any(unfrozen, axis=0), ~stop)
        )

    def body(state):
        unfrozen, counts, cap_left, share, stop, rates = state
        smin = jnp.min(share, axis=0)
        ok = jnp.logical_and(jnp.isfinite(smin), ~stop)
        tied = share == smin[None, :]
        # No unfrozen mask on the tied pass: frozen branches crossing
        # a tied edge are filtered branch-side by ``& unfrozen`` below.
        hit = _table_any(tied, branch_table)
        freeze = jnp.logical_and(
            jnp.logical_and(hit, unfrozen), ok[None, :]
        )
        unfrozen = jnp.logical_and(unfrozen, jnp.logical_not(freeze))
        counts_next = _table_count(unfrozen, edge_table, cdtype)
        smin_safe = jnp.where(ok, smin, 0.0)
        rates = jnp.where(freeze, smin_safe[None, :], rates)
        # freeze ⊆ unfrozen, so counts - counts_next is exactly the
        # crossings drained this round. Draining as two fma passes
        # (instead of materializing the int->f64 cast of the
        # difference) measures ~15% faster per round. It is a third
        # grouping of numpy's sequential per-entry drain — "batched"
        # vs "vectorized" already differ the same way, and the parity
        # contract is rtol=1e-9 on results, not bitwise drains.
        cap_left = (
            cap_left
            - smin_safe[None, :] * counts
            + smin_safe[None, :] * counts_next
        )
        share = jnp.where(
            counts_next > 0,
            cap_left / counts_next.astype(jnp.float64),
            jnp.inf,
        )
        stop = jnp.logical_or(stop, jnp.logical_not(jnp.isfinite(smin)))
        return unfrozen, counts_next, cap_left, share, stop, rates

    counts0 = _table_count(active, edge_table, cdtype)
    share0 = jnp.where(
        counts0 > 0, caps / counts0.astype(jnp.float64), jnp.inf
    )
    state = (
        active, counts0, caps, share0,
        jnp.zeros((num_r,), dtype=bool),
        jnp.zeros((num_b, num_r), dtype=jnp.float64),
    )
    # Two rounds per loop iteration: a round past convergence is an
    # exact no-op (``ok`` false everywhere -> nothing freezes, nothing
    # drains, no rate is stamped), and the unroll lets XLA fuse across
    # the round boundary — measured ~20% faster than checking ``cond``
    # every round.
    state = lax.while_loop(cond, lambda s: body(body(s)), state)
    return state[5]


def _simulate_batch(caps_pp, cancel_time, active0, sizes, starts,
                    max_events, branch_table, edge_table):
    """All rollouts on device: ``lax.scan`` over the shared boundary
    grid, a ``lax.while_loop`` event loop per interval — the numpy
    event loop's arithmetic verbatim per lane (dt selection, exact
    boundary landing, finish threshold), with the rollout axis last on
    every array ([B_pad, R] state, [P, E_pad, R] capacities). Lanes
    advance independently: every update is masked by the lane's own
    loop condition (``live``), exactly the masking ``vmap`` of a
    ``while_loop`` applies, so per-lane results are bitwise those of a
    one-lane run. Churn applies at interval entry (every churn time is
    a grid boundary). Starvation (no positive rate, no future
    boundary) sets a per-lane flag the host raises on — exceptions
    cannot cross jit.
    """
    thresh = 1e-9 * sizes
    ends = jnp.concatenate(
        [starts[1:], jnp.full((1,), jnp.inf, dtype=jnp.float64)]
    )

    def phase_step(carry, xs):
        caps, t_start, t_end = xs
        t, remaining, done_time, cancelled, active, events, starved = carry
        newly = jnp.logical_and(active, cancel_time <= t_start)
        cancelled = jnp.logical_or(cancelled, newly)
        active = jnp.logical_and(active, jnp.logical_not(newly))

        def lanes_live(t_, act, ev, stv):
            return (
                jnp.any(act, axis=0)
                & (t_ < t_end)
                & jnp.logical_not(stv)
                & (ev < max_events)
            )

        def cond(s):
            t_, _rem, _done, act, ev, stv = s
            return jnp.any(lanes_live(t_, act, ev, stv))

        def body(s):
            t_, remaining_, done_, active_, events_, starved_ = s
            live = lanes_live(t_, active_, events_, starved_)
            # Lanes already done this interval enter the water-fill
            # with nothing unfrozen, so they cost no extra rounds and
            # their (zero) rates are discarded by the masks below.
            rates = _waterfill(
                jnp.logical_and(active_, live[None, :]), caps,
                branch_table, edge_table,
            )
            pos = jnp.any(
                jnp.where(active_, rates, 0.0) > 0.0, axis=0
            )
            starved_now = (
                jnp.logical_not(pos) & jnp.isinf(t_end) & live
            )
            dt0 = jnp.min(
                jnp.where(
                    active_,
                    remaining_ / jnp.maximum(rates, 1e-300),
                    jnp.inf,
                ),
                axis=0,
            )
            bdt = t_end - t_
            use_b = bdt < dt0
            dt = jnp.where(use_b, bdt, dt0)
            t_new = jnp.where(use_b, t_end, t_ + dt0)
            t_new = jnp.where(starved_now, t_, t_new)
            # All-nonpositive rates jump to the boundary without
            # draining (numpy's `continue` path); mixed-sign rounds
            # subtract for every active branch as numpy does.
            dt_eff = jnp.where(pos, dt, 0.0)
            update = jnp.logical_and(active_, live[None, :])
            remaining_ = jnp.where(
                update, remaining_ - rates * dt_eff[None, :], remaining_
            )
            finished = jnp.logical_and(
                update, remaining_ <= thresh[:, None]
            )
            done_ = jnp.where(
                finished, jnp.broadcast_to(t_new[None, :], done_.shape),
                done_,
            )
            active_ = jnp.logical_and(active_, jnp.logical_not(finished))
            return (
                jnp.where(live, t_new, t_), remaining_, done_, active_,
                events_ + live.astype(jnp.int64),
                jnp.logical_or(starved_, starved_now),
            )

        t, remaining, done_time, active, events, starved = lax.while_loop(
            cond, body, (t, remaining, done_time, active, events, starved)
        )
        return (
            t, remaining, done_time, cancelled, active, events, starved
        ), None

    num_b, num_r = active0.shape
    init = (
        jnp.zeros((num_r,), dtype=jnp.float64),
        jnp.broadcast_to(sizes[:, None], (num_b, num_r)),
        jnp.full((num_b, num_r), jnp.nan, dtype=jnp.float64),
        jnp.zeros((num_b, num_r), dtype=bool),
        active0,
        jnp.zeros((num_r,), dtype=jnp.int64),
        jnp.zeros((num_r,), dtype=bool),
    )
    carry, _ = lax.scan(phase_step, init, (caps_pp, starts, ends))
    _t, _remaining, done_time, cancelled, active, events, starved = carry
    return done_time, cancelled, active, events, starved


@jax.jit
def _run_batch(branch_table, edge_table, sizes, active0, starts, caps,
               cancel, max_events):
    """One XLA launch for the whole Monte-Carlo batch: ``caps`` is
    [P, E_pad, R] and ``cancel``/``active0`` are [B_pad, R] — rollout
    axis last throughout (see ``_simulate_batch``)."""
    return _simulate_batch(
        caps, cancel, active0, sizes, starts, max_events,
        branch_table, edge_table,
    )


# ---------------------------------------------------------------------------
# Host entry points
# ---------------------------------------------------------------------------


def device_args(
    dev: DeviceIncidence,
    starts: np.ndarray,
    caps: np.ndarray,
    cancel_times: np.ndarray,
    max_events: int = 100_000,
) -> tuple:
    """The exact argument tuple ``run_rollouts`` launches ``_run_batch``
    with: host-side padding of ``caps`` [R, P, E] / ``cancel_times``
    [R, B] into the device buckets, rollout axis moved last
    ([P, E_pad, R] / [B_pad, R] — see ``_simulate_batch`` for why the
    kernel wants that layout). Exposed so the trace lint
    (``repro.analysis.tracelint``) certifies ``_run_batch`` against the
    argument shapes the real host path produces, not a reconstruction.
    """
    caps = np.asarray(caps, dtype=np.float64)
    cancel_times = np.asarray(cancel_times, dtype=np.float64)
    rollouts = caps.shape[0]
    nb, ne = dev.num_branches, dev.num_edges
    starts = np.asarray(starts, dtype=np.float64)
    caps_p = np.ones(
        (starts.size, dev.padded_edges, rollouts), dtype=np.float64
    )
    caps_p[:, :ne, :] = np.transpose(caps, (1, 2, 0))
    cancel_p = np.full(
        (dev.padded_branches, rollouts), np.inf, dtype=np.float64
    )
    cancel_p[:nb, :] = cancel_times.T
    active0 = np.zeros((dev.padded_branches, rollouts), dtype=bool)
    active0[:nb, :] = True
    return (
        dev.branch_table, dev.edge_table, dev.sizes, active0, starts,
        caps_p, cancel_p, np.asarray(max_events, dtype=np.int64),
    )


def run_rollouts(
    dev: DeviceIncidence,
    starts: np.ndarray,
    caps: np.ndarray,
    cancel_times: np.ndarray,
    max_events: int = 100_000,
) -> list[tuple[np.ndarray, np.ndarray, int, int]]:
    """Run R rollouts in one launch; per rollout returns
    ``(done_time[B], cancelled[B], events, unfinished)`` on the real
    (unpadded) branches.

    ``caps`` is [R, P, E] on the source incidence's edges and
    ``cancel_times`` is [R, B]; padding to the device buckets happens
    in ``device_args``. Raises the numpy engines' starvation
    ``RuntimeError`` if any rollout starves (all-zero rates with no
    future boundary).
    """
    compat.require_x64()
    nb = dev.num_branches
    rollouts = np.asarray(caps).shape[0]
    done, cancelled, active, events, starved = (
        np.asarray(a)
        for a in _run_batch(
            *device_args(dev, starts, caps, cancel_times, max_events)
        )
    )
    if bool(np.any(starved)):
        raise RuntimeError("starved branches; invalid routing/capacities")
    return [
        (
            done[:nb, r],
            cancelled[:nb, r],
            int(events[r]),
            int(active[:nb, r].sum()),
        )
        for r in range(rollouts)
    ]


def simulate_jax(
    sol,
    overlay,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    scenario: Scenario | None = None,
    incidence: BranchIncidence | None = None,
    extra_boundaries: Sequence[float] = (),
) -> SimResult:
    """``simulate(engine="jax")``: one deterministic run on the device.

    Semantically ``engine="batched"`` for the supported scenario
    surface (maxmin fairness; capacity phases + churn), to rtol=1e-9.
    ``extra_boundaries`` adds grid boundaries (how ``simulate_phased``
    lands exactly on its segment starts).
    """
    compat.require_x64()
    _check_supported(scenario, fairness)
    if scenario is not None:
        scenario.validate()
        m = overlay.num_agents
        for ev in scenario.churn:
            if not 0 <= ev.agent < m:
                raise ValueError(
                    f"scenario references agent {ev.agent}, but the "
                    f"overlay has {m} agents"
                )
    if incidence is None:
        branches = sol.unicast_branches(overlay)
        if not branches:
            return SimResult(0.0, tuple(0.0 for _ in sol.demands), 0)
        incidence = compile_incidence(sol, overlay, branches)
    elif incidence.num_branches == 0:
        return SimResult(0.0, tuple(0.0 for _ in sol.demands), 0)
    flow_size = np.array([d.size for d in sol.demands], dtype=np.float64)
    flow_source = np.array(
        [d.source for d in sol.demands], dtype=np.int64
    )
    dev = device_incidence(incidence, flow_size)
    batch = densify_realizations(
        (scenario if scenario is not None else Scenario(),),
        incidence, extra_boundaries=extra_boundaries,
    )
    cancel = branch_cancel_times(
        incidence, flow_source, batch.churn[0]
    )
    ((done, cancelled, events, unfinished),) = run_rollouts(
        dev, batch.starts, batch.capacity, cancel[None], max_events
    )
    return _collect_result(
        sol, incidence.flows, done, cancelled, events, unfinished
    )


def rollout_batch_results(
    sol,
    dev: DeviceIncidence,
    batch: RealizationBatch,
    max_events: int = 100_000,
) -> tuple[SimResult, ...]:
    """Price every realization in ``batch`` against the precompiled
    ``dev`` in one vmapped launch — the designer's hot path. Returns
    one ``SimResult`` per rollout, in rollout order, with the numpy
    engines' NaN/cancellation semantics (``_collect_result``)."""
    compat.require_x64()
    inc = dev.source
    flow_source = np.array(
        [d.source for d in sol.demands], dtype=np.int64
    )
    cancel = batch_cancel_times(inc, flow_source, batch)
    outs = run_rollouts(
        dev, batch.starts, batch.capacity, cancel, max_events
    )
    return tuple(
        _collect_result(sol, inc.flows, done, cancelled, events, unfin)
        for done, cancelled, events, unfin in outs
    )


def simulate_rollout_batch(
    sol,
    overlay,
    batch: RealizationBatch,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    incidence: BranchIncidence | None = None,
) -> tuple[SimResult, ...]:
    """Price a whole ``RealizationBatch`` in one XLA launch.

    The incidence is compiled (or taken precompiled) once for the
    activated-link set and shared by every rollout; registered against
    ``_rollout_batch_reference`` — the numpy ``engine="batched"``
    loop over the same realizations — in ``parity_manifest.txt``
    (per-rollout makespan/flow-completion parity at rtol=1e-9).
    """
    if fairness != "maxmin":
        raise ValueError(
            "engine='jax' implements fairness='maxmin' only; price "
            "equal-share allocations with engine='batched'"
        )
    if incidence is None:
        incidence = compile_incidence(sol, overlay)
    flow_size = np.array([d.size for d in sol.demands], dtype=np.float64)
    dev = device_incidence(incidence, flow_size)
    return rollout_batch_results(sol, dev, batch, max_events=max_events)


def _rollout_batch_reference(
    sol,
    overlay,
    batch: RealizationBatch,
    fairness: str = "maxmin",
    max_events: int = 100_000,
    incidence: BranchIncidence | None = None,
) -> tuple[SimResult, ...]:
    """Numpy oracle for ``simulate_rollout_batch``: the Python rollout
    loop over the batch's realizations with ``engine="batched"`` — the
    pre-device pricing path, kept as the parity reference the device
    engine is property-tested and nightly-gated against."""
    from repro.net.simulator import simulate

    return tuple(
        simulate(
            sol, overlay, fairness=fairness, max_events=max_events,
            scenario=sc, engine="batched", incidence=incidence,
        )
        for sc in batch.realizations
    )
