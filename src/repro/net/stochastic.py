"""Stochastic scenario layer — Markov-modulated links, correlated
outages, random churn (ROADMAP "stochastic capacity processes").

The deterministic ``Scenario`` prices a design against ONE realization
of the network's future. Real edge links fluctuate stochastically, so
this module describes *distributions* over scenarios and draws seeded
realizations from them:

  * ``MarkovLinkModel``    — a discrete-time Markov chain modulating the
    capacity of a group of underlay edges (states = capacity scales,
    e.g. good/degraded/outage), stepped at fixed boundary spacing.
  * ``CorrelatedOutages``  — a shared-shock process: one global shock
    (weather, backhaul flap, interference burst) hits several edge
    groups at once, so outages are *correlated* across links instead of
    independent — the regime that actually breaks single-path designs.
  * ``StochasticScenario`` — composes the above (plus optional random
    agent churn and a deterministic ``base`` scenario) on a fixed
    horizon. ``sample(key)`` draws one concrete piecewise-constant
    realization as an ordinary ``Scenario`` reusing ``CapacityPhase`` /
    ``ChurnEvent`` — so every existing consumer (``simulate``,
    ``simulate_phased``, ``evaluate_design``,
    ``FaultToleranceController``) prices realizations unchanged.

Sampling is deterministic in the key: the same key yields a bitwise-
identical realization (property-tested), which makes stochastic pricing
(`evaluate_design(stochastic_rollouts=N)`) a *seeded expectation* — a
reproducible number, not a flaky one. The per-step draw order is fixed
(Markov models in declaration order, then the outage shock, then churn
hazards), so adding draws at the end of a step never perturbs earlier
ones within the same release.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.net.simulator import (
    CapacityPhase,
    ChurnEvent,
    Scenario,
    _phase_capacity_array,
)


@dataclasses.dataclass(frozen=True)
class MarkovLinkModel:
    """Discrete-time Markov-modulated capacity process on a group of
    underlay edges.

    All edges in ``edges`` share one chain (they degrade together — a
    congested backhaul region, a shared radio channel). ``scales[s]`` is
    the capacity multiplier in state ``s``; ``transition[s]`` is the
    row-stochastic distribution of the next state, applied at every
    boundary of the enclosing ``StochasticScenario``. A one-state model
    with ``scales == (1.0,)`` is the degenerate deterministic link —
    its realizations are trivially static (property-tested).
    """

    edges: tuple[tuple[int, int], ...]
    scales: tuple[float, ...]
    transition: tuple[tuple[float, ...], ...]
    initial: int = 0

    def validate(self) -> None:
        n = len(self.scales)
        if n == 0:
            raise ValueError("MarkovLinkModel needs at least one state")
        if not self.edges:
            raise ValueError("MarkovLinkModel needs at least one edge")
        if any(s <= 0 for s in self.scales):
            raise ValueError("capacity scales must be positive")
        if not 0 <= self.initial < n:
            raise ValueError(
                f"initial state {self.initial} out of range for {n} states"
            )
        if len(self.transition) != n:
            raise ValueError("transition matrix must be square in #states")
        for row in self.transition:
            if len(row) != n:
                raise ValueError(
                    "transition matrix must be square in #states"
                )
            if any(p < 0 for p in row):
                raise ValueError("transition probabilities must be >= 0")
            if not math.isclose(sum(row), 1.0, rel_tol=0, abs_tol=1e-9):
                raise ValueError(
                    f"transition rows must sum to 1 (got {sum(row)!r})"
                )

    @property
    def is_degenerate(self) -> bool:
        """True when the chain can never leave a scale-1.0 state set
        reachable from ``initial`` — i.e. one state at base capacity."""
        return len(self.scales) == 1 and float(self.scales[0]) == 1.0


@dataclasses.dataclass(frozen=True)
class CorrelatedOutages:
    """Shared-shock outage process over several edge groups.

    At every boundary a global shock fires with probability
    ``shock_prob``; conditional on the shock, each group independently
    joins the outage with probability ``group_prob`` and drops to
    ``scale`` × base capacity for ``duration_steps`` boundaries. Because
    the groups share the shock draw, outages are correlated — several
    regions of the underlay sag *simultaneously*, which is the case a
    per-link-independent model understates.
    """

    groups: tuple[tuple[tuple[int, int], ...], ...]
    shock_prob: float
    group_prob: float = 1.0
    duration_steps: int = 1
    scale: float = 0.05

    def validate(self) -> None:
        if not self.groups or any(not g for g in self.groups):
            raise ValueError("outage groups must be nonempty")
        for p, name in (
            (self.shock_prob, "shock_prob"),
            (self.group_prob, "group_prob"),
        ):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.duration_steps < 1:
            raise ValueError("duration_steps must be >= 1")
        if self.scale <= 0:
            raise ValueError("outage scale must be positive")


@dataclasses.dataclass(frozen=True)
class StochasticScenario:
    """Distribution over ``Scenario`` realizations on a fixed horizon.

    ``step`` is the boundary spacing in seconds: all stochastic
    processes evolve at t = 0, step, 2·step, … < ``horizon`` (the last
    sampled state persists beyond the horizon — capacity phases are
    piecewise-constant to ∞). ``base`` carries deterministic events
    (cross-traffic, stragglers, scheduled churn) folded into every
    realization; it must not carry capacity phases of its own — the
    sampled per-edge scales would not compose with them (the simulator
    applies the *latest* phase, it does not multiply overlapping ones).

    ``churn_hazard`` gives each agent in ``churn_agents`` an independent
    per-boundary departure probability (departure is absorbing; the
    resulting ``ChurnEvent``s reuse the deterministic machinery).

    ``sample(key)`` accepts anything ``np.random.default_rng`` accepts
    (an int, a tuple of ints, a ``SeedSequence``) and is bitwise-
    deterministic in it.
    """

    links: tuple[MarkovLinkModel, ...] = ()
    outages: CorrelatedOutages | None = None
    step: float = 60.0
    horizon: float = 600.0
    base: Scenario = Scenario()
    churn_agents: tuple[int, ...] = ()
    churn_hazard: float = 0.0

    def validate(self) -> None:
        if self.step <= 0:
            raise ValueError("step must be positive")
        if self.horizon < self.step:
            raise ValueError("horizon must cover at least one step")
        for model in self.links:
            model.validate()
        if self.outages is not None:
            self.outages.validate()
        if self.base.capacity_phases:
            raise ValueError(
                "base scenario must not carry capacity phases: sampled "
                "per-edge scales do not compose with deterministic "
                "phases (the simulator applies the latest phase, it "
                "does not multiply overlapping ones)"
            )
        if not 0.0 <= self.churn_hazard <= 1.0:
            raise ValueError("churn_hazard must be in [0, 1]")
        if self.churn_hazard > 0 and not self.churn_agents:
            raise ValueError("churn_hazard needs churn_agents")
        self.base.validate()

    @property
    def num_steps(self) -> int:
        return int(math.ceil(self.horizon / self.step))

    @property
    def is_trivial(self) -> bool:
        """True when every realization is the (static) base scenario."""
        return (
            all(m.is_degenerate for m in self.links)
            and self.outages is None
            and self.churn_hazard == 0.0
        )

    def sample(self, key) -> Scenario:
        """Draw one piecewise-constant realization as a ``Scenario``.

        Bitwise-deterministic in ``key``. Consecutive boundaries with an
        unchanged effective scale map emit no phase (realizations are
        minimal), and a map that returns to all-ones emits a scalar
        ``scale=1.0`` recovery phase.
        """
        self.validate()
        rng = np.random.default_rng(key)
        states = [m.initial for m in self.links]
        outage_left = (
            [0] * len(self.outages.groups) if self.outages is not None
            else []
        )
        phases: list[CapacityPhase] = []
        churn: list[ChurnEvent] = []
        alive = list(self.churn_agents)
        prev_map: dict[tuple[int, int], float] = {}
        for k in range(self.num_steps):
            t = k * self.step
            # 1. Markov transitions (models in declaration order; the
            # initial states apply at t=0, transitions from the first
            # boundary on).
            if k > 0:
                for mi, model in enumerate(self.links):
                    row = model.transition[states[mi]]
                    states[mi] = int(rng.choice(len(row), p=row))
            # 2. Correlated outage shock (one draw gates every group).
            if self.outages is not None:
                outage_left = [max(0, d - 1) for d in outage_left]
                if rng.random() < self.outages.shock_prob:
                    for gi in range(len(self.outages.groups)):
                        if rng.random() < self.outages.group_prob:
                            outage_left[gi] = self.outages.duration_steps
            # 3. Churn hazards (absorbing; agents in declaration order).
            if self.churn_hazard > 0:
                still = []
                for agent in alive:
                    if rng.random() < self.churn_hazard:
                        churn.append(ChurnEvent(agent=agent, time=t))
                    else:
                        still.append(agent)
                alive = still
            # Effective scale per edge: product over Markov models and
            # active outage groups touching it (multiplicative — a
            # degraded link inside an outage region sags twice).
            scale_map: dict[tuple[int, int], float] = {}
            for mi, model in enumerate(self.links):
                f = float(model.scales[states[mi]])
                if f != 1.0:
                    for e in model.edges:
                        scale_map[e] = scale_map.get(e, 1.0) * f
            if self.outages is not None:
                for gi, left in enumerate(outage_left):
                    if left > 0:
                        for e in self.outages.groups[gi]:
                            scale_map[e] = (
                                scale_map.get(e, 1.0) * self.outages.scale
                            )
            if scale_map != prev_map and not (k == 0 and not scale_map):
                phases.append(
                    CapacityPhase(
                        start=t,
                        scale=dict(scale_map) if scale_map else 1.0,
                    )
                )
            prev_map = scale_map
        churn.extend(self.base.churn)
        return Scenario(
            capacity_phases=tuple(phases),
            cross_traffic=self.base.cross_traffic,
            stragglers=self.base.stragglers,
            churn=tuple(
                sorted(churn, key=lambda c: (c.time, c.agent))
            ),
            floor_frac=self.base.floor_frac,
        )

    def sample_many(self, seed, n: int) -> tuple[Scenario, ...]:
        """N independent realizations, seeded as (seed, rollout-index) —
        the contract ``evaluate_design(stochastic_rollouts=N)`` uses, so
        rollout r of a sweep is reproducible in isolation."""
        return tuple(self.sample((seed, r)) for r in range(n))

    def realization_batch(
        self, seed, n: int, incidence, extra_boundaries=()
    ) -> "RealizationBatch":
        """N realizations densified for the device engine: the same
        seeded draws as ``sample_many(seed, n)`` (bitwise — the batch
        wraps those very ``Scenario`` objects), lowered onto a shared
        boundary grid as one ``[rollouts, phases, edges]`` capacity
        tensor over ``incidence``'s indexed edges, so
        ``jax_engine.simulate_rollout_batch`` can ``vmap`` the whole
        Monte-Carlo batch in one XLA launch."""
        return densify_realizations(
            self.sample_many(seed, n), incidence,
            extra_boundaries=extra_boundaries,
        )


def realization_deltas(
    scenario: Scenario,
) -> tuple[tuple[float, dict[tuple[int, int], float]], ...]:
    """Event-source a sampled realization: per capacity phase, the edges
    whose effective scale *changed* at that boundary.

    ``StochasticScenario.sample`` emits minimal piecewise-constant
    phases, but each phase carries the full *absolute* scale map. The
    design service wants deltas — only the links that actually moved —
    so it can absorb or patch per touched edge instead of re-scanning
    the whole map. Each returned entry is ``(time, {edge: new_scale})``
    where ``new_scale`` is the absolute multiplier vs base capacity
    (1.0 means the edge recovered). Edges inside each delta are emitted
    in sorted order so downstream iteration is deterministic.

    Only scalar-1.0 phases (all-clear recovery, the only scalar form
    ``sample`` emits) and per-edge maps are accepted; a scalar phase
    with scale != 1.0 would need the underlay edge set to expand and is
    rejected.
    """
    deltas: list[tuple[float, dict[tuple[int, int], float]]] = []
    prev: dict[tuple[int, int], float] = {}
    for phase in scenario.capacity_phases:
        if isinstance(phase.scale, (int, float)):
            if float(phase.scale) != 1.0:
                raise ValueError(
                    "realization_deltas needs per-edge scale maps; got a "
                    f"scalar phase with scale={phase.scale!r}"
                )
            cur: dict[tuple[int, int], float] = {}
        else:
            cur = {e: float(s) for e, s in phase.scale.items()}
        changed = {
            e: cur.get(e, 1.0)
            for e in sorted({*prev, *cur})
            if cur.get(e, 1.0) != prev.get(e, 1.0)
        }
        if changed:
            deltas.append((float(phase.start), changed))
        prev = cur
    return tuple(deltas)


@dataclasses.dataclass(frozen=True)
class RealizationBatch:
    """Dense device-ready view of N sampled realizations.

    ``starts`` ([P] float64, first entry 0.0) is the shared boundary
    grid — the union of every realization's phase starts and churn
    times (plus any caller-supplied extra boundaries). Stochastic
    processes evolve on the fixed t = k·step grid, so realizations
    share their boundaries and the union stays O(num_steps), which is
    what lets ``vmap`` batch rollouts under one static shape.

    ``capacity[r, p]`` is realization r's effective capacity vector on
    the grid interval starting at ``starts[p]``, indexed on the
    compiling ``BranchIncidence``'s edges. Each row is produced by the
    same ``_phase_capacity_array`` the numpy event loop evaluates, so
    the per-realization capacities are bitwise-equal to what
    ``simulate(scenario=sample(key))`` would see — the engines diverge
    only in fp drain grouping, never in inputs.

    ``churn[r]`` carries realization r's churn events (the one
    per-rollout quantity besides capacities), and ``realizations``
    keeps the underlying ``Scenario`` objects for the numpy parity
    oracle.
    """

    starts: np.ndarray  # [P] float64 boundary grid, starts at 0.0
    capacity: np.ndarray  # [R, P, E] float64 effective capacities
    churn: tuple[tuple[ChurnEvent, ...], ...]  # per rollout
    realizations: tuple[Scenario, ...]

    @property
    def num_rollouts(self) -> int:
        return self.capacity.shape[0]


def densify_realizations(
    realizations, incidence, extra_boundaries=()
) -> RealizationBatch:
    """Lower sampled ``Scenario`` realizations onto one dense
    ``[rollouts, phases, edges]`` capacity tensor (see
    ``RealizationBatch``). Rejects realizations carrying cross-traffic
    or straggler events — those need the host event loop
    (``engine="batched"``); capacity phases and churn are the paths the
    device engine lowers."""
    realizations = tuple(realizations)
    if not realizations:
        raise ValueError("densify_realizations needs >= 1 realization")
    ts = [0.0]
    ts.extend(float(t) for t in extra_boundaries)
    for sc in realizations:
        if sc.cross_traffic or sc.stragglers:
            raise ValueError(
                "densify_realizations lowers capacity phases and churn "
                "only; cross-traffic and straggler events need the host "
                "event loop — price this scenario with engine='batched'"
            )
        ts.extend(float(ph.start) for ph in sc.capacity_phases)
        ts.extend(float(c.time) for c in sc.churn)
    ts = [t for t in ts if t >= 0.0 and math.isfinite(t)]
    starts = np.unique(np.asarray(ts, dtype=np.float64))
    num_p = starts.size
    num_e = incidence.num_edges
    caps = np.empty((len(realizations), num_p, num_e), dtype=np.float64)
    grid = starts.tolist()
    for r, sc in enumerate(realizations):
        phases = tuple(
            sorted(sc.capacity_phases, key=lambda ph: ph.start)
        )
        phase_caps = [_phase_capacity_array(incidence, ph) for ph in phases]
        cur = -1
        nxt = 0
        for p, t in enumerate(grid):
            # Latest phase with start <= t — the numpy loop's rule.
            while nxt < len(phases) and phases[nxt].start <= t:
                cur = nxt
                nxt += 1
            caps[r, p] = (
                phase_caps[cur] if cur >= 0 else incidence.base_capacity
            )
    return RealizationBatch(
        starts=starts,
        capacity=caps,
        churn=tuple(sc.churn for sc in realizations),
        realizations=realizations,
    )
