"""Communication demands triggered by a set of activated overlay links.

Paper eq. (4): instead of 2·|E_a| unicast flows, all flows originating at
the same agent i are combined into one *multicast* flow disseminating
agent i's parameters to its activated neighborhood N_{E_a}(i).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class MulticastDemand:
    """h = (s_h, T_h, κ_h): source agent, destination agents, bytes."""

    source: int
    destinations: frozenset
    size: float

    def __post_init__(self):
        if self.source in self.destinations:
            raise ValueError("source cannot be its own destination")
        if not self.destinations:
            raise ValueError("empty destination set")
        if self.size <= 0:
            raise ValueError("non-positive demand size")


def demands_from_links(
    activated_links: Iterable[tuple[int, int]],
    kappa: float,
    num_agents: int | None = None,
) -> list[MulticastDemand]:
    """Build H (eq. 4) from activated undirected overlay links E_a.

    Every agent with a nonempty activated neighborhood multicasts its
    κ-byte parameter vector to that neighborhood.
    """
    neigh: dict[int, set] = {}
    for i, j in activated_links:
        if i == j:
            raise ValueError("self-link in E_a")
        neigh.setdefault(i, set()).add(j)
        neigh.setdefault(j, set()).add(i)
    if num_agents is not None:
        bad = [a for a in neigh if a >= num_agents or a < 0]
        if bad:
            raise ValueError(f"agent index out of range: {bad}")
    return [
        MulticastDemand(source=i, destinations=frozenset(ns), size=kappa)
        for i, ns in sorted(neigh.items())
        if ns
    ]


def activated_links_from_matrix(w, atol: float = 1e-12) -> list[tuple[int, int]]:
    """E_a(W) = undirected links with nonzero off-diagonal weight."""
    import numpy as np

    w = np.asarray(w)
    m = w.shape[0]
    return [
        (i, j)
        for i in range(m)
        for j in range(i + 1, m)
        if abs(w[i, j]) > atol
    ]
