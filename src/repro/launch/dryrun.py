import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The first two lines above MUST run before any other import (jax locks the
device count on first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

Per cell it prints/records compiled.memory_analysis() (fits-in-HBM proof),
compiled.cost_analysis() (FLOPs/bytes for §Roofline), and the collective
byte breakdown parsed from the HLO.
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import (
    ALL_SHAPES,
    ARCH_IDS,
    cell_is_supported,
    get_config,
    get_shape,
    get_train_config,
)
from repro import compat
from repro.launch.fabric import design_mixing_matrix
from repro.launch.mesh import make_production_mesh, num_agents
from repro.launch.serve import build_serve_artifacts
from repro.launch.train import build_train_artifacts
from repro.models import model as M
from repro.roofline import analysis as roofline


def _memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        val = getattr(ma, key, None)
        if val is not None:
            out[key] = int(val)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    gossip: str = "auto",
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    tcfg = get_train_config(arch)
    if gossip != "auto":
        import dataclasses as _dc

        tcfg = _dc.replace(tcfg, gossip=gossip)
    shape = get_shape(shape_name)
    ok, reason = cell_is_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": reason,
        }

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
    }
    try:
        with compat.set_mesh(mesh):
            if shape.kind == "train":
                m = num_agents(mesh, tcfg.agent_layout)
                kappa = None
                w = None
                if m > 1:
                    # κ = per-agent parameter bytes shipped per gossip
                    # exchange (bf16 params / TP shards).
                    kappa = (
                        M.parameter_count(cfg) * 2 / mesh.shape["model"]
                    )
                    w, _ = design_mixing_matrix(
                        m, pods=(2 if multi_pod else 1), kappa_bytes=kappa
                    )
                art = build_train_artifacts(cfg, tcfg, shape, mesh, w)
                lowered = art.lower()
                record["num_agents"] = m
                record["gossip_mode"] = tcfg.gossip
                num_ag = m
            else:  # decode or prefill
                art = build_serve_artifacts(cfg, shape, mesh)
                lowered = art.lower()
                num_ag = 1

            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            gossip_edges = 0
            if shape.kind == "train" and num_ag > 1:
                w_off = w - np.diag(np.diag(w))
                gossip_edges = int(np.count_nonzero(np.abs(w_off) > 1e-12))
            rep = roofline.report(
                arch=arch,
                shape=shape,
                cfg=cfg,
                mesh_name=mesh_name,
                chips=chips,
                cost=cost,
                hlo_text=hlo,
                num_agents=num_ag,
                remat=True,
                tcfg=tcfg if shape.kind == "train" else None,
                mesh_shape={a: mesh.shape[a] for a in mesh.axis_names},
                gossip_directed_edges=gossip_edges,
            )
            record.update(
                status="ok",
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory=_memory_summary(compiled),
                cost_flops=float(cost.get("flops", 0.0) or 0.0),
                cost_bytes=float(cost.get("bytes accessed", 0.0) or 0.0),
                roofline=rep.to_dict(),
            )
            if verbose:
                mem = record["memory"]
                print(
                    f"[ok] {arch} × {shape_name} × {mesh_name}: "
                    f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
                    f"dominant={rep.dominant} bound={rep.bound_s*1e3:.2f}ms "
                    f"roofline={rep.roofline_fraction:.2%} "
                    f"coll={rep.collective_bytes_per_chip/1e6:.0f}MB/chip "
                    f"temp={mem.get('temp_size_in_bytes', 0)/1e9:.1f}GB"
                )
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} × {shape_name} × {mesh_name}: {record['error']}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--gossip", default="auto",
                    choices=["auto", "sparse", "dense", "allreduce"])
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = (
        [s.name for s in ALL_SHAPES]
        if (args.all or not args.shape)
        else [args.shape]
    )
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                records.append(run_cell(arch, shape, mp, gossip=args.gossip))
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # de-dup on (arch, shape, mesh): new records win
        keys = {(r["arch"], r["shape"], r["mesh"]) for r in records}
        existing = [
            r for r in existing
            if (r["arch"], r["shape"], r["mesh"]) not in keys
        ]
        with open(args.out, "w") as f:
            json.dump(existing + records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"cells: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
