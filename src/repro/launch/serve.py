"""Distributed serving: prefill and decode steps (no agents — pure TP/DP).

``decode_32k`` / ``long_500k`` lower ``serve_step`` (one new token against
a seq_len-deep KV cache); ``prefill_32k`` lowers the prompt pass.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as shard_rules
from repro.models import model as M


@dataclasses.dataclass
class ServeArtifacts:
    step_fn: Callable            # decode: (params, caches, token) -> (logits, caches)
    prefill_fn: Callable | None  # (params, inputs) -> (logits, caches)
    param_shapes: Any
    cache_shapes: Any
    input_shapes: Any
    param_shardings: Any
    cache_shardings: Any
    input_shardings: Any

    def jit(self, donate: bool = True):
        """Steady-state decode jit: caches round-trip on their shardings
        (donated); logits sharding left to the partitioner."""
        if self.step_fn is None:
            return jax.jit(
                self.prefill_fn,
                in_shardings=(self.param_shardings, self.input_shardings),
                out_shardings=(None, self.cache_shardings),
            )
        return jax.jit(
            self.step_fn,
            in_shardings=(
                self.param_shardings,
                self.cache_shardings,
                self.input_shardings,
            ),
            out_shardings=(None, self.cache_shardings),
            donate_argnums=(1,) if donate else (),
        )

    def lower(self):
        if self.step_fn is None:
            return self.jit().lower(self.param_shapes, self.input_shapes)
        return self.jit(donate=False).lower(
            self.param_shapes, self.cache_shapes, self.input_shapes
        )


def build_serve_artifacts(
    cfg: ModelConfig, shape: ShapeConfig, mesh: jax.sharding.Mesh
) -> ServeArtifacts:
    b, s = shape.global_batch, shape.seq_len
    param_shapes = jax.eval_shape(lambda k: M.init(cfg, k), jax.random.key(0))
    param_specs = shard_rules.param_specs_serve(param_shapes, mesh, cfg)
    to_sh = lambda specs: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda sp: isinstance(sp, P),
    )

    from repro.models.sharding_hints import hints

    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    role_axes = {
        "batch": batch_axes if (b % bsz == 0 and b >= bsz) else (),
        "tp": ("model",),
        "seq": ("model",),
    }

    if shape.kind == "decode":
        cache_shapes = jax.eval_shape(
            lambda: M.init_caches(cfg, b, s)
        )
        cache_specs = shard_rules.cache_specs_serve(cache_shapes, mesh, cfg)
        token_shape = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        token_spec = shard_rules.token_specs_serve(token_shape, mesh)

        def step_fn(params, caches, token):
            with hints(role_axes):
                return M.decode_step(cfg, params, caches, token)

        return ServeArtifacts(
            step_fn=step_fn,
            prefill_fn=None,
            param_shapes=param_shapes,
            cache_shapes=cache_shapes,
            input_shapes=token_shape,
            param_shardings=to_sh(param_specs),
            cache_shardings=to_sh(cache_specs),
            input_shardings=NamedSharding(mesh, token_spec),
        )

    # prefill
    inputs_shapes: dict = {}
    if cfg.frontend == "vision_patches":
        text = s - cfg.num_patches
        inputs_shapes["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
        inputs_shapes["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
    else:
        inputs_shapes["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    baxis = (
        (batch_axes if len(batch_axes) > 1 else batch_axes[0])
        if role_axes["batch"]
        else None
    )
    input_specs = jax.tree.map(
        lambda x: P(baxis, *([None] * (len(x.shape) - 1))), inputs_shapes
    )

    def prefill_fn(params, inputs):
        with hints(role_axes):
            return M.prefill(cfg, params, inputs, max_len=s)

    cache_shapes = jax.eval_shape(
        lambda: M.init_caches(cfg, b, s)
    )
    cache_specs = shard_rules.cache_specs_serve(cache_shapes, mesh, cfg)
    return ServeArtifacts(
        step_fn=None,
        prefill_fn=prefill_fn,
        param_shapes=param_shapes,
        cache_shapes=cache_shapes,
        input_shapes=inputs_shapes,
        param_shardings=to_sh(param_specs),
        cache_shardings=to_sh(cache_specs),
        input_shardings=to_sh(input_specs),
    )
