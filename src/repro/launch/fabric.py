"""The TPU fabric as the paper's underlay (hardware adaptation, DESIGN §4).

Agents on the "data" layout occupy rows of the (data, model) mesh; a
gossip exchange (i, j) moves each agent-row's parameter shards along the
data-axis ICI ring. The per-model-column paths are identical, so the
whole fabric reduces to ONE 16-node ring underlay whose links carry the
gossip traffic of all model columns in parallel. Multi-pod runs add a
second ring connected by per-node DCN links that are ~10× slower — the
bandwidth-limited regime where underlay-aware design matters most.

``design_mixing_matrix`` runs the paper's full pipeline (categories →
FMMD-WP → weight opt) against this fabric and returns the W used by the
distributed train step.
"""

from __future__ import annotations

import functools

import networkx as nx
import numpy as np

from repro.core.fmmd import fmmd_wp
from repro.net.categories import compute_categories
from repro.net.topology import Underlay, build_overlay

ICI_BW = 50e9   # bytes/s per direction per link
DCN_BW = 5e9    # bytes/s pod-to-pod per host pair


def ring_fabric_underlay(
    agents_per_pod: int, pods: int = 1,
    ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW,
) -> Underlay:
    """Ring(s) of agent nodes; cross-pod peers joined by DCN links."""
    g = nx.Graph()
    for p in range(pods):
        base = p * agents_per_pod
        for i in range(agents_per_pod):
            g.add_edge(
                base + i,
                base + (i + 1) % agents_per_pod,
                capacity=ici_bw,
            )
    for i in range(agents_per_pod):
        for p in range(pods - 1):
            g.add_edge(
                p * agents_per_pod + i,
                (p + 1) * agents_per_pod + i,
                capacity=dcn_bw,
            )
    if pods == 1 and agents_per_pod == 2:
        # path_graph degenerate double-edge guard: ring of 2 = single link
        g = nx.Graph()
        g.add_edge(0, 1, capacity=ici_bw)
    return Underlay(graph=g)


@functools.lru_cache(maxsize=16)
def design_mixing_matrix(
    num_agents: int,
    pods: int = 1,
    kappa_bytes: float = 1e9,
    iterations: int | None = None,
) -> tuple:
    """FMMD-WP on the fabric underlay. Returns (W, design) — cached.

    κ is the per-agent gossip payload (the parameter-shard bytes actually
    shipped per exchange).
    """
    per_pod = num_agents // pods
    if num_agents == 1:
        return (np.ones((1, 1)), None)
    underlay = ring_fabric_underlay(per_pod, pods)
    overlay = build_overlay(underlay, list(range(num_agents)))
    cats = compute_categories(overlay)
    t = iterations or max(2 * num_agents, 4)
    design = fmmd_wp(num_agents, t, cats, kappa_bytes)
    return (design.matrix, design)
