"""Mesh construction for the production deployment and tests.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — required because
the dry-run forces 512 host devices while tests/benches run on 1.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(
    shape=(2, 2), axes=("data", "model")
) -> jax.sharding.Mesh:
    """Small mesh for CPU tests (requires forced host device count)."""
    return compat.make_mesh(shape, axes)


def agent_axes(mesh: jax.sharding.Mesh, layout: str) -> tuple[str, ...]:
    """Mesh axes whose product forms the D-PSGD agent space."""
    has_pod = "pod" in mesh.axis_names
    if layout in ("data", "data_dp"):
        return ("pod", "data") if has_pod else ("data",)
    if layout == "pod":
        return ("pod",) if has_pod else ()
    raise ValueError(f"unknown agent layout {layout!r}")


def num_agents(mesh: jax.sharding.Mesh, layout: str) -> int:
    n = 1
    for a in agent_axes(mesh, layout):
        n *= mesh.shape[a]
    return max(n, 1)
