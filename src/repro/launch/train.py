"""Distributed D-PSGD training step (pjit + shard_map gossip).

One step per agent (paper eq. (2), compute ∥ exchange form):

  1. per-agent gradients over the stacked agent axis (vmap), with
     gradient accumulation over ``microbatch`` chunks,
  2. local SGD-momentum update,
  3. gossip mixing of the parameters — sparse ppermute schedule,
     dense einsum, or all-reduce (W = J), per the designed mixing matrix.

State pytree: {"params": [A, ...], "opt": {"momentum": [A, ...]},
"step": i32[]} — stacked leading agent axis A on every leaf.

``build_train_artifacts`` returns everything the dry-run and the real
launcher need: the step function, NamedShardings for state and batch, and
abstract input shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import gossip as gossip_lib
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as shard_rules
from repro.models import model as M
from repro.optim import sgd


@dataclasses.dataclass
class TrainArtifacts:
    step_fn: Callable                    # (state, batch) -> (state, metrics)
    state_shapes: Any                    # ShapeDtypeStructs (stacked agents)
    batch_shapes: Any
    state_shardings: Any                 # NamedShardings
    batch_shardings: Any
    num_agents: int
    mixing_matrix: np.ndarray | None
    init_state: Callable[[jax.Array], Any]  # key -> concrete state

    def jit(self, donate: bool = True):
        """Steady-state jit: outputs land on the input shardings so the
        train loop round-trips without resharding; state is donated."""
        return jax.jit(
            self.step_fn,
            in_shardings=(self.state_shardings, self.batch_shardings),
            out_shardings=(self.state_shardings, None),
            donate_argnums=(0,) if donate else (),
        )

    def lower(self):
        return self.jit(donate=False).lower(
            self.state_shapes, self.batch_shapes
        )


def _batch_shapes(
    cfg: ModelConfig, shape: ShapeConfig, num_agents: int, microbatch: int
) -> dict:
    per_agent = shape.global_batch // max(num_agents, 1)
    k = max(microbatch, 1)
    if per_agent % k != 0:
        k = 1
    mb = per_agent // k
    s = shape.seq_len
    shapes = {}
    if cfg.frontend == "vision_patches":
        text = s - cfg.num_patches
        shapes["tokens"] = jax.ShapeDtypeStruct(
            (num_agents, k, mb, text + 1), jnp.int32
        )
        shapes["patch_embeds"] = jax.ShapeDtypeStruct(
            (num_agents, k, mb, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
    else:
        shapes["tokens"] = jax.ShapeDtypeStruct(
            (num_agents, k, mb, s + 1), jnp.int32
        )
    return shapes


def _stacked_state_shapes(cfg: ModelConfig, num_agents: int) -> Any:
    params = jax.eval_shape(lambda k: M.init(cfg, k), jax.random.key(0))
    opt = jax.eval_shape(lambda p: sgd.init(p), params)

    def stack(x):
        return jax.ShapeDtypeStruct((num_agents,) + x.shape, x.dtype)

    return {
        "params": jax.tree.map(stack, params),
        "opt": jax.tree.map(stack, opt),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def build_train_artifacts(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    shape: ShapeConfig,
    mesh: jax.sharding.Mesh,
    mixing_matrix: np.ndarray | None = None,
    learning_rate: Callable | None = None,
) -> TrainArtifacts:
    """Assemble the distributed train step for one (arch × shape) cell.

    ``mixing_matrix`` must be m×m for m = number of agents implied by the
    layout and mesh; None ⇒ identity (no gossip; m=1 cells).
    """
    agent_axes = mesh_lib.agent_axes(mesh, tcfg.agent_layout)
    m = mesh_lib.num_agents(mesh, tcfg.agent_layout)
    if mixing_matrix is not None and mixing_matrix.shape[0] != m:
        raise ValueError(
            f"mixing matrix is {mixing_matrix.shape[0]}x…, layout implies m={m}"
        )

    state_shapes = _stacked_state_shapes(cfg, m)
    batch_shapes = _batch_shapes(cfg, shape, m, tcfg.microbatch)

    param_specs = shard_rules.param_specs_train(
        state_shapes["params"], mesh, tcfg.agent_layout
    )
    state_specs = {
        "params": param_specs,
        "opt": {"momentum": param_specs},
        "step": P(),
    }
    batch_specs = jax.tree.map(
        lambda spec: P(spec[0], None, *spec[1:]),  # insert microbatch dim
        shard_rules.batch_specs_train(
            jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(
                    (x.shape[0],) + x.shape[2:], x.dtype
                ),
                batch_shapes,
            ),
            mesh,
            tcfg.agent_layout,
        ),
        is_leaf=lambda s: isinstance(s, P),
    )

    lr_fn = learning_rate or (lambda step: jnp.asarray(tcfg.learning_rate))

    # Gossip mode resolution.
    mode = tcfg.gossip
    schedule = None
    w_arr = None
    if mixing_matrix is None or m <= 1:
        mode = "none"
    else:
        w_arr = np.asarray(mixing_matrix, np.float64)
        is_j = np.allclose(w_arr, np.full((m, m), 1.0 / m), atol=1e-9)
        if mode == "auto":
            nnz = np.count_nonzero(
                np.abs(w_arr - np.diag(np.diag(w_arr))) > 1e-12
            )  # directed activated edges
            # ppermute schedule ships nnz·κ bytes total vs the clique
            # all-gather's m(m−1)·κ — sparse wins for any non-clique.
            mode = (
                "allreduce" if is_j else
                ("sparse" if nnz < m * (m - 1) else "dense")
            )
        if mode == "sparse":
            schedule = gossip_lib.build_schedule(w_arr)

    def loss_for_agent(params, batch_mb):
        total, metrics = M.loss(
            cfg,
            params,
            batch_mb,
            moe_aux_weight=tcfg.moe_aux_weight,
            router_z_weight=tcfg.router_z_weight,
            remat=(tcfg.remat != "none"),
        )
        return total, metrics

    def grads_for_agent(params, batch_agent):
        """Gradient accumulation over the leading microbatch dim."""
        k = jax.tree.leaves(batch_agent)[0].shape[0]

        def one(mb):
            (l, metr), g = jax.value_and_grad(loss_for_agent, has_aux=True)(
                params, mb
            )
            return l, metr, g

        def acc_step(carry, mb):
            l0, g0 = carry
            l, metr, g = one(mb)
            return (
                l0 + l / k,
                jax.tree.map(lambda a, b: a + b.astype(a.dtype) / k, g0, g),
            ), metr

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(
            acc_step, (jnp.zeros((), jnp.float32), zeros), batch_agent
        )
        if tcfg.agent_layout == "data_dp":
            # Accumulate fp32 locally, reduce in bf16: halves the
            # cross-"model" gradient all-reduce (§Perf iteration 2).
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16), grads
            )
        return loss, grads

    from repro.models.sharding_hints import hints

    # Per-agent activations: the batch role maps to the intra-agent FSDP
    # axis ("pod" layout), the repurposed "model" axis ("data_dp"
    # layout), or nothing ("data" — each agent's microbatch lives wholly
    # on its own data rank).
    role_axes = {
        "batch": {
            "pod": ("data",),
            "data_dp": ("model",),
            "data": (),
        }[tcfg.agent_layout],
        "tp": ("model",) if tcfg.agent_layout != "data_dp" else (),
        # sequence-parallel boundaries (no-op for data_dp: "model" is DP)
        "seq": ("model",) if tcfg.agent_layout != "data_dp" else (),
    }

    def step_fn(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]
        with hints(role_axes):
            loss, grads = jax.vmap(grads_for_agent)(params, batch)
        lr = lr_fn(step)
        new_params, new_opt = sgd.update(
            grads, opt, params, lr, momentum=tcfg.momentum
        )
        # Gossip mixing (paper eq. (2)): mix the post-update parameters.
        if mode == "allreduce":
            new_params = gossip_lib.mix_allreduce(new_params)
        elif mode == "dense":
            new_params = gossip_lib.mix_dense(new_params, jnp.asarray(w_arr))
        elif mode == "sparse":
            if tcfg.agent_layout == "data_dp":
                # Params are replicated over "model": gossip the raveled
                # tree sliced over that axis (no redundant traffic).
                new_params = gossip_lib.mix_sparse_flat(
                    new_params, schedule, mesh, agent_axes, ("model",)
                )
            else:
                new_params = gossip_lib.mix_sparse_shardmap(
                    new_params, schedule, mesh, agent_axes, param_specs
                )
        new_state = {"params": new_params, "opt": new_opt, "step": step + 1}
        metrics = {"loss": jnp.mean(loss), "lr": lr}
        return new_state, metrics

    to_sharding = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )

    state_shardings = to_sharding(state_specs)

    def init_state(key) -> Any:
        def init_one(k):
            p = M.init(cfg, k)
            return {"params": p, "opt": sgd.init(p)}

        keys = jax.random.split(key, m)
        # Identical init across agents (standard D-PSGD start): fold key 0.
        stacked = jax.vmap(init_one)(jnp.broadcast_to(keys[0], keys.shape))
        state = {
            "params": stacked["params"],
            "opt": stacked["opt"],
            "step": jnp.zeros((), jnp.int32),
        }
        return jax.device_put(state, state_shardings)

    return TrainArtifacts(
        step_fn=step_fn,
        state_shapes=state_shapes,
        batch_shapes=batch_shapes,
        state_shardings=state_shardings,
        batch_shardings=to_sharding(batch_specs),
        num_agents=m,
        mixing_matrix=w_arr,
        init_state=init_state,
    )
