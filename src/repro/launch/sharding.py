"""Sharding rules: PartitionSpecs for params, batches, and caches.

Roles (resolved to mesh axes per layout):
  agent — stacked D-PSGD agent dim (dim 0 of every train leaf)
  fsdp  — intra-agent parameter/optimizer sharding ("pod" layout only)
  tp    — tensor parallelism over the "model" axis
  ep    — expert parallelism (MoE expert dim)

Train layouts (TrainConfig.agent_layout):
  "data": agents on ("pod"×)"data"; each agent's params live on its data
          rank, TP over "model". Small/mid archs (≤ ~50B).
  "pod" : one agent per pod; FSDP over "data" + TP over "model" inside
          the agent. Big archs (mixtral-8x22b, mistral-large, jamba).

Serving has no agents: weights are TP-sharded over "model", and for big
archs additionally over "data" (2-D tensor parallelism); caches shard
batch over ("pod","data") and sequence over "model" (sequence dim is the
only one guaranteed large in every decode shape).

The rules are path-pattern driven and *divisibility-safe*: an axis is
only assigned if the dim divides evenly, else dropped (GSPMD padding is
never relied upon).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


# (pattern, per-dim roles from the END of the shape). Earlier entries win.
# Dims not covered (leading stacked dims G) get None; dim 0 agent handled
# separately. Roles per dim: tuple of candidate roles tried in order.
_PARAM_RULES: tuple[tuple[str, tuple[tuple[str, ...], ...]], ...] = (
    # xLSTM mixer projections: REPLICATED. TP-sharding them was measured
    # forcing ~300 MB activation all-reduces per layer per microbatch
    # (the 4 mLSTM heads cannot align with a 16-way model axis); the
    # model is ≤125M params, so replication is free (§Perf).
    (r"mixer/(up|down)/kernel$", ((), ())),
    # MoE stacked experts [*, E, D, F] / [*, E, F, D]
    (r"ffn/(gate|up)$", (("ep",), ("fsdp",), ("tp",))),
    (r"ffn/down$", (("ep",), ("tp",), ("fsdp",))),
    (r"router/kernel$", (("fsdp",), ())),
    # Attention / MLP projections
    (r"(wq|wk|wv)/kernel$", (("fsdp",), ("tp",))),
    (r"(wq|wk|wv)/bias$", (("tp",),)),
    (r"wo/kernel$", (("tp",), ("fsdp",))),
    (r"(gate|up)/kernel$", (("fsdp",), ("tp",))),
    (r"down/kernel$", (("tp",), ("fsdp",))),
    # Embeddings
    (r"(embed|unembed)/table$", (("tp",), ("fsdp",))),
    (r"patch_proj/kernel$", (("fsdp",), ("tp",))),
    # Mamba
    (r"in_proj/kernel$", (("fsdp",), ("tp",))),
    (r"out_proj/kernel$", (("tp",), ("fsdp",))),
    (r"mixer/conv$", ((), ("tp",))),
    (r"conv_bias$", (("tp",),)),
    (r"x_proj/kernel$", (("tp",), ())),
    (r"dt_proj/kernel$", ((), ("tp",))),
    (r"(dt_bias|d_skip)$", (("tp",),)),
    (r"a_log$", (("tp",), ())),
    # xLSTM: up/down projected; per-head block-diag weights replicated
    (r"mixer/up/kernel$", (("fsdp",), ("tp",))),
    (r"mixer/down/kernel$", (("tp",), ("fsdp",))),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _assign(shape, roles_from_end, role_axes, mesh) -> P:
    """Build a spec assigning roles to trailing dims, divisibility-safe.

    Each mesh axis is used at most once per leaf.
    """
    spec: list = [None] * len(shape)
    used: set[str] = set()
    n = len(roles_from_end)
    for i, roles in enumerate(roles_from_end):
        dim = len(shape) - n + i
        if dim < 0:
            continue
        for role in roles:
            axes = role_axes.get(role, ())
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[dim] % size == 0 and shape[dim] >= size:
                spec[dim] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
                break
    return P(*spec)


def _role_axes_train(mesh, layout: str) -> dict:
    has_pod = "pod" in mesh.axis_names
    if layout == "data":
        return {
            "agent": (("pod", "data") if has_pod else ("data",)),
            "fsdp": (),
            "tp": ("model",),
            "ep": (),
            "batch_inner": (),
        }
    if layout == "data_dp":
        # Small models: replicate weights over "model" and use it as
        # intra-agent data parallelism — kills the per-layer TP
        # all-reduces that dominate sub-1B-model training.
        return {
            "agent": (("pod", "data") if has_pod else ("data",)),
            "fsdp": (),
            "tp": (),
            "ep": (),
            "batch_inner": ("model",),
        }
    if layout == "pod":
        return {
            "agent": (("pod",) if has_pod else ()),
            "fsdp": ("data",),
            "tp": ("model",),
            "ep": ("data",),  # EP and FSDP share the data axis (either/or)
            "batch_inner": ("data",),
        }
    raise ValueError(layout)


def param_specs_train(
    params_shape: Any, mesh, layout: str
) -> Any:
    """Specs for stacked-agent train params (leaf dim 0 = agent)."""
    role_axes = _role_axes_train(mesh, layout)
    agent = role_axes["agent"]

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        inner = shape[1:]  # strip agent dim
        rules = None
        for pat, roles in _PARAM_RULES:
            if re.search(pat, s):
                rules = roles
                break
        if rules is None:
            inner_spec = P(*([None] * len(inner)))
        else:
            inner_spec = _assign(inner, rules, role_axes, mesh)
        a0 = None
        if agent:
            size = int(np.prod([mesh.shape[a] for a in agent]))
            if shape[0] % size == 0:
                a0 = agent if len(agent) > 1 else agent[0]
        return P(a0, *inner_spec)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs_train(batch_shape: Any, mesh, layout: str) -> Any:
    """Batch leaves are [A, per_agent_B, ...]: agent dim + inner-batch
    sharding per layout (fsdp for "pod", "model" for "data_dp")."""
    role_axes = _role_axes_train(mesh, layout)
    agent, fsdp = role_axes["agent"], role_axes["batch_inner"]

    def spec_for(path, leaf):
        shape = leaf.shape
        a0 = None
        if agent:
            size = int(np.prod([mesh.shape[a] for a in agent]))
            if shape[0] % size == 0:
                a0 = agent if len(agent) > 1 else agent[0]
        b1 = None
        if fsdp and len(shape) > 1:
            size = int(np.prod([mesh.shape[a] for a in fsdp]))
            if shape[1] % size == 0:
                b1 = fsdp if len(fsdp) > 1 else fsdp[0]
        return P(a0, b1, *([None] * (len(shape) - 2)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def _role_axes_serve(mesh, cfg: ModelConfig) -> dict:
    """2-D TP for big archs (weights > ~8 GB per model shard), else 1-D."""
    from repro.models import model as M

    bytes_total = M.parameter_count(cfg) * 2  # bf16
    two_d = bytes_total / mesh.shape["model"] > 8e9
    return {
        "agent": (),
        "fsdp": ("data",) if two_d else (),
        "tp": ("model",),
        "ep": ("data",) if two_d else (),
    }


def param_specs_serve(params_shape: Any, mesh, cfg: ModelConfig) -> Any:
    role_axes = _role_axes_serve(mesh, cfg)

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        for pat, roles in _PARAM_RULES:
            if re.search(pat, s):
                return _assign(shape, roles, role_axes, mesh)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def cache_specs_serve(cache_shape: Any, mesh, cfg: ModelConfig) -> Any:
    """Caches: batch over ("pod","data") when divisible, else sequence
    over ("data",...); sequence/state dims over "model"."""
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)

    def spec_for(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        if re.search(r"/(k|v)$", s) and len(shape) == 5:
            # [G, B, S, H_kv, Dh]
            g, b, seq, h, dh = shape
            bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
            spec = [None] * 5
            used_for_b = False
            if b % bsize == 0 and b >= bsize:
                spec[1] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
                used_for_b = True
            seq_axes: tuple[str, ...] = ("model",)
            if not used_for_b:
                # B too small: also spread sequence over the batch axes.
                seq_axes = (*batch_axes, "model")
            ssize = int(np.prod([mesh.shape[a] for a in seq_axes]))
            if seq % ssize == 0 and seq >= ssize:
                spec[2] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            elif seq % mesh.shape["model"] == 0:
                spec[2] = "model"
            return P(*spec)
        if re.search(r"/(conv|ssm)$", s) and len(shape) >= 3:
            # mamba states [G, B, c|di, di|ds] — shard the d_inner dim.
            spec = [None] * len(shape)
            di_dim = 2 if s.endswith("ssm") else len(shape) - 1
            if shape[di_dim] % mesh.shape["model"] == 0:
                spec[di_dim] = "model"
            bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
            if shape[1] % bsize == 0 and shape[1] >= bsize:
                spec[1] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
            return P(*spec)
        # pos scalars, xlstm states etc.: batch-shard if possible.
        spec = [None] * len(shape)
        if len(shape) >= 2:
            bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
            if shape[1] % bsize == 0 and shape[1] >= bsize:
                spec[1] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def token_specs_serve(token_shape, mesh) -> P:
    has_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if has_pod else ("data",)
    b = token_shape.shape[0]
    bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
    if b % bsize == 0 and b >= bsize:
        return P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    return P(None, None)
