"""Launch: mesh, sharding rules, distributed train/serve, dry-run."""
