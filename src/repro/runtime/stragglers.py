"""Straggler mitigation for decentralized training.

Lemma III.1 says equal bandwidth sharing is makespan-optimal when all
agents move the same κ — but a straggling *link or agent* breaks the
premise. Two mitigations, both of which keep D-PSGD's convergence
guarantees:

  * ``renormalized_mixing``: skip the straggler's exchange this round and
    renormalize W's rows over delivered neighbors (the effective W is
    still symmetric row-stochastic on the delivered support — a valid
    time-varying mixing matrix under [32]).
  * ``deadline_from_history``: per-round deadline = q-quantile of past
    round times × slack, the standard bounded-staleness trigger.

``StragglerSimulator`` models per-agent slowdowns on top of the fluid
network simulator to quantify the benefit in benchmarks.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def renormalized_mixing(
    w: np.ndarray, delivered: np.ndarray
) -> np.ndarray:
    """Zero out undelivered exchanges and restore row sums to 1.

    delivered: boolean [m, m]; delivered[i, j] ⇔ agent i received j's
    parameters this round (must be symmetric to keep W symmetric).
    """
    m = w.shape[0]
    delivered = np.asarray(delivered, bool)
    if not np.array_equal(delivered, delivered.T):
        raise ValueError("delivered matrix must be symmetric")
    w_eff = np.where(delivered, w, 0.0)
    np.fill_diagonal(w_eff, 0.0)
    # Push the missing mass back to the diagonal: W_ii = 1 − Σ_j W_ij.
    np.fill_diagonal(w_eff, 1.0 - w_eff.sum(axis=1))
    return w_eff


def deadline_from_history(
    history_s: list[float], quantile: float = 0.75, slack: float = 1.5,
    floor_s: float = 0.0,
) -> float:
    if not history_s:
        return float("inf")
    return max(float(np.quantile(history_s, quantile)) * slack, floor_s)


@dataclasses.dataclass
class StragglerSimulator:
    """Per-round agent slowdown model: normal rounds ~1×, straggle rounds
    ~``severity``× with probability ``prob`` per agent per round."""

    num_agents: int
    prob: float = 0.05
    severity: float = 4.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def round_slowdowns(self) -> np.ndarray:
        s = np.ones(self.num_agents)
        mask = self._rng.random(self.num_agents) < self.prob
        s[mask] = self.severity
        return s

    def scenario_events(
        self, horizon: float, round_time: float
    ) -> tuple:
        """Sample per-round slowdowns as fluid-simulator events.

        Each simulated gossip round [r·round_time, (r+1)·round_time)
        contributes one ``StragglerEvent`` per straggling agent, so the
        same stochastic model that drives ``round_time`` can degrade the
        network simulator (``repro.net.simulate(scenario=...)``).
        """
        from repro.net.simulator import StragglerEvent

        if round_time <= 0:
            raise ValueError("round_time must be positive")
        if not np.isfinite(horizon):
            raise ValueError("horizon must be finite")
        events = []
        r = 0
        while r * round_time < horizon:
            start = r * round_time
            stop = min(start + round_time, horizon)
            for agent in np.flatnonzero(self.round_slowdowns() > 1.0):
                events.append(
                    StragglerEvent(
                        agent=int(agent), slowdown=self.severity,
                        start=start, stop=stop,
                    )
                )
            r += 1
        return tuple(events)

    def round_time(
        self, base_time: float, w: np.ndarray, deadline: float | None = None
    ) -> tuple[float, np.ndarray]:
        """(elapsed, delivered) for one gossip round.

        An exchange (i, j) lands at base_time × max(slow_i, slow_j); with
        a deadline, late exchanges are dropped (delivered=False) and the
        round closes at the deadline.
        """
        slow = self.round_slowdowns()
        m = self.num_agents
        delivered = np.ones((m, m), bool)
        t_round = 0.0
        for i in range(m):
            for j in range(i + 1, m):
                if abs(w[i, j]) < 1e-12:
                    continue
                t = base_time * max(slow[i], slow[j])
                if deadline is not None and t > deadline:
                    delivered[i, j] = delivered[j, i] = False
                else:
                    t_round = max(t_round, t)
        if deadline is not None:
            t_round = min(max(t_round, base_time), deadline)
        return t_round, delivered
