"""Fault tolerance: agent failure → mixing-matrix re-design → resume.

In D-PSGD a failed agent is not a lost shard of THE model — every agent
holds a full replica — so recovery is a *membership + hyperparameter*
problem, which is exactly what the paper's machinery solves:

  1. detect the failure (missed heartbeats),
  2. drop the agent from the overlay, re-run FMMD-WP on the surviving
     overlay (categories restricted to surviving paths),
  3. re-map the stacked state (checkpoint.restore's elastic agent axis,
     or in-memory row drop), rebuild the gossip schedule, continue.

The same path handles *scale-up* (new agents join, cloned from a current
replica) — elastic scaling. ``FaultToleranceController`` simulates the
control loop; on a real deployment the heartbeat source is the cluster
manager and re-jit is triggered through the launcher.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.fmmd import fmmd_wp
from repro.core.gossip import GossipSchedule, build_schedule
from repro.net.categories import Categories, compute_categories
from repro.net.simulator import ChurnEvent, Scenario, StragglerEvent
from repro.net.topology import OverlayNetwork, build_overlay


@dataclasses.dataclass
class Membership:
    """Live agent set over an (optionally changing) overlay."""

    overlay: OverlayNetwork
    alive: tuple[int, ...]  # agent indices into the ORIGINAL overlay

    def surviving_overlay(self) -> OverlayNetwork:
        nodes = [self.overlay.agents[a] for a in self.alive]
        return build_overlay(self.overlay.underlay, nodes)


def redesign_after_failure(
    overlay: OverlayNetwork,
    alive: tuple[int, ...],
    kappa: float,
    iterations: int | None = None,
) -> tuple[np.ndarray, GossipSchedule, Categories]:
    """Re-run the paper's pipeline on the surviving agents."""
    m = len(alive)
    if m == 1:
        # A single survivor has no overlay links, hence no nonempty
        # categories — return the valid empty structure the signature
        # promises (``compute_categories`` on a 1-agent overlay yields
        # exactly this), not None.
        w = np.ones((1, 1))
        return w, build_schedule(w), Categories(
            members={}, capacity={}, edge_capacity={}
        )
    sub = build_overlay(
        overlay.underlay, [overlay.agents[a] for a in alive]
    )
    cats = compute_categories(sub)
    design = fmmd_wp(m, iterations or max(2 * m, 4), cats, kappa)
    return design.matrix, build_schedule(design.matrix), cats


def churn_events_from_failures(
    failures: Mapping[int, float]
) -> tuple[ChurnEvent, ...]:
    """Agent → failure-time map as fluid-simulator churn events."""
    return tuple(
        ChurnEvent(agent=a, time=t)
        for a, t in sorted(failures.items(), key=lambda kv: kv[1])
    )


def failure_scenario(
    failures: Mapping[int, float] | None = None,
    pre_failure_slowdown: float = 1.0,
    slowdown_window: float = 0.0,
) -> Scenario:
    """Scenario for pricing a round that loses agents mid-flight.

    Optionally models the common failure signature where an agent limps
    (``pre_failure_slowdown``× for ``slowdown_window`` seconds) before it
    drops out — the pattern ``HeartbeatMonitor`` reacts to.
    """
    failures = dict(failures or {})
    stragglers = []
    if pre_failure_slowdown > 1.0 and slowdown_window > 0.0:
        for agent, t in failures.items():
            stragglers.append(
                StragglerEvent(
                    agent=agent,
                    slowdown=pre_failure_slowdown,
                    start=max(0.0, t - slowdown_window),
                    stop=t,
                )
            )
    return Scenario(
        stragglers=tuple(stragglers),
        churn=churn_events_from_failures(failures),
    )


def shrink_state(
    state: Any, alive: tuple[int, ...], num_agents: int
) -> Any:
    """Drop failed agents' rows from a stacked-agent state pytree.

    ``num_agents`` is the CURRENT stacked-agent count: only leaves whose
    leading dimension equals it are sliced. (The previous
    ``x.shape[0] > max(alive)`` heuristic sliced *any* leaf with a large
    enough leading dim — corrupting non-agent leaves such as a
    replicated RNG key of shape [2] or global scalars lifted to 1-D.)
    """
    import jax

    idx = np.asarray(alive)
    if idx.size and (idx.min() < 0 or idx.max() >= num_agents):
        raise ValueError(
            f"alive indices {alive} out of range for num_agents="
            f"{num_agents}"
        )

    def take(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == num_agents:
            return x[idx]
        return x

    return jax.tree.map(take, state)


def grow_state(state: Any, new_m: int, clone_from: int = 0) -> Any:
    """Add agents cloned from an existing replica (elastic scale-up)."""
    import jax

    def grow(x):
        if not hasattr(x, "ndim") or x.ndim < 1:
            return x
        old_m = x.shape[0]
        if new_m <= old_m:
            return x[:new_m]
        reps = jax.numpy.repeat(
            x[clone_from : clone_from + 1], new_m - old_m, axis=0
        )
        return jax.numpy.concatenate([x, reps], axis=0)

    return jax.tree.map(grow, state)


class HeartbeatMonitor:
    """Failure detection by missed heartbeats (simulation-friendly)."""

    def __init__(self, agents: tuple[int, ...], timeout: float = 3.0,
                 now: Callable[[], float] = time.monotonic):
        self._timeout = timeout
        self._now = now
        self._last = {a: now() for a in agents}

    def beat(self, agent: int) -> None:
        self._last[agent] = self._now()

    def failed(self) -> tuple[int, ...]:
        t = self._now()
        return tuple(
            a for a, last in self._last.items() if t - last > self._timeout
        )

    def remove(self, agent: int) -> None:
        self._last.pop(agent, None)


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    failed: tuple[int, ...]
    survivors: tuple[int, ...]
    new_rho: float
    redesign_seconds: float
    # Transition-round pricing: the fluid-simulated makespan of the
    # in-flight round under a failure_scenario for the detected
    # failures, and how many unicast exchanges the departures cancelled.
    # NaN/0 when transition pricing is disabled. ``pricing_seconds``
    # times the pricing itself, kept separate so ``redesign_seconds``
    # stays a pure redesign-cost metric.
    transition_tau: float = float("nan")
    cancelled_exchanges: int = 0
    pricing_seconds: float = 0.0


class FaultToleranceController:
    """Orchestrates detect → price → redesign → shrink for a stacked
    trainer.

    Besides redesigning the mixing matrix for the survivors, the
    controller prices the *transition* round: the round in flight when
    the failure hits is simulated under ``failure_scenario`` (departures
    cancel the affected exchanges mid-round), and the resulting makespan
    and cancelled-exchange count land in the ``RecoveryEvent`` — the
    recovery cost, not just the recovery outcome. Disable with
    ``price_transitions=False`` (e.g. when the controller is driven at
    very high frequency and the extra routing+simulation per failure
    matters).
    """

    def __init__(
        self,
        overlay: OverlayNetwork,
        kappa: float,
        price_transitions: bool = True,
        transition_routing_rounds: int = 2,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.overlay = overlay
        self.kappa = kappa
        self.alive = tuple(range(overlay.num_agents))
        self.events: list[RecoveryEvent] = []
        self.price_transitions = price_transitions
        self._routing_rounds = transition_routing_rounds
        # Injectable for deterministic tests / the virtual-clock service
        # loop; the default attribute reference is what the determinism
        # lint permits (no direct wall-clock *calls* in handlers).
        self._clock = clock
        self._cur_overlay = overlay
        self._cur_routing = None  # lazily routed per membership epoch

    def _round_routing(self):
        """Routing of the round in flight for the current membership."""
        from repro.net.demands import demands_from_links
        from repro.net.routing import route

        if self._cur_routing is None:
            m = self._cur_overlay.num_agents
            if m < 2:
                return None
            cats = compute_categories(self._cur_overlay)
            design = fmmd_wp(m, max(2 * m, 4), cats, self.kappa)
            demands = demands_from_links(
                design.activated_links, self.kappa, m
            )
            if demands:
                # Heuristic-only (milp_var_budget=0): the transition
                # price must stay cheap next to the redesign itself.
                self._cur_routing = route(
                    demands, cats, self.kappa, m, milp_var_budget=0,
                    heuristic_rounds=self._routing_rounds,
                )
        return self._cur_routing

    def _price_transition(
        self,
        failed: tuple[int, ...],
        failure_times: Mapping[int, float] | None,
    ) -> tuple[float, int]:
        from repro.net.simulator import simulate

        routing = self._round_routing()
        if routing is None or not routing.demands:
            return float("nan"), 0
        # Agents are re-indexed after each redesign: churn events must
        # address positions within the current membership.
        pos = {a: i for i, a in enumerate(self.alive)}
        tau0 = routing.completion_time
        failures = {
            pos[a]: max(float((failure_times or {}).get(a, 0.5 * tau0)),
                        1e-9)
            for a in failed if a in pos
        }
        if not failures:
            return float("nan"), 0
        sim = simulate(
            routing, self._cur_overlay,
            scenario=failure_scenario(failures),
        )
        return float(sim.makespan), int(sim.cancelled_branches)

    def handle_failures(
        self,
        failed: tuple[int, ...],
        state: Any,
        step: int,
        failure_times: Mapping[int, float] | None = None,
    ) -> tuple[Any, np.ndarray, GossipSchedule]:
        """Price the interrupted round, redesign, and shrink the state.

        ``failure_times`` (original agent index → seconds into the
        in-flight round) refines the transition pricing; failures
        default to the middle of the round.
        """
        from repro.core import mixing as mixing_lib

        survivors = tuple(a for a in self.alive if a not in failed)
        if not survivors:
            raise RuntimeError("all agents failed")
        t_price = self._clock()
        transition_tau, cancelled = (
            self._price_transition(tuple(failed), failure_times)
            if self.price_transitions else (float("nan"), 0)
        )
        t0 = self._clock()  # redesign timing excludes the pricing
        pricing_seconds = t0 - t_price
        # state rows are indexed by position within current alive set
        keep_pos = tuple(
            i for i, a in enumerate(self.alive) if a not in failed
        )
        new_state = shrink_state(state, keep_pos, len(self.alive))
        w, schedule, _ = redesign_after_failure(
            self.overlay, survivors, self.kappa
        )
        self.alive = survivors
        self._cur_overlay = build_overlay(
            self.overlay.underlay,
            [self.overlay.agents[a] for a in survivors],
        )
        self._cur_routing = None  # next failure re-routes the new epoch
        self.events.append(
            RecoveryEvent(
                step=step,
                failed=tuple(failed),
                survivors=survivors,
                new_rho=mixing_lib.rho(w) if w.shape[0] > 1 else 0.0,
                redesign_seconds=self._clock() - t0,
                transition_tau=transition_tau,
                cancelled_exchanges=cancelled,
                pricing_seconds=pricing_seconds,
            )
        )
        return new_state, w, schedule
