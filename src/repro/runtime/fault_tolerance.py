"""Fault tolerance: agent failure → mixing-matrix re-design → resume.

In D-PSGD a failed agent is not a lost shard of THE model — every agent
holds a full replica — so recovery is a *membership + hyperparameter*
problem, which is exactly what the paper's machinery solves:

  1. detect the failure (missed heartbeats),
  2. drop the agent from the overlay, re-run FMMD-WP on the surviving
     overlay (categories restricted to surviving paths),
  3. re-map the stacked state (checkpoint.restore's elastic agent axis,
     or in-memory row drop), rebuild the gossip schedule, continue.

The same path handles *scale-up* (new agents join, cloned from a current
replica) — elastic scaling. ``FaultToleranceController`` simulates the
control loop; on a real deployment the heartbeat source is the cluster
manager and re-jit is triggered through the launcher.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.fmmd import fmmd_wp
from repro.core.gossip import GossipSchedule, build_schedule
from repro.net.categories import Categories, compute_categories
from repro.net.simulator import ChurnEvent, Scenario, StragglerEvent
from repro.net.topology import OverlayNetwork, build_overlay


@dataclasses.dataclass
class Membership:
    """Live agent set over an (optionally changing) overlay."""

    overlay: OverlayNetwork
    alive: tuple[int, ...]  # agent indices into the ORIGINAL overlay

    def surviving_overlay(self) -> OverlayNetwork:
        nodes = [self.overlay.agents[a] for a in self.alive]
        return build_overlay(self.overlay.underlay, nodes)


def redesign_after_failure(
    overlay: OverlayNetwork,
    alive: tuple[int, ...],
    kappa: float,
    iterations: int | None = None,
) -> tuple[np.ndarray, GossipSchedule, Categories]:
    """Re-run the paper's pipeline on the surviving agents."""
    m = len(alive)
    if m == 1:
        w = np.ones((1, 1))
        return w, build_schedule(w), None
    sub = build_overlay(
        overlay.underlay, [overlay.agents[a] for a in alive]
    )
    cats = compute_categories(sub)
    design = fmmd_wp(m, iterations or max(2 * m, 4), cats, kappa)
    return design.matrix, build_schedule(design.matrix), cats


def churn_events_from_failures(
    failures: Mapping[int, float]
) -> tuple[ChurnEvent, ...]:
    """Agent → failure-time map as fluid-simulator churn events."""
    return tuple(
        ChurnEvent(agent=a, time=t)
        for a, t in sorted(failures.items(), key=lambda kv: kv[1])
    )


def failure_scenario(
    failures: Mapping[int, float] | None = None,
    pre_failure_slowdown: float = 1.0,
    slowdown_window: float = 0.0,
) -> Scenario:
    """Scenario for pricing a round that loses agents mid-flight.

    Optionally models the common failure signature where an agent limps
    (``pre_failure_slowdown``× for ``slowdown_window`` seconds) before it
    drops out — the pattern ``HeartbeatMonitor`` reacts to.
    """
    failures = dict(failures or {})
    stragglers = []
    if pre_failure_slowdown > 1.0 and slowdown_window > 0.0:
        for agent, t in failures.items():
            stragglers.append(
                StragglerEvent(
                    agent=agent,
                    slowdown=pre_failure_slowdown,
                    start=max(0.0, t - slowdown_window),
                    stop=t,
                )
            )
    return Scenario(
        stragglers=tuple(stragglers),
        churn=churn_events_from_failures(failures),
    )


def shrink_state(state: Any, alive: tuple[int, ...]) -> Any:
    """Drop failed agents' rows from a stacked-agent state pytree."""
    import jax

    idx = np.asarray(alive)

    def take(x):
        return x[idx] if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] > max(idx) else x

    return jax.tree.map(take, state)


def grow_state(state: Any, new_m: int, clone_from: int = 0) -> Any:
    """Add agents cloned from an existing replica (elastic scale-up)."""
    import jax

    def grow(x):
        if not hasattr(x, "ndim") or x.ndim < 1:
            return x
        old_m = x.shape[0]
        if new_m <= old_m:
            return x[:new_m]
        reps = jax.numpy.repeat(
            x[clone_from : clone_from + 1], new_m - old_m, axis=0
        )
        return jax.numpy.concatenate([x, reps], axis=0)

    return jax.tree.map(grow, state)


class HeartbeatMonitor:
    """Failure detection by missed heartbeats (simulation-friendly)."""

    def __init__(self, agents: tuple[int, ...], timeout: float = 3.0,
                 now: Callable[[], float] = time.monotonic):
        self._timeout = timeout
        self._now = now
        self._last = {a: now() for a in agents}

    def beat(self, agent: int) -> None:
        self._last[agent] = self._now()

    def failed(self) -> tuple[int, ...]:
        t = self._now()
        return tuple(
            a for a, last in self._last.items() if t - last > self._timeout
        )

    def remove(self, agent: int) -> None:
        self._last.pop(agent, None)


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    failed: tuple[int, ...]
    survivors: tuple[int, ...]
    new_rho: float
    redesign_seconds: float


class FaultToleranceController:
    """Orchestrates detect → redesign → shrink for a stacked trainer."""

    def __init__(self, overlay: OverlayNetwork, kappa: float):
        self.overlay = overlay
        self.kappa = kappa
        self.alive = tuple(range(overlay.num_agents))
        self.events: list[RecoveryEvent] = []

    def handle_failures(
        self, failed: tuple[int, ...], state: Any, step: int
    ) -> tuple[Any, np.ndarray, GossipSchedule]:
        from repro.core import mixing as mixing_lib

        t0 = time.perf_counter()
        survivors = tuple(a for a in self.alive if a not in failed)
        if not survivors:
            raise RuntimeError("all agents failed")
        # state rows are indexed by position within current alive set
        keep_pos = tuple(
            i for i, a in enumerate(self.alive) if a not in failed
        )
        new_state = shrink_state(state, keep_pos)
        w, schedule, _ = redesign_after_failure(
            self.overlay, survivors, self.kappa
        )
        self.alive = survivors
        self.events.append(
            RecoveryEvent(
                step=step,
                failed=tuple(failed),
                survivors=survivors,
                new_rho=mixing_lib.rho(w) if w.shape[0] > 1 else 0.0,
                redesign_seconds=time.perf_counter() - t0,
            )
        )
        return new_state, w, schedule
