"""Gradient/parameter compression — composable with mixing design (§I).

The paper notes compression, hyperparameter optimization, and adaptive
communication "are compatible with each other and thus can be combined";
κ in the communication optimizer is then the *compressed* size
(footnote 5: use the max compressed size for a guaranteed τ). Provided
operators, all pytree-level:

  * top-k sparsification (with error feedback accumulator),
  * random-k sparsification (rescaled, unbiased),
  * int8 linear quantization (per-tensor scale).

Each returns (compressed_payload, decode_fn, bytes) so the trainer can
feed real κ values back into the routing/mixing design.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Compressed:
    payload: Any
    nbytes: int
    decode: Callable[[], Any]


def _leaf_bytes(x) -> int:
    return x.size * x.dtype.itemsize


def topk_compress(tree: Any, fraction: float = 0.01) -> Compressed:
    """Keep the largest-|value| fraction per leaf: (indices, values)."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = []
    nbytes = 0
    for leaf in leaves:
        flat = leaf.reshape(-1)
        k = max(1, int(flat.size * fraction))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        payload.append((idx.astype(jnp.int32), vals, leaf.shape, leaf.dtype))
        nbytes += k * (4 + leaf.dtype.itemsize)

    def decode():
        out = []
        for idx, vals, shape, dtype in payload:
            flat = jnp.zeros(
                int(jnp.prod(jnp.asarray(shape))), dtype
            ).at[idx].set(vals)
            out.append(flat.reshape(shape))
        return jax.tree.unflatten(treedef, out)

    return Compressed(payload, nbytes, decode)


def randk_compress(tree: Any, fraction: float = 0.01, seed: int = 0) -> Compressed:
    """Unbiased random-k: keep random coordinates, scale by 1/fraction."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = []
    nbytes = 0
    for i, leaf in enumerate(leaves):
        flat = leaf.reshape(-1)
        k = max(1, int(flat.size * fraction))
        idx = jax.random.choice(
            jax.random.key((seed, i)[1] * 7919 + seed),
            flat.size, (k,), replace=False,
        )
        vals = flat[idx] / fraction
        payload.append((idx.astype(jnp.int32), vals, leaf.shape, leaf.dtype))
        nbytes += k * (4 + leaf.dtype.itemsize)

    def decode():
        out = []
        for idx, vals, shape, dtype in payload:
            flat = jnp.zeros(
                int(jnp.prod(jnp.asarray(shape))), dtype
            ).at[idx].set(vals.astype(dtype))
            out.append(flat.reshape(shape))
        return jax.tree.unflatten(treedef, out)

    return Compressed(payload, nbytes, decode)


def int8_compress(tree: Any) -> Compressed:
    """Per-tensor symmetric int8 quantization."""
    leaves, treedef = jax.tree.flatten(tree)
    payload = []
    nbytes = 0
    for leaf in leaves:
        scale = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-12) / 127.0
        q = jnp.clip(
            jnp.round(leaf.astype(jnp.float32) / scale), -127, 127
        ).astype(jnp.int8)
        payload.append((q, scale, leaf.dtype))
        nbytes += leaf.size + 4

    def decode():
        return jax.tree.unflatten(
            treedef,
            [
                (q.astype(jnp.float32) * scale).astype(dtype)
                for q, scale, dtype in payload
            ],
        )

    return Compressed(payload, nbytes, decode)


@dataclasses.dataclass
class ErrorFeedback:
    """EF memory for biased compressors (top-k): compress(g + e)."""

    residual: Any = None

    def step(
        self, grads: Any, compressor: Callable[[Any], Compressed]
    ) -> Compressed:
        if self.residual is None:
            self.residual = jax.tree.map(jnp.zeros_like, grads)
        corrected = jax.tree.map(lambda g, e: g + e, grads, self.residual)
        comp = compressor(corrected)
        decoded = comp.decode()
        self.residual = jax.tree.map(
            lambda c, d: c - d, corrected, decoded
        )
        return comp


def compressed_kappa(example_tree: Any, method: str, **kw) -> int:
    """Worst-case compressed payload bytes — the κ fed to the designer
    (paper footnote 5)."""
    if method == "topk":
        frac = kw.get("fraction", 0.01)
        return sum(
            max(1, int(l.size * frac)) * (4 + l.dtype.itemsize)
            for l in jax.tree.leaves(example_tree)
        )
    if method == "int8":
        return sum(l.size + 4 for l in jax.tree.leaves(example_tree))
    if method == "none":
        return sum(_leaf_bytes(l) for l in jax.tree.leaves(example_tree))
    raise ValueError(method)
