"""Design-as-a-service: a long-lived incremental-redesign loop.

A production deployment of the paper's pipeline does not re-run a
490-second design sweep per network event — it *amends* the incumbent
design. ``DesignService`` ingests a replayable stream of events
(``runtime/events.py``) and, per event, picks the cheapest sound
response:

  * **absorb**   — the change touches no category member edge (edges no
    overlay path traverses never constrain, Definition 1), so the
    incumbent design, τ, and every compiled structure are provably
    unchanged: O(changed edges) bookkeeping.
  * **patch**    — capacities of member edges moved but realized τ stays
    within ``drift_band`` of the value at adoption: re-derive only the
    touched C_F (``patch_categories_capacity``), patch the κ/C_F
    coefficients (``patch_category_incidence``) and the simulator's
    capacity vector (``BranchIncidence.with_capacities``) in place —
    every patched structure re-validates under ``REPRO_VALIDATE=1`` —
    and keep the incumbent.
  * **defer / adopt** — τ drifted past the band: price a redesign by
    warm-starting FMMD-P from the incumbent ``_PriorityState``
    (``reset`` rebinds it to the patched incidence, skipping the
    atom→category flattening) and price the *transition* (PR 3: the
    in-flight round simulated on the patched incidence). Adopt only
    when projected savings beat the transition cost; otherwise defer.
  * **redesign** — membership changed (leave/join): regroup categories
    from the cached shortest-path pairs (no routing recomputation;
    bitwise-identical to rebuilding the overlay from scratch) and run a
    mandatory redesign.

Robustness is first-class. Every pricing attempt runs under an optional
``FaultInjector`` (``runtime/faultinject.py``) with bounded
deterministic retry-with-backoff on a **virtual clock** (no wall-clock
reads, per the determinism lint), and failures degrade through explicit
tiers rather than crashing the loop:

  * ``incumbent-keep``   — redesign failed after retries: keep (or, on a
    departure, renormalize) the incumbent design; revert a failed join.
  * ``scratch-rebuild``  — an incremental patch tripped a
    ``ContractViolation``: distrust the cached structures and rebuild
    overlay + categories + design from scratch.
  * ``quarantine``       — a malformed event with an attributable origin
    quarantines that reporter; its later events are logged-and-dropped.

Every event produces exactly one ``ServiceRecord`` in the ``ServiceLog``
(zero dropped events), so tests assert the decision trail directly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.analysis.contracts import ContractViolation
from repro.core.fmmd import _PriorityState, fmmd
from repro.net.categories import (
    Categories,
    _group_category_pairs,
    category_entry_order,
    compile_category_incidence,
    compute_categories,
    edge_category_index,
    patch_categories_capacity,
    patch_category_incidence,
)
from repro.net.demands import demands_from_links
from repro.net.routing import RoutingSolution, route_direct
from repro.net.simulator import _ENGINES, compile_incidence, simulate
from repro.net.topology import OverlayNetwork, build_overlay
from repro.runtime.events import (
    AgentJoin,
    AgentLeave,
    LinkStateChange,
    event_sort_key,
    malformed_reason,
)
from repro.runtime.faultinject import FaultInjector, PricingFault
from repro.runtime.fault_tolerance import failure_scenario
from repro.runtime.stragglers import renormalized_mixing


class VirtualClock:
    """Deterministic service time: advanced by events and backoffs, never
    read from the wall (the determinism lint forbids wall-clock reads in
    runtime/)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError("virtual clock cannot run backwards")
        self._t += float(seconds)

    def advance_to(self, t: float) -> None:
        if t > self._t:
            self._t = float(t)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the incremental-redesign policy.

    ``design_iterations=None`` uses the pipeline default ``max(2m, 4)``
    (pass a small explicit budget at scale). ``drift_band`` is the
    relative τ corridor around the value at adoption inside which a
    capacity patch keeps the incumbent without re-pricing; drifting out
    in *either* direction (degradation or significant recovery)
    triggers pricing. Adoption requires projected savings
    ``horizon_rounds·(τ_now − τ_cand)`` to exceed the transition bill
    ``transition_rounds·τ_transition``. Retries back off
    ``backoff_base·backoff_factor^attempt`` virtual seconds.

    ``engine`` selects the fluid simulator used for amendment/transition
    pricing (any name ``repro.net.simulator.simulate`` accepts). Leave
    transitions always price on ``"batched"``: their mid-round departure
    is a straggler scenario, which the jax engine does not lower.

    Engine / scenario / stochastic matrix (service pricing calls)::

        engine=       scenario= (service-built)     stochastic=
        ------------  ----------------------------  -------------------------
        "batched"     full — amendment, drift, and  n/a — the service prices
                      leave-transition pricing      deterministic event
                      (straggler scenario)          streams; Monte-Carlo
        "vectorized"  full (same as "batched")      pricing lives in
        "reference"   RAISES when an event needs    ``evaluate_design(
                      scenario pricing or a         stochastic=...)`` /
                      precompiled incidence         ``StochasticTau.price``
        "jax"         amendment pricing only        (both honor this
                      (capacity phases + churn);    ``engine``)
                      leave transitions still
                      price on "batched" (straggler
                      events don't lower to XLA)

        ``__post_init__`` RAISES on any engine name ``simulate`` does
        not accept.
    """

    design_iterations: int | None = None
    weight_opt: bool = False
    engine: str = "batched"
    drift_band: float = 0.05
    horizon_rounds: float = 50.0
    transition_rounds: float = 1.0
    max_retries: int = 2
    backoff_base: float = 0.5
    backoff_factor: float = 2.0
    price_transitions: bool = True

    def __post_init__(self):
        if self.engine not in _ENGINES:
            raise ValueError(
                f"unknown pricing engine {self.engine!r}: valid engines "
                f"are {', '.join(repr(e) for e in _ENGINES)}"
            )
        if self.drift_band < 0:
            raise ValueError("drift_band must be nonnegative")
        if self.max_retries < 0:
            raise ValueError("max_retries must be nonnegative")
        if self.backoff_base < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff must be nonnegative, factor >= 1")


@dataclasses.dataclass(frozen=True)
class ServiceRecord:
    """One event, one record — the auditable decision trail."""

    seq: int  # position in the ingested stream
    time: float  # event time (virtual seconds)
    event: str  # event kind
    decision: str  # absorb|patch|defer|adopt|redesign|scratch-rebuild|
    #               quarantine|drop|reject
    tier: str  # normal|incumbent-keep|scratch-rebuild|quarantine
    tau: float  # deployed τ after the event
    detail: str = ""
    retries: int = 0
    faults: tuple[str, ...] = ()


class ServiceLog:
    """Append-only record list with decision/tier tallies."""

    def __init__(self):
        self.records: list[ServiceRecord] = []

    def append(self, rec: ServiceRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    def _tally(self, field: str) -> dict[str, int]:
        counts: dict[str, int] = {}
        for r in self.records:
            k = getattr(r, field)
            counts[k] = counts.get(k, 0) + 1
        return counts

    @property
    def decisions(self) -> dict[str, int]:
        return self._tally("decision")

    @property
    def tiers(self) -> dict[str, int]:
        return self._tally("tier")

    @property
    def fault_count(self) -> int:
        return sum(len(r.faults) for r in self.records)


@dataclasses.dataclass(frozen=True)
class DesignCandidate:
    """A priced redesign proposal. ``epoch`` stamps the service state it
    was computed against — a stale-cache fault from an earlier epoch is
    detected by the mismatch and retried."""

    epoch: int
    matrix: np.ndarray
    links: tuple
    tau: float  # realized τ of the candidate (closed form, Lemma III.2)
    transition_tau: float  # in-flight-round makespan under the switch
    routing: RoutingSolution


def _poison(cand: DesignCandidate) -> DesignCandidate:
    """The injector's ``nan`` corruption: a numerically-poisoned τ."""
    return dataclasses.replace(cand, tau=float("nan"))


class DesignService:
    """The long-lived loop. Construct from a designed overlay, then feed
    events through ``process``/``run``. See the module docstring for the
    decision policy; all state below is derived from three primaries —
    the membership (stable integer handles → underlay nodes), the cached
    shortest paths per handle pair, and the per-edge capacity scale map
    — so every structure can be re-derived from scratch when a contract
    trips.
    """

    def __init__(
        self,
        overlay: OverlayNetwork,
        kappa: float,
        config: ServiceConfig | None = None,
        clock: VirtualClock | None = None,
        fault_injector: FaultInjector | None = None,
    ):
        self.config = config or ServiceConfig()
        self.kappa = float(kappa)
        self.clock = clock or VirtualClock()
        self.injector = fault_injector
        if self.injector is not None and self.injector._clock is None:
            self.injector._clock = self.clock
        self.log = ServiceLog()
        self._seq = 0
        self._epoch = 0
        self._underlay = overlay.underlay
        self._scale: dict[tuple[int, int], float] = {}
        self._quarantined: set[int] = set()
        # Membership: stable handles, initialized to agent indices.
        self._handles: list[int] = list(range(overlay.num_agents))
        self._next_handle = overlay.num_agents
        self._node_of: dict[int, int] = {
            h: overlay.agents[h] for h in self._handles
        }
        # Path cache, keyed (ha, hb) with ha < hb: exactly the paths
        # ``build_overlay`` would recompute, so regrouping from the
        # cache is bitwise-identical to rebuilding the overlay.
        self._pairs: dict[tuple[int, int], tuple[int, ...]] = {}
        m = overlay.num_agents
        for i in range(m):
            for j in range(i + 1, m):
                self._pairs[(i, j)] = overlay.path(i, j)
        self._rebuild_structure()
        self._cold_redesign()

    # -- derived-state maintenance ------------------------------------

    def _cap_of(self, u: int, v: int) -> float:
        key = (u, v) if u < v else (v, u)
        return self._underlay.capacity(u, v) * self._scale.get(key, 1.0)

    def _positions(self) -> dict[int, int]:
        return {h: p for p, h in enumerate(self._handles)}

    @property
    def num_agents(self) -> int:
        return len(self._handles)

    @property
    def members(self) -> tuple[int, ...]:
        return tuple(self._handles)

    @property
    def epoch(self) -> int:
        return self._epoch

    @property
    def design(self) -> np.ndarray:
        return self._design

    @property
    def categories(self) -> Categories:
        return self._cats

    @property
    def tau(self) -> float:
        return self._tau

    @property
    def quarantined(self) -> tuple[int, ...]:
        return tuple(sorted(self._quarantined))

    def _rebuild_structure(self) -> None:
        """Regroup categories + recompile incidences from the cached
        paths under the current scale map — no path recomputation."""
        handles = self._handles
        m = len(handles)
        pos = self._positions()
        self._overlay = OverlayNetwork(
            underlay=self._underlay,
            agents=tuple(self._node_of[h] for h in handles),
            paths={
                (pos[a], pos[b]): p
                for (a, b), p in self._pairs.items()
            },
        )
        link_arr, eu, ev, rank = self._overlay.batched_path_edges()
        self._cats = _group_category_pairs(
            m, link_arr, eu, ev, rank, self._cap_of
        )
        self._inc = compile_category_incidence(self._cats, m, self.kappa)
        self._edge_index = edge_category_index(self._cats)
        self._entry_order = category_entry_order(self._inc)
        self._prio = (
            _PriorityState(
                [(i, j) for i in range(m) for j in range(i + 1, m)],
                m,
                self._cats,
                self.kappa,
                incidence=self._inc,
            )
            if m >= 2
            else None
        )
        self._epoch += 1

    def _rebuild_from_scratch(self) -> None:
        """Scratch-rebuild degradation tier: distrust every cached
        structure and re-derive overlay + categories from the primaries
        (membership, scale map) via the full pipeline."""
        und = self._underlay
        if self._scale:
            und = self._underlay.with_scaled_capacities(
                {k: s for k, s in sorted(self._scale.items())}
            )
        ov = build_overlay(
            und, [self._node_of[h] for h in self._handles]
        )
        # Re-prime the path cache from the rebuilt overlay (hop-count
        # paths are capacity-independent, but the cache is now untrusted).
        pos_to_handle = dict(enumerate(self._handles))
        self._pairs = {
            (pos_to_handle[i], pos_to_handle[j]): ov.path(i, j)
            for (i, j) in ov.overlay_links
        }
        m = ov.num_agents
        self._overlay = OverlayNetwork(
            underlay=self._underlay,
            agents=ov.agents,
            paths=dict(ov.paths),
        )
        self._cats = compute_categories(ov)
        self._inc = compile_category_incidence(self._cats, m, self.kappa)
        self._edge_index = edge_category_index(self._cats)
        self._entry_order = category_entry_order(self._inc)
        self._prio = (
            _PriorityState(
                [(i, j) for i in range(m) for j in range(i + 1, m)],
                m,
                self._cats,
                self.kappa,
                incidence=self._inc,
            )
            if m >= 2
            else None
        )
        self._epoch += 1

    def _design_once(self) -> tuple[np.ndarray, tuple]:
        """One FMMD-P run on the current structures (warm when the
        priority state exists — ``reset`` makes warm bitwise-equal to
        cold, property-tested)."""
        m = self.num_agents
        iters = self.config.design_iterations or max(2 * m, 4)
        if self._prio is not None:
            self._prio.reset(self._inc)
        res = fmmd(
            m,
            iters,
            categories=self._cats,
            kappa=self.kappa,
            weight_opt=self.config.weight_opt,
            priority=True,
            incidence=self._inc,
            warm_state=self._prio,
        )
        return res.matrix, res.activated_links

    def _deploy(self, matrix: np.ndarray, links: tuple,
                routing: RoutingSolution | None = None) -> None:
        """Install a design: route it, compile + capacity-patch the
        branch incidence, refresh the deployed-τ bookkeeping."""
        m = self.num_agents
        self._design = matrix
        self._links = tuple(links)
        if routing is None:
            routing = route_direct(
                demands_from_links(self._links, self.kappa, m),
                self._cats,
                self.kappa,
            )
        self._routing = routing
        if routing.demands:
            binc = compile_incidence(routing, self._overlay)
            if self._scale:
                binc = binc.with_capacities(self._scaled_directed_caps())
            self._binc = binc
            self._loads = self._inc.loads_from_uses(routing.link_uses())
        else:
            self._binc = None
            self._loads = np.zeros(self._inc.num_categories)
        self._tau = self._inc.completion_time(self._loads)
        self._tau_adopt = self._tau

    def _cold_redesign(self) -> None:
        m = self.num_agents
        if m <= 1:
            self._design = np.ones((m, m))
            self._links = ()
            self._routing = None
            self._binc = None
            self._loads = np.zeros(self._inc.num_categories)
            self._tau = 0.0
            self._tau_adopt = 0.0
            return
        matrix, links = self._design_once()
        self._deploy(matrix, links)

    def _scaled_directed_caps(self) -> dict[tuple[int, int], float]:
        """Directed absolute capacities of every currently-scaled edge —
        what ``BranchIncidence.with_capacities`` consumes."""
        caps: dict[tuple[int, int], float] = {}
        for (u, v), s in sorted(self._scale.items()):
            c = self._underlay.capacity(u, v) * s
            caps[(u, v)] = c
            caps[(v, u)] = c
        return caps

    # -- pricing with retry / degradation ------------------------------

    def _priced_candidate(self) -> DesignCandidate:
        matrix, links = self._design_once()
        m = self.num_agents
        routing = route_direct(
            demands_from_links(links, self.kappa, m),
            self._cats,
            self.kappa,
        )
        ttrans = float("nan")
        if (
            self.config.price_transitions
            and self._routing is not None
            and self._routing.demands
            and self._binc is not None
        ):
            # PR 3's transition price: the round in flight completes on
            # the *patched* capacities before the new design takes over.
            sim = simulate(
                self._routing, self._overlay, incidence=self._binc,
                engine=self.config.engine,
            )
            ttrans = float(sim.makespan)
        return DesignCandidate(
            epoch=self._epoch,
            matrix=matrix,
            links=links,
            tau=float(routing.completion_time),
            transition_tau=ttrans,
            routing=routing,
        )

    def _attempt_redesign(
        self,
    ) -> tuple[DesignCandidate | None, int, tuple[str, ...]]:
        """Bounded retry-with-backoff around one priced redesign.

        Returns ``(candidate, retries, fault_descriptions)`` with
        ``candidate=None`` when every attempt failed — the caller picks
        the degradation tier.
        """
        cfg = self.config
        faults: list[str] = []
        delay = cfg.backoff_base
        for attempt in range(cfg.max_retries + 1):
            try:
                if self.injector is not None:
                    cand = self.injector.call(
                        self._priced_candidate, poison=_poison
                    )
                else:
                    cand = self._priced_candidate()
                if cand.epoch != self._epoch:
                    raise PricingFault(
                        f"stale candidate: epoch {cand.epoch} != "
                        f"{self._epoch}"
                    )
                if not math.isfinite(cand.tau) or not np.all(
                    np.isfinite(cand.matrix)
                ):
                    raise PricingFault("poisoned candidate (non-finite)")
                return cand, attempt, tuple(faults)
            except PricingFault as exc:
                faults.append(f"attempt {attempt}: {exc}")
                if attempt < cfg.max_retries:
                    self.clock.advance(delay)
                    delay *= cfg.backoff_factor
        return None, cfg.max_retries, tuple(faults)

    # -- event handlers ------------------------------------------------

    def _event_time(self, ev) -> float:
        t = getattr(ev, "time", None)
        if isinstance(t, (int, float)) and math.isfinite(t):
            return float(t)
        return self.clock.now()  # malformed time: stamp with service time

    def _record(self, ev, decision: str, tier: str = "normal",
                detail: str = "", retries: int = 0,
                faults: tuple[str, ...] = ()) -> ServiceRecord:
        rec = ServiceRecord(
            seq=self._seq,
            time=self._event_time(ev),
            event=type(ev).__name__,
            decision=decision,
            tier=tier,
            tau=self._tau,
            detail=detail,
            retries=retries,
            faults=faults,
        )
        self.log.append(rec)
        self._seq += 1
        return rec

    def process(self, ev) -> ServiceRecord:
        """Ingest one event; always returns (and logs) exactly one
        record — the zero-dropped-events contract."""
        self.clock.advance_to(self._event_time(ev))
        origin = getattr(ev, "origin", None)
        if origin is not None and origin in self._quarantined:
            return self._record(
                ev, "drop", tier="quarantine",
                detail=f"origin {origin} is quarantined",
            )
        reason = malformed_reason(ev)
        if reason is not None:
            if origin is not None:
                self._quarantined.add(origin)
                return self._record(
                    ev, "quarantine", tier="quarantine",
                    detail=f"malformed ({reason}); origin {origin} "
                    "quarantined",
                )
            return self._record(
                ev, "reject", tier="quarantine",
                detail=f"malformed ({reason}); no attributable origin",
            )
        if isinstance(ev, LinkStateChange):
            return self._on_link_state(ev)
        if isinstance(ev, AgentLeave):
            return self._on_leave(ev)
        if isinstance(ev, AgentJoin):
            return self._on_join(ev)
        return self._record(  # pragma: no cover - malformed_reason gates
            ev, "reject", tier="quarantine", detail="unhandled event"
        )

    def run(self, events: Sequence) -> ServiceLog:
        """Replay an event stream (sorted by ``event_sort_key``)."""
        for ev in sorted(events, key=event_sort_key):
            self.process(ev)
        return self.log

    # LinkStateChange ---------------------------------------------------

    def _on_link_state(self, ev: LinkStateChange) -> ServiceRecord:
        unknown = [
            e for e in ev.scales if not self._underlay.graph.has_edge(*e)
        ]
        if unknown:
            detail = f"scales name non-underlay edges {unknown[:4]}"
            if ev.origin is not None:
                self._quarantined.add(ev.origin)
                return self._record(
                    ev, "quarantine", tier="quarantine",
                    detail=f"{detail}; origin {ev.origin} quarantined",
                )
            return self._record(
                ev, "reject", tier="quarantine", detail=detail
            )
        changed: dict[tuple[int, int], float] = {}
        for e, s in ev.scales.items():
            key = (e[0], e[1]) if e[0] < e[1] else (e[1], e[0])
            s = float(s)
            if s != self._scale.get(key, 1.0):
                changed[key] = s
        if not changed:
            return self._record(ev, "absorb", detail="no scale moved")
        for key, s in sorted(changed.items()):
            if s == 1.0:
                self._scale.pop(key, None)
            else:
                self._scale[key] = s
        # Directed member edges touched; non-traversed edges belong to
        # no category (Definition 1) and provably change nothing.
        member_caps: dict[tuple[int, int], float] = {}
        directed_caps: dict[tuple[int, int], float] = {}
        edge_cap = self._cats.edge_capacity or {}
        for key, s in sorted(changed.items()):
            for d in (key, (key[1], key[0])):
                c = self._underlay.capacity(*d) * s
                directed_caps[d] = c
                if d in edge_cap:
                    member_caps[d] = c
        if not member_caps:
            return self._record(
                ev, "absorb",
                detail=f"{len(changed)} edge(s) moved, none traversed",
            )
        try:
            cats, touched = patch_categories_capacity(
                self._cats, member_caps, self._edge_index
            )
            inc = patch_category_incidence(
                self._inc, cats, touched, self._entry_order
            )
            binc = (
                self._binc.with_capacities(directed_caps)
                if self._binc is not None
                else None
            )
        except ContractViolation as exc:
            self._rebuild_from_scratch()
            self._cold_redesign()
            return self._record(
                ev, "scratch-rebuild", tier="scratch-rebuild",
                detail=f"incremental patch tripped contract: {exc}",
            )
        self._cats, self._inc, self._binc = cats, inc, binc
        self._epoch += 1  # capacity state moved: older candidates stale
        if self._prio is not None:
            self._prio.reset(self._inc)
        tau_now = self._inc.completion_time(self._loads)
        self._tau = tau_now
        band = self.config.drift_band * self._tau_adopt
        if abs(tau_now - self._tau_adopt) <= band:
            return self._record(
                ev, "patch",
                detail=f"{touched.size} categor(ies) re-bottlenecked, "
                f"tau within band",
            )
        cand, retries, faults = self._attempt_redesign()
        if cand is None:
            return self._record(
                ev, "incumbent-keep", tier="incumbent-keep",
                detail="redesign failed after retries; incumbent kept",
                retries=retries, faults=faults,
            )
        saving = self.config.horizon_rounds * (tau_now - cand.tau)
        cost = self.config.transition_rounds * (
            cand.transition_tau if math.isfinite(cand.transition_tau)
            else 0.0
        )
        if saving <= cost:
            return self._record(
                ev, "defer",
                detail=f"saving {saving:.3g} <= transition {cost:.3g}",
                retries=retries, faults=faults,
            )
        self._deploy(cand.matrix, cand.links, routing=cand.routing)
        return self._record(
            ev, "adopt",
            detail=f"tau {tau_now:.3g} -> {self._tau:.3g}, "
            f"transition {cost:.3g}",
            retries=retries, faults=faults,
        )

    # AgentLeave --------------------------------------------------------

    def _on_leave(self, ev: AgentLeave) -> ServiceRecord:
        h = ev.agent
        if h not in self._node_of:
            if ev.origin is not None:
                self._quarantined.add(ev.origin)
                return self._record(
                    ev, "quarantine", tier="quarantine",
                    detail=f"leave for unknown agent {h}; origin "
                    f"{ev.origin} quarantined",
                )
            return self._record(
                ev, "reject", tier="quarantine",
                detail=f"leave for unknown agent {h}",
            )
        if len(self._handles) == 1:
            return self._record(
                ev, "reject", tier="quarantine",
                detail="last agent cannot leave",
            )
        old_w = self._design
        old_routing, old_binc = self._routing, self._binc
        old_overlay = self._overlay
        keep_pos = [
            p for p, hh in enumerate(self._handles) if hh != h
        ]
        gone_pos = self._handles.index(h)
        self._handles.remove(h)
        del self._node_of[h]
        self._pairs = {
            (a, b): p
            for (a, b), p in self._pairs.items()
            if a != h and b != h
        }
        try:
            self._rebuild_structure()
        except ContractViolation:
            self._rebuild_from_scratch()
        m = self.num_agents
        if m == 1:
            self._cold_redesign()
            return self._record(
                ev, "redesign",
                detail="single survivor: identity design",
            )
        ttrans = self._price_leave_transition(
            old_routing, old_binc, old_overlay, gone_pos
        )
        cand, retries, faults = self._attempt_redesign()
        if cand is not None:
            self._deploy(cand.matrix, cand.links, routing=cand.routing)
            return self._record(
                ev, "redesign",
                detail=f"agent {h} left; transition {ttrans:.3g}",
                retries=retries, faults=faults,
            )
        # Degradation: shrink the incumbent — drop the departed row and
        # push the lost mass back to the diagonal (doubly stochastic).
        w_eff = renormalized_mixing(
            old_w[np.ix_(keep_pos, keep_pos)],
            np.ones((m, m), dtype=bool),
        )
        links = tuple(
            (i, j)
            for i in range(m)
            for j in range(i + 1, m)
            if w_eff[i, j] > 1e-12
        )
        self._deploy(w_eff, links)
        return self._record(
            ev, "incumbent-keep", tier="incumbent-keep",
            detail=f"redesign failed; incumbent renormalized over "
            f"{m} survivors",
            retries=retries, faults=faults,
        )

    def _price_leave_transition(
        self, old_routing, old_binc, old_overlay, gone_pos: int
    ) -> float:
        """The in-flight round under the departure (PR 3's transition
        pricing): the departed agent's exchanges cancel mid-round."""
        if (
            not self.config.price_transitions
            or old_routing is None
            or not old_routing.demands
            or old_binc is None
        ):
            return float("nan")
        tau0 = max(float(old_routing.completion_time), 1e-9)
        # Stays on "batched" regardless of config.engine: the departure
        # is modeled as a mid-round straggler, outside the jax lowering.
        sim = simulate(
            old_routing,
            old_overlay,
            scenario=failure_scenario({gone_pos: 0.5 * tau0}),
            incidence=old_binc,
        )
        return float(sim.makespan)

    # AgentJoin ---------------------------------------------------------

    def _on_join(self, ev: AgentJoin) -> ServiceRecord:
        node = ev.node
        if node not in self._underlay.graph.nodes:
            return self._record(
                ev, "reject", tier="quarantine",
                detail=f"join on unknown underlay node {node}",
            )
        if node in {self._node_of[h] for h in self._handles}:
            return self._record(
                ev, "reject", tier="quarantine",
                detail=f"node {node} already hosts an agent",
            )
        snapshot = self._snapshot()
        h = self._next_handle
        self._next_handle += 1
        for a in list(self._handles):
            self._pairs[(a, h)] = self._underlay.shortest_path(
                self._node_of[a], node
            )
        self._handles.append(h)
        self._node_of[h] = node
        try:
            self._rebuild_structure()
        except ContractViolation:
            self._rebuild_from_scratch()
        ttrans = float("nan")
        if (
            self.config.price_transitions
            and snapshot["routing"] is not None
            and snapshot["routing"].demands
            and snapshot["binc"] is not None
        ):
            sim = simulate(
                snapshot["routing"],
                snapshot["overlay"],
                incidence=snapshot["binc"],
                engine=self.config.engine,
            )
            ttrans = float(sim.makespan)
        cand, retries, faults = self._attempt_redesign()
        if cand is not None:
            self._deploy(cand.matrix, cand.links, routing=cand.routing)
            return self._record(
                ev, "redesign",
                detail=f"agent {h} joined on node {node}; transition "
                f"{ttrans:.3g}",
                retries=retries, faults=faults,
            )
        self._restore(snapshot)
        return self._record(
            ev, "incumbent-keep", tier="incumbent-keep",
            detail=f"join of node {node} reverted: redesign failed "
            "after retries",
            retries=retries, faults=faults,
        )

    # -- snapshot / restore (join revert) -------------------------------

    def _snapshot(self) -> dict:
        return {
            "handles": list(self._handles),
            "next_handle": self._next_handle,
            "node_of": dict(self._node_of),
            "pairs": dict(self._pairs),
            "overlay": self._overlay,
            "cats": self._cats,
            "inc": self._inc,
            "edge_index": self._edge_index,
            "entry_order": self._entry_order,
            "prio": self._prio,
            "design": self._design,
            "links": self._links,
            "routing": self._routing,
            "binc": self._binc,
            "loads": self._loads,
            "tau": self._tau,
            "tau_adopt": self._tau_adopt,
            "epoch": self._epoch,
        }

    def _restore(self, s: dict) -> None:
        self._handles = s["handles"]
        self._next_handle = s["next_handle"]
        self._node_of = s["node_of"]
        self._pairs = s["pairs"]
        self._overlay = s["overlay"]
        self._cats = s["cats"]
        self._inc = s["inc"]
        self._edge_index = s["edge_index"]
        self._entry_order = s["entry_order"]
        self._prio = s["prio"]
        self._design = s["design"]
        self._links = s["links"]
        self._routing = s["routing"]
        self._binc = s["binc"]
        self._loads = s["loads"]
        self._tau = s["tau"]
        self._tau_adopt = s["tau_adopt"]
        # A fresh epoch, not the snapshot's: candidates priced against
        # the aborted membership must read as stale.
        self._epoch += 1
