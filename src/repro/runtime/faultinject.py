"""Seeded fault injection for the design service's pricing calls.

Robustness claims are only as good as the faults they were tested
against, so the service's chaos story is a *harness*, not ad-hoc
monkeypatching: a ``FaultInjector`` wraps every pricing/redesign attempt
and, on a schedule that is a pure function of ``(seed, call index)``,
makes the call

  * ``raise``   — fail outright (``PricingFault``), before any work;
  * ``timeout`` — burn ``timeout_seconds`` of *virtual* clock, then fail
    (``PricingTimeout``) — no wall-clock reads, per the determinism lint;
  * ``nan``     — run the real computation, then hand back a poisoned
    copy (the caller supplies the ``poison`` transform — e.g. stamping
    τ to NaN), modelling a numerically-corrupted result;
  * ``stale``   — skip the computation and replay the *previous*
    successful result, modelling a cache or replica serving an old
    answer. The service detects cross-epoch staleness via the epoch
    stamp on its candidates.

Determinism: the per-call draw is ``default_rng((seed, call_index))``,
so fault schedules are reproducible per call even if earlier calls are
added or removed — the property that keeps chaos tests debuggable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np


class PricingFault(RuntimeError):
    """A pricing/redesign attempt failed (injected or organic)."""


class PricingTimeout(PricingFault):
    """A pricing attempt exceeded its (virtual) deadline."""


_MODES = ("raise", "timeout", "nan", "stale")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded schedule of injected pricing faults.

    ``rate`` is the per-call fault probability; ``modes`` the fault
    kinds drawn uniformly when a call faults. ``rate=0`` is the
    fault-free plan (every call passes through).
    """

    seed: int = 0
    rate: float = 0.0
    modes: Sequence[str] = _MODES
    timeout_seconds: float = 5.0

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate {self.rate} outside [0, 1]")
        bad = [m for m in self.modes if m not in _MODES]
        if bad or not self.modes:
            raise ValueError(
                f"unknown fault modes {bad}; choose from {_MODES}"
            )
        if self.timeout_seconds < 0:
            raise ValueError("timeout_seconds must be nonnegative")


class FaultInjector:
    """Wraps pricing calls, injecting faults per a ``FaultPlan``.

    ``clock`` is the service's virtual clock (``advance(seconds)``);
    timeouts advance it so retry/backoff arithmetic stays deterministic.
    ``injected`` records ``(call_index, mode)`` for every fault actually
    delivered — the ground truth chaos tests assert the ``ServiceLog``
    against.
    """

    def __init__(self, plan: FaultPlan, clock=None):
        self.plan = plan
        self._clock = clock
        self.calls = 0
        self.injected: list[tuple[int, str]] = []
        self._last_good = None
        self._has_last = False

    def _draw(self, idx: int) -> str | None:
        if self.plan.rate <= 0.0:
            return None
        rng = np.random.default_rng((self.plan.seed, idx))
        if rng.random() >= self.plan.rate:
            return None
        return self.plan.modes[int(rng.integers(len(self.plan.modes)))]

    def call(self, fn: Callable[[], object], poison=None):
        """Run ``fn`` under the fault schedule.

        ``poison`` transforms a clean result into a corrupted one for
        the ``nan`` mode; without it the mode degrades to ``raise``.
        """
        idx = self.calls
        self.calls += 1
        mode = self._draw(idx)
        if mode == "stale" and not self._has_last:
            mode = "raise"  # nothing cached yet: fail outright
        if mode == "nan" and poison is None:
            mode = "raise"
        if mode == "raise":
            self.injected.append((idx, "raise"))
            raise PricingFault(f"injected fault at pricing call {idx}")
        if mode == "timeout":
            self.injected.append((idx, "timeout"))
            if self._clock is not None:
                self._clock.advance(self.plan.timeout_seconds)
            raise PricingTimeout(
                f"injected timeout ({self.plan.timeout_seconds}s) at "
                f"pricing call {idx}"
            )
        if mode == "stale":
            self.injected.append((idx, "stale"))
            return self._last_good
        result = fn()
        self._last_good = result
        self._has_last = True
        if mode == "nan":
            self.injected.append((idx, "nan"))
            return poison(result)
        return result
