"""Event model for the design-as-a-service loop.

A deployed designer does not see networks — it sees *events*: link
capacities sagging and recovering, agents dropping out, agents asking to
join. This module defines the replayable event vocabulary the
``DesignService`` (``runtime/design_service.py``) ingests, plus the
bridge that turns a sampled ``StochasticScenario`` realization into an
event stream (so the same Markov dynamics that price designs offline
drive the service online).

Every event is a frozen dataclass with a ``time`` (virtual seconds) and
an optional ``origin`` — the agent handle that *reported* the event.
Origins power the quarantine degradation tier: a malformed event with an
attributable origin quarantines that reporter, and later events from a
quarantined origin are logged-and-dropped instead of trusted.

``malformed_reason`` is the structural validator: it returns a human-
readable reason string for events that must not reach the design logic
(non-finite times, non-positive capacity scales, bogus agent ids), or
``None`` for well-formed events. Semantic validation (does this agent
handle exist *right now*?) stays in the service, which owns membership.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from repro.net.stochastic import StochasticScenario, realization_deltas


@dataclasses.dataclass(frozen=True)
class LinkStateChange:
    """Underlay link capacities moved: ``scales`` maps underlay edges
    (either key direction) to their new *absolute* multiplier vs base
    capacity — 1.0 means the edge recovered. Matches the semantics of
    ``CapacityPhase.scale`` maps, so ``realization_deltas`` output feeds
    straight in."""

    time: float
    scales: Mapping[tuple[int, int], float]
    origin: int | None = None


@dataclasses.dataclass(frozen=True)
class AgentLeave:
    """Agent ``agent`` (service handle) departs — churn or failure."""

    time: float
    agent: int
    origin: int | None = None


@dataclasses.dataclass(frozen=True)
class AgentJoin:
    """A new agent asks to join, placed on underlay node ``node``."""

    time: float
    node: int
    origin: int | None = None


Event = LinkStateChange | AgentLeave | AgentJoin

# Deterministic tie-break for same-time events: capacity moves first
# (they are observations about the past interval), then departures, then
# joins. Stable sort preserves stream order within a kind.
_KIND_ORDER = {LinkStateChange: 0, AgentLeave: 1, AgentJoin: 2}


def event_sort_key(ev) -> tuple[float, int]:
    t = getattr(ev, "time", None)
    if not isinstance(t, (int, float)) or not math.isfinite(t):
        t = math.inf  # malformed times sort last; the service rejects them
    return (float(t), _KIND_ORDER.get(type(ev), 99))


def _is_index(x) -> bool:
    return isinstance(x, int) and not isinstance(x, bool) and x >= 0


def malformed_reason(ev) -> str | None:
    """Reason string when ``ev`` must not reach the design logic."""
    t = getattr(ev, "time", None)
    if not isinstance(t, (int, float)) or not math.isfinite(t) or t < 0:
        return f"non-finite or negative time {t!r}"
    if isinstance(ev, LinkStateChange):
        if not isinstance(ev.scales, Mapping):
            return "scales is not a mapping"
        for e, s in ev.scales.items():
            if (
                not isinstance(e, tuple)
                or len(e) != 2
                or not all(_is_index(n) for n in e)
            ):
                return f"malformed edge key {e!r}"
            if not isinstance(s, (int, float)) or not math.isfinite(s) \
                    or s <= 0:
                return f"non-positive scale {s!r} for edge {e}"
        return None
    if isinstance(ev, AgentLeave):
        return None if _is_index(ev.agent) else f"bad agent {ev.agent!r}"
    if isinstance(ev, AgentJoin):
        return None if _is_index(ev.node) else f"bad node {ev.node!r}"
    return f"unknown event type {type(ev).__name__}"


def events_from_stochastic(
    sto: StochasticScenario, key
) -> tuple[Event, ...]:
    """Event-source one sampled realization of ``sto``.

    Bitwise-deterministic in ``key`` (inherits ``sample``'s contract):
    each capacity-phase boundary becomes one ``LinkStateChange`` holding
    only the edges whose scale actually moved (``realization_deltas``),
    and each churn event becomes an ``AgentLeave`` of that agent handle.
    The stream is sorted by ``event_sort_key`` — replaying it through
    ``DesignService`` visits network states in realization order.
    """
    scen = sto.sample(key)
    events: list[Event] = []
    for t, changed in realization_deltas(scen):
        events.append(LinkStateChange(time=t, scales=changed))
    for c in scen.churn:
        events.append(AgentLeave(time=float(c.time), agent=int(c.agent)))
    return tuple(sorted(events, key=event_sort_key))
