"""Runtime: fault tolerance, straggler mitigation, compression."""

from repro.runtime import compression, fault_tolerance, stragglers
