"""Runtime: fault tolerance, design service, stragglers, compression."""

from repro.runtime import (
    compression,
    design_service,
    events,
    fault_tolerance,
    faultinject,
    stragglers,
)
