"""Runtime CSR contracts for the incidence structures.

The vectorized engines trust their precompiled CSR payloads blindly:
a non-monotone ``link_ptr`` silently mis-slices, an out-of-bounds
entry index reads another link's categories, a float32 capacity array
perturbs every priced makespan. These invariants are declared here
once and validated at construction of ``BranchIncidence``
(``net/simulator.py``), ``CategoryIncidence`` and ``_FlatCategories``
(``net/categories.py``) whenever ``REPRO_VALIDATE=1`` — the safety net
incremental incidence patching (ROADMAP item 3) will run behind.

Validation is opt-in because construction sits on hot paths (one
incidence per routing solution, one rescale per capacity phase): with
the flag unset the cost is one environment lookup and a dict probe.
The nightly tier-1 suite runs with it enabled; tests can monkeypatch
``REPRO_VALIDATE``.

This module is imported by ``repro.net`` at module load, so it must
not import anything from ``repro`` outside this package. Dispatch is
by class *name* (``maybe_validate``) for the same reason: the
dataclasses call in, never the other way around.
"""

from __future__ import annotations

import os

import numpy as np

_ENV_FLAG = "REPRO_VALIDATE"


class ContractViolation(ValueError):
    """A CSR incidence structure failed a declared invariant.

    ``structure``/``field``/``invariant`` name the violation precisely;
    the message says what was found and what well-formed looks like, so
    the error is actionable at the (possibly distant) construction site
    that produced the corrupt payload.
    """

    def __init__(self, structure: str, field: str, invariant: str,
                 detail: str):
        self.structure = structure
        self.field = field
        self.invariant = invariant
        super().__init__(
            f"{structure}.{field} violates '{invariant}': {detail}"
        )


def validation_enabled() -> bool:
    """True when ``REPRO_VALIDATE`` is set to anything but ''/'0'."""
    return os.environ.get(_ENV_FLAG, "") not in ("", "0")


# ---------------------------------------------------------------------------
# Invariant primitives — each raises ContractViolation with a named
# invariant and an actionable message.
# ---------------------------------------------------------------------------


def _check_dtype(structure: str, field: str, arr: np.ndarray,
                 expected: type) -> None:
    if not isinstance(arr, np.ndarray):
        raise ContractViolation(
            structure, field, "is-ndarray",
            f"got {type(arr).__name__}; build it with np.asarray(..., "
            f"dtype=np.{np.dtype(expected).name})",
        )
    if arr.dtype != np.dtype(expected):
        raise ContractViolation(
            structure, field, "dtype",
            f"got {arr.dtype}, expected {np.dtype(expected).name} — "
            "pricing arrays are float64 and index arrays int64 "
            "everywhere (PR 1-5 discipline); cast at the producer, "
            "not the consumer",
        )


def _check_length(structure: str, field: str, arr: np.ndarray,
                  expected: int, what: str) -> None:
    if arr.ndim != 1 or arr.shape[0] != expected:
        raise ContractViolation(
            structure, field, "length",
            f"shape {arr.shape}, expected ({expected},) — must have one "
            f"entry per {what}",
        )


def _check_ptr(structure: str, field: str, ptr: np.ndarray,
               num_rows: int, nnz: int) -> None:
    """CSR pointer: int64, [num_rows+1], starts 0, ends nnz, monotone."""
    _check_dtype(structure, field, ptr, np.int64)
    _check_length(structure, field, ptr, num_rows + 1, "row plus sentinel")
    if ptr.size and (ptr[0] != 0 or ptr[-1] != nnz):
        raise ContractViolation(
            structure, field, "ptr-bounds",
            f"ptr[0]={ptr[0]}, ptr[-1]={ptr[-1]}, expected 0 and nnz="
            f"{nnz} — the pointer must span exactly the entry arrays",
        )
    if ptr.size and np.any(np.diff(ptr) < 0):
        bad = int(np.argmax(np.diff(ptr) < 0))
        raise ContractViolation(
            structure, field, "ptr-monotone",
            f"decreases at row {bad} ({ptr[bad]} -> {ptr[bad + 1]}) — "
            "CSR pointers are cumulative counts and must be "
            "non-decreasing; rebuild via bincount+cumsum",
        )


def _check_index(structure: str, field: str, idx: np.ndarray,
                 upper: int, what: str) -> None:
    """Index array: int64 and within [0, upper)."""
    _check_dtype(structure, field, idx, np.int64)
    if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= upper):
        raise ContractViolation(
            structure, field, "index-bounds",
            f"values span [{idx.min()}, {idx.max()}], must lie in "
            f"[0, {upper}) — every entry must name a real {what}",
        )


def _check_finite_positive(structure: str, field: str,
                           arr: np.ndarray) -> None:
    if arr.size and not np.all(np.isfinite(arr) & (arr > 0)):
        raise ContractViolation(
            structure, field, "finite-positive",
            "contains non-finite or non-positive values — capacities "
            "and coefficients are strictly positive bytes/s quantities",
        )


def _check_ptr_matches_entries(structure: str, ptr_field: str,
                               ptr: np.ndarray, entry_field: str,
                               entries: np.ndarray) -> None:
    """Each CSR slice [ptr[r], ptr[r+1]) must hold entries with row id
    r — i.e. ptr is exactly the bincount+cumsum of the (sorted) row
    array. Catches ptr/entry mismatches that in-bounds checks miss."""
    expect = np.repeat(
        np.arange(ptr.size - 1, dtype=np.int64), np.diff(ptr)
    )
    if not np.array_equal(expect, entries):
        bad = int(np.argmax(expect != entries))
        raise ContractViolation(
            structure, ptr_field, "ptr-entry-consistency",
            f"slice arithmetic puts entry {bad} in row {expect[bad]} "
            f"but {entry_field}[{bad}]={entries[bad]} — the entry array "
            "must be row-major-sorted with ptr its cumulative histogram",
        )


# ---------------------------------------------------------------------------
# Per-structure validators (duck-typed: attribute access only, so this
# module never imports repro.net).
# ---------------------------------------------------------------------------


def validate_branch_incidence(inc) -> None:
    """All declared invariants of ``net.simulator.BranchIncidence``."""
    s = "BranchIncidence"
    nb = inc.flows.shape[0] if hasattr(inc.flows, "shape") else 0
    _check_dtype(s, "base_capacity", inc.base_capacity, np.float64)
    _check_finite_positive(s, "base_capacity", inc.base_capacity)
    ne = inc.base_capacity.shape[0]
    _check_dtype(s, "flows", inc.flows, np.int64)
    _check_dtype(s, "links", inc.links, np.int64)
    if inc.links.shape != (nb, 2):
        raise ContractViolation(
            s, "links", "length",
            f"shape {inc.links.shape}, expected ({nb}, 2) — one (i, j) "
            "overlay endpoint pair per branch",
        )
    nnz = inc.flat_branch.shape[0]
    _check_index(s, "flat_branch", inc.flat_branch, nb, "branch")
    _check_length(s, "flat_edge", inc.flat_edge, nnz, "traversal entry")
    _check_index(s, "flat_edge", inc.flat_edge, ne, "indexed edge")
    _check_ptr(s, "branch_ptr", inc.branch_ptr, nb, nnz)
    _check_ptr_matches_entries(
        s, "branch_ptr", inc.branch_ptr, "flat_branch", inc.flat_branch
    )
    _check_length(s, "edge_branch", inc.edge_branch, nnz, "traversal entry")
    _check_index(s, "edge_branch", inc.edge_branch, nb, "branch")
    _check_ptr(s, "edge_ptr", inc.edge_ptr, ne, nnz)
    if len(inc.edges) != ne or len(inc.edge_index) != ne:
        raise ContractViolation(
            s, "edges", "length",
            f"{len(inc.edges)} edges / {len(inc.edge_index)} index "
            f"entries for {ne} capacities — the three must agree",
        )


def _check_bucket(structure: str, field: str, padded: int,
                  real: int) -> None:
    """Padded axis: power-of-two bucket >= 8 with >= 1 pad slot."""
    if padded < 8 or padded & (padded - 1) or padded <= real:
        raise ContractViolation(
            structure, field, "padded-bucket",
            f"padded extent {padded} for real extent {real} — device "
            "axes are power-of-two buckets >= 8 with at least one "
            "padding slot (the inert row every pad entry points at); "
            "rebuild via jax_engine.device_incidence",
        )


def _check_pad_value(structure: str, field: str, arr: np.ndarray,
                     start: int, value) -> None:
    if arr[start:].size and not np.all(arr[start:] == value):
        raise ContractViolation(
            structure, field, "inert-padding",
            f"padding tail [{start}:] must be uniformly {value!r} so "
            "padded entries/rows cannot perturb segment reductions — "
            f"found {arr[start:][arr[start:] != value][:3]!r}",
        )


def _check_prefix(structure: str, field: str, arr: np.ndarray,
                  expect: np.ndarray) -> None:
    n = expect.shape[0]
    if not np.array_equal(arr[:n], expect):
        bad = int(np.argmax(arr[:n] != expect))
        raise ContractViolation(
            structure, field, "source-prefix",
            f"entry {bad} is {arr[bad]!r} but the source incidence has "
            f"{expect[bad]!r} — the real prefix must be bitwise-equal "
            "to the BranchIncidence payload (padding never rewrites "
            "live entries)",
        )


def validate_device_incidence(dev) -> None:
    """All declared invariants of ``net.jax_engine.DeviceIncidence``.

    The padded device layout is only safe if (a) every real prefix is
    bitwise the source ``BranchIncidence`` payload, (b) every padding
    entry points at the dedicated inert row (branch ``B`` with size 0,
    edge ``E`` with capacity 1), (c) the edge-major ordering is
    sorted so ``segment_sum(..., indices_are_sorted=True)`` is valid,
    and (d) the bounded-degree tables the kernel actually gathers
    through (``branch_table``/``edge_table``) repack the CSR segments
    exactly, padded with the inert row id.
    """
    s = "DeviceIncidence"
    src = dev.source
    validate_branch_incidence(src)
    nb, ne = src.flows.shape[0], src.base_capacity.shape[0]
    nnz = src.flat_branch.shape[0]
    if (dev.num_branches, dev.num_edges, dev.num_entries) != (nb, ne, nnz):
        raise ContractViolation(
            s, "num_branches", "source-extents",
            f"declared (B, E, nnz)=({dev.num_branches}, {dev.num_edges},"
            f" {dev.num_entries}) but source has ({nb}, {ne}, {nnz}) — "
            "the unpadded extents are what run_rollouts slices back out",
        )
    zp = dev.flat_branch.shape[0]
    for field in ("flat_branch", "flat_edge", "edge_branch", "edge_edge"):
        arr = getattr(dev, field)
        _check_dtype(s, field, arr, np.int64)
        _check_length(s, field, arr, zp, "padded traversal entry")
    _check_dtype(s, "base_capacity", dev.base_capacity, np.float64)
    _check_dtype(s, "sizes", dev.sizes, np.float64)
    _check_bucket(s, "flat_branch", zp, nnz)
    _check_bucket(s, "base_capacity", dev.base_capacity.shape[0], ne)
    _check_bucket(s, "sizes", dev.sizes.shape[0], nb)
    _check_prefix(s, "flat_branch", dev.flat_branch, src.flat_branch)
    _check_prefix(s, "flat_edge", dev.flat_edge, src.flat_edge)
    _check_prefix(s, "edge_branch", dev.edge_branch, src.edge_branch)
    _check_prefix(
        s, "edge_edge", dev.edge_edge,
        np.repeat(np.arange(ne, dtype=np.int64), np.diff(src.edge_ptr)),
    )
    _check_prefix(s, "base_capacity", dev.base_capacity,
                  src.base_capacity)
    _check_pad_value(s, "flat_branch", dev.flat_branch, nnz, nb)
    _check_pad_value(s, "flat_edge", dev.flat_edge, nnz, ne)
    _check_pad_value(s, "edge_branch", dev.edge_branch, nnz, nb)
    _check_pad_value(s, "edge_edge", dev.edge_edge, nnz, ne)
    _check_pad_value(s, "base_capacity", dev.base_capacity, ne, 1.0)
    _check_pad_value(s, "sizes", dev.sizes, nb, 0.0)
    for field, rows, real_ptr in (
        ("branch_ptr", dev.sizes.shape[0], src.branch_ptr),
        ("edge_ptr", dev.base_capacity.shape[0], src.edge_ptr),
    ):
        ptr = getattr(dev, field)
        real = real_ptr.shape[0] - 1
        _check_dtype(s, field, ptr, np.int64)
        _check_length(s, field, ptr, rows + 1, "padded CSR pointer")
        _check_prefix(s, field, ptr, real_ptr)
        # Pad row `real` owns exactly the pad entries [nnz, zp); rows
        # past it are empty — that closure is what makes the cumsum
        # segment reduction equal an element-wise segment sum.
        _check_pad_value(s, field, ptr, real + 1, zp)
    for field, real_ptr, values, rows, fill in (
        ("branch_table", src.branch_ptr, src.flat_edge,
         dev.sizes.shape[0], ne),
        ("edge_table", src.edge_ptr, src.edge_branch,
         dev.base_capacity.shape[0], nb),
    ):
        table = getattr(dev, field)
        _check_dtype(s, field, table, np.int32)
        deg = np.diff(real_ptr)
        width = max(2, 1 << max(0, int(deg.max(initial=0)) - 1).bit_length())
        if table.shape != (rows, width):
            raise ContractViolation(
                s, field, "table-shape",
                f"shape {table.shape} != ({rows}, {width}) — bounded-"
                "degree tables span every padded row at the power-of-"
                "two width of the maximum real degree",
            )
        expected = np.full((rows, width), fill, dtype=np.int32)
        mask = np.arange(width)[None, :] < deg[:, None]
        expected[: deg.size][mask] = values
        if not np.array_equal(table, expected):
            bad = int(np.argmax(np.any(table != expected, axis=1)))
            raise ContractViolation(
                s, field, "table-packing",
                f"row {bad} does not repack its CSR segment — each row "
                "must list the segment's ids in order, padded with the "
                f"inert id {fill}; the kernel gathers through these "
                "rows instead of the CSR entries",
            )
    if nnz > 1 and np.any(np.diff(dev.edge_edge[:nnz]) < 0):
        bad = int(np.argmax(np.diff(dev.edge_edge[:nnz]) < 0))
        raise ContractViolation(
            s, "edge_edge", "entries-sorted",
            f"edge ids decrease at entry {bad} — the edge-major "
            "ordering must be ascending, it is what licenses the "
            "cumsum-based sorted-segment reduction on the device",
        )
    if dev.sizes.size and not np.all(
        np.isfinite(dev.sizes) & (dev.sizes >= 0)
    ):
        raise ContractViolation(
            s, "sizes", "finite-nonnegative",
            "per-branch demand sizes must be finite and nonnegative "
            "byte counts (padding rows are exactly 0)",
        )


def validate_category_incidence(inc) -> None:
    """All declared invariants of ``net.categories.CategoryIncidence``."""
    s = "CategoryIncidence"
    m, nf = inc.num_agents, inc.capacity.shape[0]
    if not (np.isfinite(inc.kappa) and inc.kappa > 0):
        raise ContractViolation(
            s, "kappa", "finite-positive",
            f"kappa={inc.kappa!r} — per-link traffic must be a positive "
            "byte count",
        )
    _check_dtype(s, "capacity", inc.capacity, np.float64)
    _check_finite_positive(s, "capacity", inc.capacity)
    nnz = inc.entry_link.shape[0]
    _check_index(s, "entry_link", inc.entry_link, m * m, "dense link id")
    _check_length(s, "entry_cat", inc.entry_cat, nnz, "entry")
    _check_index(s, "entry_cat", inc.entry_cat, nf, "category")
    _check_dtype(s, "entry_coef", inc.entry_coef, np.float64)
    _check_length(s, "entry_coef", inc.entry_coef, nnz, "entry")
    _check_finite_positive(s, "entry_coef", inc.entry_coef)
    _check_ptr(s, "link_ptr", inc.link_ptr, m * m, nnz)
    _check_ptr_matches_entries(
        s, "link_ptr", inc.link_ptr, "entry_link", inc.entry_link
    )
    if nnz and not np.array_equal(
        inc.entry_coef, (inc.kappa / inc.capacity)[inc.entry_cat]
    ):
        raise ContractViolation(
            s, "entry_coef", "coef-consistency",
            "entry_coef != (kappa / capacity)[entry_cat] bitwise — "
            "coefficients must be rebuilt (never patched in place) "
            "whenever capacity changes; see CategoryIncidence.rescaled",
        )


def validate_flat_categories(flat) -> None:
    """All declared invariants of ``net.categories._FlatCategories``."""
    s = "_FlatCategories"
    m, nf = flat.num_agents, flat.num_categories
    nnz = flat.entry_link.shape[0]
    _check_index(s, "entry_link", flat.entry_link, m * m, "dense link id")
    _check_length(s, "entry_cat", flat.entry_cat, nnz, "entry")
    _check_index(s, "entry_cat", flat.entry_cat, nf, "category")
    _check_ptr(s, "link_ptr", flat.link_ptr, m * m, nnz)
    _check_ptr_matches_entries(
        s, "link_ptr", flat.link_ptr, "entry_link", flat.entry_link
    )
    if nnz > 1:
        dl = np.diff(flat.entry_link)
        dc = np.diff(flat.entry_cat)
        if not np.all((dl > 0) | ((dl == 0) & (dc > 0))):
            bad = int(np.argmax(~((dl > 0) | ((dl == 0) & (dc > 0)))))
            raise ContractViolation(
                s, "entry_link", "entries-sorted",
                f"entries {bad} and {bad + 1} are not strictly "
                "(link, category)-ascending — the payload must be the "
                "fused-key sort compute_categories produces (each "
                "(link, family) pair at most once)",
            )


# Dispatch by class name: the dataclasses call ``maybe_validate(self)``
# from ``__post_init__``; this module never imports their definitions.
# The contracts static checker (contracts_static.py) keys off this
# registry too — adding a structure here obligates wiring its hook.
VALIDATORS = {
    "BranchIncidence": validate_branch_incidence,
    "CategoryIncidence": validate_category_incidence,
    "DeviceIncidence": validate_device_incidence,
    "_FlatCategories": validate_flat_categories,
}


def maybe_validate(obj) -> None:
    """Validate ``obj`` against its registered contract when
    ``REPRO_VALIDATE`` is on; free (one env read) otherwise."""
    if validation_enabled():
        validator = VALIDATORS.get(type(obj).__name__)
        if validator is not None:
            validator(obj)
