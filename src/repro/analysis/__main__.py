"""CLI: ``python -m repro.analysis [--all | --<checker>... | name...]``.

Runs the static invariant checkers and exits non-zero when any
unwaived finding remains (2 on usage errors such as an unknown checker
name). ``--root`` points the suite at another tree (the negative
fixtures under ``tests/fixtures/lint_negative`` are the self-test: at
least one planted violation per checker).
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.analysis import (
    common,
    contracts_static,
    determinism,
    docs_check,
    dtypes,
    parity,
    tracelint,
)

CHECKERS = {
    "determinism": determinism.check,
    "dtypes": dtypes.check,
    "parity": parity.check,
    "contracts": contracts_static.check,
    "docs": docs_check.check,
    "tracelint": tracelint.check,
}


def run(
    root: Path, names: list[str], waiver_path: Path | None = None
) -> tuple[list[common.Finding], list[common.Finding]]:
    """(unwaived, waived) findings of the selected checkers on
    ``root``. The waiver file defaults to the tree's own
    ``src/repro/analysis/waivers.txt`` (fixture trees ship their own
    or none)."""
    findings: list[common.Finding] = []
    for name in names:
        findings.extend(CHECKERS[name](root))
    if waiver_path is None:
        waiver_path = root / "src/repro/analysis" / common.WAIVERS_FILENAME
    waivers, waiver_findings = common.load_waivers(waiver_path)
    findings.extend(waiver_findings)
    return common.apply_waivers(findings, waivers, waiver_path)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0],
    )
    ap.add_argument("--all", action="store_true",
                    help="run every checker (default when none selected)")
    for name in CHECKERS:
        ap.add_argument(f"--{name}", action="store_true",
                        help=f"run the {name} checker")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: this repo)")
    ap.add_argument("--waivers", type=Path, default=None,
                    help="waiver file (default: <root>/src/repro/"
                         "analysis/waivers.txt)")
    ap.add_argument("checkers", nargs="*", metavar="checker",
                    help="checker names to run (same as the --<name> "
                         "flags; unknown names exit 2)")
    args = ap.parse_args(argv)

    unknown = [n for n in args.checkers if n not in CHECKERS]
    if unknown:
        print(
            f"repro.analysis: unknown checker(s) "
            f"{', '.join(repr(n) for n in unknown)} — valid names: "
            f"{', '.join(CHECKERS)}",
            file=sys.stderr,
        )
        return 2
    selected = [
        n for n in CHECKERS if getattr(args, n) or n in args.checkers
    ]
    if args.all or not selected:
        selected = list(CHECKERS)
    root = (args.root or common.repo_root()).resolve()

    t0 = time.perf_counter()
    unwaived, waived = run(root, selected, args.waivers)
    elapsed = time.perf_counter() - t0

    for f in sorted(unwaived, key=lambda f: (f.path, f.line)):
        print(f.render())
    for note in tracelint.LAST_SKIP_NOTES:
        print(f"note: {note}")
    print(
        f"repro.analysis: {', '.join(selected)} on {root} — "
        f"{len(unwaived)} finding(s), {len(waived)} waived, "
        f"{elapsed:.2f}s"
    )
    return 1 if unwaived else 0


if __name__ == "__main__":
    sys.exit(main())
