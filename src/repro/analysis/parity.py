"""Parity-coverage checker.

Every speedup PR in this repo follows the same pattern: keep the
original implementation as ``*_reference`` ground truth and
property-test the fast path bitwise (or rtol) against it. That
discipline is only worth anything if the tests *stay* registered — an
optimization PR that deletes or forgets the parity test silently
removes the one thing standing between "fast" and "fast but wrong".

This checker makes the pairing explicit. ``parity_manifest.txt``
(next to this module; per-tree) registers every reference
implementation::

    <src-file>::<reference-def>  <fast-symbol>  <test-file>[,<test>…]  [via=<token>]

and the checker fails when:

``unregistered-reference``   a ``*_reference`` def exists in ``src/``
                             with no manifest entry;
``stale-manifest-entry``     a manifest entry names a file or def that
                             no longer exists;
``missing-parity-test``      a registered test file does not exist;
``parity-test-lacks-symbol`` the test file's AST mentions neither the
                             reference def (nor its ``via=`` token —
                             e.g. the simulator reference engine is
                             reached as ``engine="reference"``) nor
                             the fast symbol;
``malformed-manifest``       a line that doesn't parse.

Mentions are AST-level: an identifier (Name/Attribute/import) or an
exact string constant — a docstring that merely *talks about* the
symbol doesn't count, ``engine="reference"`` does.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

from repro.analysis.common import Finding, dotted_name, parse_file, rel

CHECKER = "parity"

MANIFEST_FILENAME = "parity_manifest.txt"
SRC_SCAN_DIR = "src/repro"


@dataclasses.dataclass(frozen=True)
class ManifestEntry:
    src_file: str       # repo-relative, POSIX
    reference: str      # def name
    fast: str           # fast-path symbol the tests must also touch
    tests: tuple[str, ...]
    via: str | None     # alternate mention token (string constant)
    line: int           # in the manifest file


def load_manifest(path: Path) -> tuple[list[ManifestEntry], list[Finding]]:
    entries: list[ManifestEntry] = []
    findings: list[Finding] = []
    if not path.is_file():
        return entries, findings
    for lineno, raw in enumerate(path.read_text().splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        fields = line.split()
        via = None
        if fields and fields[-1].startswith("via="):
            via = fields.pop()[len("via="):]
        if len(fields) != 3 or "::" not in fields[0]:
            findings.append(Finding(
                checker=CHECKER, path=path.name, line=lineno,
                scope="<module>", code="malformed-manifest",
                message=(
                    f"cannot parse {raw!r}: expected "
                    "'src_file::reference fast test[,test...] "
                    "[via=token]'"
                ),
            ))
            continue
        src_file, _, reference = fields[0].partition("::")
        entries.append(ManifestEntry(
            src_file=src_file, reference=reference, fast=fields[1],
            tests=tuple(fields[2].split(",")), via=via, line=lineno,
        ))
    return entries, findings


def _reference_defs(root: Path) -> dict[tuple[str, str], int]:
    """(repo-relative file, def name) -> line, for every function whose
    name ends in ``_reference`` under ``src/repro``."""
    out: dict[tuple[str, str], int] = {}
    src = root / SRC_SCAN_DIR
    if not src.is_dir():
        return out
    for path in sorted(src.rglob("*.py")):
        tree = parse_file(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name.endswith("_reference"):
                out[(rel(path, root), node.name)] = node.lineno
    return out


def _mentions(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(identifier mentions, exact string constants) in a test AST."""
    names: set[str] = set()
    strings: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
            chain = dotted_name(node)
            if chain:
                names.add(chain)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            strings.add(node.value)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add(alias.name.rsplit(".", 1)[-1])
                if alias.asname:
                    names.add(alias.asname)
    return names, strings


def check(root: Path) -> list[Finding]:
    manifest_path = root / "src/repro/analysis" / MANIFEST_FILENAME
    entries, findings = load_manifest(manifest_path)
    defs = _reference_defs(root)
    registered = {(e.src_file, e.reference) for e in entries}

    for (src_file, name), lineno in sorted(defs.items()):
        if (src_file, name) not in registered:
            findings.append(Finding(
                checker=CHECKER, path=src_file, line=lineno,
                scope=name, code="unregistered-reference",
                message=(
                    f"reference implementation {name!r} has no entry in "
                    f"{MANIFEST_FILENAME} — register its fast path and "
                    "parity test so coverage cannot be dropped silently"
                ),
            ))

    for e in entries:
        if (e.src_file, e.reference) not in defs:
            findings.append(Finding(
                checker=CHECKER, path=manifest_path.name, line=e.line,
                scope=e.reference, code="stale-manifest-entry",
                message=(
                    f"{e.src_file}::{e.reference} no longer exists — "
                    "update or remove the manifest entry (and make sure "
                    "the parity guarantee moved with the code)"
                ),
            ))
            continue
        for test_rel in e.tests:
            test_path = root / test_rel
            if not test_path.is_file():
                findings.append(Finding(
                    checker=CHECKER, path=test_rel, line=0,
                    scope=e.reference, code="missing-parity-test",
                    message=(
                        f"registered parity test file for {e.reference} "
                        "does not exist"
                    ),
                ))
                continue
            tree = parse_file(test_path)
            if tree is None:
                continue
            names, strings = _mentions(tree)
            ref_hit = e.reference in names or (
                e.via is not None and e.via in strings
            )
            fast_hit = e.fast in names or e.fast in strings
            if not (ref_hit and fast_hit):
                missing = e.reference if not ref_hit else e.fast
                findings.append(Finding(
                    checker=CHECKER, path=test_rel, line=1,
                    scope=e.reference, code="parity-test-lacks-symbol",
                    message=(
                        f"test file never references {missing!r} — the "
                        "registered parity test must exercise both the "
                        "reference and the fast path"
                    ),
                ))
    return findings
