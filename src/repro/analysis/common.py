"""Shared machinery for the static checkers.

A *finding* is one violation at one site. Its waiver key is
``checker path scope code`` — function-scoped rather than
line-numbered so waivers survive unrelated edits to the file, but
specific enough that a new violation of the same kind in a *different*
function is never silently covered by an old exemption.

``waivers.txt`` (next to this module; per-tree, so fixture trees carry
their own or none) holds one reviewed exemption per line::

    checker  path  scope  code  -- reason the invariant is safe here

Malformed lines (no ``--`` reason) and waivers that no finding used
are themselves findings: the file must stay an exact, reviewed list of
live exemptions — fixing a violation *removes* its entry.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

WAIVERS_FILENAME = "waivers.txt"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation at one site."""

    checker: str   # determinism | dtypes | parity | contracts | waivers
    path: str      # repo-root-relative, POSIX separators
    line: int
    scope: str     # dotted enclosing def/class qualname, or <module>
    code: str      # stable machine-readable violation kind
    message: str

    @property
    def waiver_key(self) -> str:
        return f"{self.checker} {self.path} {self.scope} {self.code}"

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.checker}/{self.code}] "
            f"{self.scope}: {self.message}"
        )


def repo_root() -> Path:
    """The tree this installed package belongs to
    (``src/repro/analysis/common.py`` -> three parents up)."""
    return Path(__file__).resolve().parents[3]


def rel(path: Path, root: Path) -> str:
    return path.resolve().relative_to(root.resolve()).as_posix()


def iter_python_files(root: Path, rel_dirs: list[str]) -> list[Path]:
    """All ``.py`` files under ``root/<d>`` for each relative dir (a
    single file path is accepted too), sorted for stable output."""
    out: list[Path] = []
    for d in rel_dirs:
        p = root / d
        if p.is_file():
            out.append(p)
        elif p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
    return out


def parse_file(path: Path) -> ast.AST | None:
    """AST of ``path``; None (skip, not crash) on syntax errors — the
    tier-1 suite, not the linter, owns 'does it parse'."""
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return None


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks the dotted def/class scope of each node.

    Subclasses read ``self.scope`` inside ``visit_*`` methods.
    """

    def __init__(self) -> None:
        self._stack: list[str] = []

    @property
    def scope(self) -> str:
        return ".".join(self._stack) if self._stack else "<module>"

    def _scoped(self, node) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _scoped
    visit_AsyncFunctionDef = _scoped
    visit_ClassDef = _scoped


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# Waivers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Waiver:
    key: str      # "checker path scope code"
    reason: str
    line: int     # in the waiver file


def load_waivers(path: Path) -> tuple[list[Waiver], list[Finding]]:
    """Parse the waiver file; malformed lines come back as findings."""
    waivers: list[Waiver] = []
    findings: list[Finding] = []
    if not path.is_file():
        return waivers, findings
    for lineno, raw in enumerate(path.read_text().splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        head, sep, reason = line.partition("--")
        fields = head.split()
        if len(fields) != 4 or not sep or not reason.strip():
            findings.append(Finding(
                checker="waivers", path=path.name, line=lineno,
                scope="<module>", code="malformed-waiver",
                message=(
                    f"cannot parse {raw!r}: expected 'checker path "
                    "scope code -- reason' (the reason is mandatory — "
                    "every exemption is a reviewed decision)"
                ),
            ))
            continue
        waivers.append(
            Waiver(key=" ".join(fields), reason=reason.strip(), line=lineno)
        )
    return waivers, findings


def apply_waivers(
    findings: list[Finding], waivers: list[Waiver], waiver_path: Path
) -> tuple[list[Finding], list[Finding]]:
    """Split into (unwaived, waived); unused waivers become new
    unwaived findings so stale exemptions cannot linger."""
    by_key = {w.key: w for w in waivers}
    used: set[str] = set()
    unwaived: list[Finding] = []
    waived: list[Finding] = []
    for f in findings:
        if f.waiver_key in by_key:
            used.add(f.waiver_key)
            waived.append(f)
        else:
            unwaived.append(f)
    for w in waivers:
        if w.key not in used:
            unwaived.append(Finding(
                checker="waivers", path=waiver_path.name, line=w.line,
                scope="<module>", code="unused-waiver",
                message=(
                    f"waiver {w.key!r} matched no finding — the "
                    "violation was fixed or moved; delete the entry "
                    "(waivers must list live exemptions only)"
                ),
            ))
    return unwaived, waived
