"""Invariant lint + contract layer.

The repo's headline results rest on invariants that used to hold only
by convention: bitwise reference/fast-path parity, seeded-only
randomness, float64 on every pricing path, and well-formed CSR
incidence payloads. This package makes them machine-checked:

* **Static analysis** (``python -m repro.analysis --all``) — four
  AST-based checkers run as a CI gate on every push:

  - ``determinism``  — unseeded RNG, time/environment reads, and
    set-iteration-order hazards in ``net/``, ``core/``, ``runtime/``;
  - ``dtypes``       — narrow float/int dtypes on pricing paths
    (everything priced must be float64, every index array int64);
  - ``parity``       — every ``*_reference`` implementation must be
    registered in ``parity_manifest.txt`` with a fast path and a test
    that exercises both, so optimization PRs cannot silently drop
    reference-parity coverage;
  - ``contracts``    — the CSR structures (``BranchIncidence``,
    ``CategoryIncidence``, ``_FlatCategories``) must keep their
    runtime-validation hook wired in ``__post_init__``.

  Exemptions live in ``waivers.txt``, one reviewed reason per site
  (see CONTRIBUTING.md); unused or malformed waivers fail the run.

* **Runtime contracts** (``repro.analysis.contracts``) — declarative
  invariants (ptr monotone, indices in-bounds, exact dtypes, array
  lengths consistent) validated at construction of the three CSR
  structures when ``REPRO_VALIDATE=1``. Off by default (zero overhead
  beyond one env lookup); the nightly tier-1 run enables it.

This ``__init__`` stays light on purpose: ``net``/``core`` import
``repro.analysis.contracts`` at module load, so nothing here may pull
in the AST machinery or (worse) anything from ``repro.net``.
"""

from repro.analysis.contracts import (
    ContractViolation,
    maybe_validate,
    validation_enabled,
)

__all__ = [
    "ContractViolation",
    "maybe_validate",
    "validation_enabled",
]
