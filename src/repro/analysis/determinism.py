"""Determinism lint.

Every priced makespan, routed tree, and sampled realization in this
repo is contractually reproducible from explicit seeds (same seed ⇒
bitwise-identical output — the property the parity tests lean on).
This checker flags the statically detectable ways that contract
breaks inside ``net/``, ``core/``, and ``runtime/``:

``global-numpy-rng``     ``np.random.<fn>()`` — the legacy global
                         generator; use ``np.random.default_rng(seed)``.
``unseeded-default-rng`` ``np.random.default_rng()`` with no arguments
                         (OS-entropy seeded) — thread the caller's seed.
``stdlib-random``        module-level ``random.<fn>()`` — global,
                         hash-seeded state.
``unseeded-random-ctor`` ``random.Random()`` / ``np.random.Generator``
                         family constructed without a seed.
``impure-prng-seed``     a PRNG seed built from a time/os/uuid call
                         (``jax.random.key(time.time_ns())`` and kin).
``fresh-prng-key``       ``jax.random.PRNGKey``/``key`` minted from
                         literals only (``PRNGKey(0)``-style) inside
                         library code — keys must be threaded from a
                         parameter or ``split``; intentional sites get
                         waivers.
``time-read``            wall/monotonic clock reads — fine for
                         telemetry fields, poison for anything that
                         feeds results; telemetry sites get waivers.
``env-read``             ``os.environ``/``os.getenv`` — behavior must
                         come from arguments, not ambient environment.
``os-entropy``           ``os.urandom``/``uuid.uuid4`` and friends.
``set-iteration-order``  iterating a set/frozenset expression directly
                         (``for x in set(...)``, ``list({...})``) —
                         hash-order-dependent; sort first. ``sorted()``
                         over a set is explicitly fine.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import (
    Finding,
    ScopedVisitor,
    dotted_name,
    iter_python_files,
    parse_file,
    rel,
)

CHECKER = "determinism"

SCAN_DIRS = ["src/repro/net", "src/repro/core", "src/repro/runtime"]

_STDLIB_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "seed", "getrandbits", "randbytes",
}
_TIME_READS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today", "datetime.now", "datetime.utcnow",
    "date.today",
}
_OS_ENTROPY = {"os.urandom", "uuid.uuid1", "uuid.uuid4", "secrets.token_bytes",
               "secrets.token_hex", "secrets.randbelow"}
_ENV_READS = {"os.getenv", "os.environb"}
_PRNG_CTORS = {
    # dotted-suffix -> needs an explicit seed argument
    "random.default_rng", "random.Random", "random.SeedSequence",
    "jax.random.PRNGKey", "jax.random.key",
}
_JAX_KEY_CTORS = {
    "jax.random.PRNGKey", "jax.random.key",
    "random.PRNGKey", "random.key", "PRNGKey",
}


def _literal_only(node: ast.AST) -> bool:
    """No Name/Attribute anywhere — the expression cannot be threading
    a caller's seed (``PRNGKey(0)``, ``key(7919 * 3)``, ...)."""
    return not any(
        isinstance(sub, (ast.Name, ast.Attribute))
        for sub in ast.walk(node)
    )


def _is_np_random(chain: str) -> bool:
    """``np.random.X`` / ``numpy.random.X`` (module attribute access,
    not a method on some generator object)."""
    parts = chain.split(".")
    return len(parts) == 3 and parts[0] in ("np", "numpy") and \
        parts[1] == "random"


def _contains_impure_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = dotted_name(sub.func)
            if chain and (chain in _TIME_READS or chain in _OS_ENTROPY
                          or chain in _ENV_READS
                          or chain.startswith("os.environ")):
                return True
    return False


def _is_set_expr(node: ast.AST) -> bool:
    """An expression whose value is a set with hash-dependent order."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


class _Visitor(ScopedVisitor):
    def __init__(self, path: str) -> None:
        super().__init__()
        self.path = path
        self.findings: list[Finding] = []

    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(Finding(
            checker=CHECKER, path=self.path,
            line=getattr(node, "lineno", 0), scope=self.scope,
            code=code, message=message,
        ))

    def visit_Call(self, node: ast.Call) -> None:
        chain = dotted_name(node.func)
        if chain:
            self._check_call(node, chain)
        # list(set(...)) / tuple(set(...)) materialize hash order;
        # sorted(set(...)) canonicalizes it and is explicitly fine.
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in ("list", "tuple")
            and len(node.args) == 1
            and _is_set_expr(node.args[0])
        ):
            self._emit(
                node, "set-iteration-order",
                f"{node.func.id}() over a set expression materializes "
                "hash-dependent order — use sorted(...) instead",
            )
        self.generic_visit(node)

    def _check_call(self, node: ast.Call, chain: str) -> None:
        leaf = chain.rsplit(".", 1)[-1]
        if _is_np_random(chain):
            if leaf == "default_rng":
                if not node.args and not node.keywords:
                    self._emit(
                        node, "unseeded-default-rng",
                        "np.random.default_rng() with no seed draws OS "
                        "entropy — thread an explicit seed through",
                    )
                elif any(_contains_impure_call(a) for a in node.args):
                    self._emit(
                        node, "impure-prng-seed",
                        "np.random.default_rng(<time/os read>) — seeds "
                        "must be explicit values, not ambient state",
                    )
            elif leaf in ("Generator", "SeedSequence", "Philox", "PCG64"):
                if not node.args and not node.keywords:
                    self._emit(
                        node, "unseeded-random-ctor",
                        f"np.random.{leaf}() without a seed draws OS "
                        "entropy — pass the caller's seed",
                    )
            else:
                self._emit(
                    node, "global-numpy-rng",
                    f"np.random.{leaf}() uses the process-global legacy "
                    "generator (shared, import-order-dependent state) — "
                    "use np.random.default_rng(seed)",
                )
        elif chain.startswith("random.") and leaf in _STDLIB_RANDOM_FNS \
                and chain.count(".") == 1:
            self._emit(
                node, "stdlib-random",
                f"random.{leaf}() uses the global stdlib generator — "
                "use a seeded np.random.default_rng(seed)",
            )
        elif chain == "random.Random" and not node.args \
                and not node.keywords:
            self._emit(
                node, "unseeded-random-ctor",
                "random.Random() without a seed — pass the caller's seed",
            )
        elif chain in _TIME_READS:
            self._emit(
                node, "time-read",
                f"{chain}() reads the clock — results must not depend "
                "on wall time (telemetry-only sites need a waiver "
                "naming the field they feed)",
            )
        elif chain in _OS_ENTROPY:
            self._emit(
                node, "os-entropy",
                f"{chain}() draws OS entropy — derive randomness from "
                "an explicit seed",
            )
        elif chain in _ENV_READS:
            self._emit(
                node, "env-read",
                f"{chain}() reads the environment — behavior must come "
                "from arguments, not ambient state",
            )
        if any(chain.endswith(suffix) for suffix in _PRNG_CTORS) and (
            any(_contains_impure_call(a) for a in node.args)
            or any(_contains_impure_call(kw.value) for kw in node.keywords)
        ):
            self._emit(
                node, "impure-prng-seed",
                f"{chain}(...) seeded from a time/os/uuid read — seeds "
                "must be explicit, reproducible values",
            )
        if chain in _JAX_KEY_CTORS and node.args \
                and not node.keywords \
                and all(_literal_only(a) for a in node.args):
            self._emit(
                node, "fresh-prng-key",
                f"{chain}(<literal>) mints a fresh key inside library "
                "code — jax PRNG keys must be threaded from a caller's "
                "key/seed parameter (or jax.random.split of one) so "
                "two call sites can never silently share a stream; "
                "waive intentional fixed-key sites with a reason",
            )

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if dotted_name(node) == "os.environ":
            self._emit(
                node, "env-read",
                "os.environ read — behavior must come from arguments, "
                "not ambient environment",
            )
        self.generic_visit(node)

    def _check_iter(self, iter_node: ast.AST) -> None:
        if _is_set_expr(iter_node):
            self._emit(
                iter_node, "set-iteration-order",
                "iterating a set expression directly — iteration order "
                "is hash-dependent; sort (or otherwise canonicalize) "
                "before iterating",
            )

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp


def check(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_python_files(root, SCAN_DIRS):
        tree = parse_file(path)
        if tree is None:
            continue
        visitor = _Visitor(rel(path, root))
        visitor.visit(tree)
        findings.extend(visitor.findings)
    return findings
