"""Static contract-wiring checker.

The runtime contracts (``repro.analysis.contracts``) only protect the
CSR structures if the structures actually call into them: each class
registered in ``contracts.VALIDATORS`` must define a ``__post_init__``
whose body calls ``maybe_validate(self)``. This checker verifies that
wiring statically, so a refactor that rebuilds one of the dataclasses
(or adds a new constructor path via ``dataclasses.replace`` — which
re-runs ``__post_init__`` — but drops the hook) fails CI rather than
silently shipping an unvalidated structure.

``missing-contract-hook``  a registered class is defined without the
                           ``__post_init__`` → ``maybe_validate`` hook;
``contract-class-missing`` a registered class is not defined anywhere
                           under ``src/repro`` — renaming a structure
                           must carry its contract along.
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import Finding, dotted_name, parse_file, rel
from repro.analysis.contracts import VALIDATORS

CHECKER = "contracts"

SRC_SCAN_DIR = "src/repro"
HOOK_NAME = "maybe_validate"


def _has_hook(cls: ast.ClassDef) -> bool:
    for item in cls.body:
        if isinstance(item, ast.FunctionDef) and \
                item.name == "__post_init__":
            for node in ast.walk(item):
                if isinstance(node, ast.Call):
                    chain = dotted_name(node.func) or ""
                    if chain.rsplit(".", 1)[-1] == HOOK_NAME:
                        return True
    return False


def check(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    found: set[str] = set()
    src = root / SRC_SCAN_DIR
    files = sorted(src.rglob("*.py")) if src.is_dir() else []
    for path in files:
        if "analysis" in path.parts:
            continue  # the contract layer itself defines no structures
        tree = parse_file(path)
        if tree is None:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name in VALIDATORS:
                found.add(node.name)
                if not _has_hook(node):
                    findings.append(Finding(
                        checker=CHECKER, path=rel(path, root),
                        line=node.lineno, scope=node.name,
                        code="missing-contract-hook",
                        message=(
                            f"{node.name} has a registered runtime "
                            "contract but no __post_init__ calling "
                            f"{HOOK_NAME}(self) — constructions would "
                            "skip validation even under REPRO_VALIDATE=1"
                        ),
                    ))
    for name in sorted(set(VALIDATORS) - found):
        findings.append(Finding(
            checker=CHECKER, path=SRC_SCAN_DIR, line=0,
            scope=name, code="contract-class-missing",
            message=(
                f"no class named {name} found under {SRC_SCAN_DIR} but "
                "contracts.VALIDATORS registers one — if the structure "
                "was renamed, move its validator (and hook) with it"
            ),
        ))
    return findings
