"""Docs gate — the module map must stay complete.

``docs/architecture.md`` is the repo's entry point: it lists every
public module of the four library packages with a one-line purpose.
Docs that describe a subset of the tree rot silently — a new module
that nobody linked is a module nobody finds. This checker makes the
listing a lint invariant (CONTRIBUTING.md: "docs are gated"):

* ``missing-architecture-doc`` — the tree has library packages but no
  ``docs/architecture.md`` at all;
* ``undocumented-module`` — a public module (any ``*.py`` whose name
  does not start with ``_``) under a checked package is never
  mentioned by filename in the doc.

The check is textual on purpose: mentioning ``foo.py`` anywhere in the
doc satisfies it, so prose, tables, and code spans all count. Waivers
(``waivers.txt``) cover intentionally undocumented modules.
"""

from __future__ import annotations

from pathlib import Path

from repro.analysis.common import Finding, iter_python_files, rel

DOC_REL_PATH = "docs/architecture.md"

CHECK_DIRS = [
    "src/repro/net",
    "src/repro/core",
    "src/repro/runtime",
    "src/repro/analysis",
]


def public_modules(root: Path) -> list[Path]:
    """Library modules the doc must list: every ``*.py`` under the
    checked packages except private/dunder ones (``_*``)."""
    return [
        p for p in iter_python_files(root, CHECK_DIRS)
        if not p.name.startswith("_")
    ]


def check(root: Path) -> list[Finding]:
    modules = public_modules(root)
    if not modules:
        return []
    doc = root / DOC_REL_PATH
    if not doc.is_file():
        return [Finding(
            checker="docs", path=DOC_REL_PATH, line=1, scope="<module>",
            code="missing-architecture-doc",
            message=(
                f"{DOC_REL_PATH} not found but the tree has "
                f"{len(modules)} public library module(s) — the module "
                "map is the gated entry point (see CONTRIBUTING.md)"
            ),
        )]
    text = doc.read_text()
    findings: list[Finding] = []
    for mod in modules:
        if mod.name not in text:
            findings.append(Finding(
                checker="docs", path=rel(mod, root), line=1,
                scope="<module>", code="undocumented-module",
                message=(
                    f"{mod.name} is not mentioned in {DOC_REL_PATH}; "
                    "add it to the module map (one line: what it is) "
                    "or waive it with a reason"
                ),
            ))
    return findings
