"""Trace-lint target registry — every JAX entry point of this repo.

``repro.analysis.tracelint`` traces each ``TraceTarget`` registered in
``TARGETS`` on a small concrete instance and certifies the IR-level
contracts (one launch, f64 everywhere, no host callbacks, eqn budget —
see ``tracelint_manifest.txt``). All three pricing entries funnel into
the single jit boundary ``net.jax_engine._run_batch``; each target
pins the argument shapes its *host path* actually builds, produced by
the same code the entry runs (``device_args`` /
``batch_cancel_times`` / ``_device_incidence_for``), so the certified
jaxpr is the one production traces:

``rollout-batch``    ``simulate_rollout_batch`` — a Monte-Carlo
                     ``RealizationBatch`` over Markov-modulated links
                     (two rollout widths, so the budget covers the
                     batch axis);
``phased-scan``      the phased ``lax.scan`` lowering ``simulate_jax``
                     / ``simulate_phased`` drive — deterministic
                     multi-phase capacity grid with extra boundaries;
``stochastic-price`` ``StochasticTau.price``'s batch path — churned
                     realizations through the designer's
                     ``DeviceIncidence`` cache helper.

Keep cases tiny (a 2-agent line): ``make_jaxpr`` is abstract, so shape
coverage, not scale, is what certifies the contract. When you add a
jitted entry point, register it here and budget it in the manifest —
an unregistered entry is exactly the hole this lint exists to close.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.tracelint import TraceCase, TraceTarget

_CACHE: dict = {}


def _line_instance():
    """2-agent line instance: routed solution, overlay, incidence,
    device incidence — built once, shared by every case."""
    got = _CACHE.get("line")
    if got is None:
        from repro.net import (
            build_overlay,
            compute_categories,
            demands_from_links,
            line_underlay,
            route_direct,
        )
        from repro.net.jax_engine import device_incidence
        from repro.net.simulator import compile_incidence

        kappa = 1e6
        u = line_underlay(2, capacity=125_000.0)
        ov = build_overlay(u, [0, 1])
        cats = compute_categories(ov)
        demands = demands_from_links([(0, 1)], kappa, 2)
        sol = route_direct(demands, cats, kappa)
        inc = compile_incidence(sol, ov)
        dev = device_incidence(
            inc, np.array([d.size for d in sol.demands], dtype=np.float64)
        )
        got = _CACHE["line"] = (sol, ov, inc, dev)
    return got


def _stochastic(ov, churn: bool):
    from repro.net import MarkovLinkModel, StochasticScenario

    tau = 8.0  # ~kappa/capacity on the line instance
    edges = tuple(ov.underlay.graph.edges)[:4] or ((0, 1),)
    return StochasticScenario(
        links=(MarkovLinkModel(
            edges=edges, scales=(1.0, 0.1),
            transition=((0.5, 0.5), (0.25, 0.75)),
        ),),
        step=0.4 * tau, horizon=4 * tau,
        churn_agents=(0,) if churn else (),
        churn_hazard=0.15 if churn else 0.0,
    )


def rollout_batch_args(rollouts: int, seed: int = 0, churn: bool = False):
    """The ``_run_batch`` argument tuple ``simulate_rollout_batch``
    launches for a seeded ``rollouts``-wide batch on the line instance
    — also the grid the retrace-count harness walks."""
    from repro.net.jax_engine import batch_cancel_times, device_args

    sol, ov, inc, dev = _line_instance()
    batch = _stochastic(ov, churn).realization_batch(seed, rollouts, inc)
    flow_source = np.array(
        [d.source for d in sol.demands], dtype=np.int64
    )
    cancel = batch_cancel_times(inc, flow_source, batch)
    return device_args(
        dev, batch.starts, batch.capacity, cancel, max_events=10_000
    )


def _run_batch_fn():
    from repro.net import jax_engine

    return jax_engine._run_batch


def _make_rollout_case(rollouts: int):
    def make():
        return _run_batch_fn(), rollout_batch_args(rollouts)

    return make


def _make_phased_case():
    """The phased lowering: a deterministic scenario with capacity
    phases plus caller boundaries — ``simulate_jax``'s
    ``densify_realizations`` path (P > 1, R = 1)."""

    def make():
        from repro.net import CapacityPhase, Scenario
        from repro.net.jax_engine import (
            batch_cancel_times,
            device_args,
        )
        from repro.net.stochastic import densify_realizations

        sol, ov, inc, dev = _line_instance()
        edge = tuple(ov.underlay.graph.edges)[0]
        scenario = Scenario(capacity_phases=(
            CapacityPhase(start=2.0, scale={edge: 0.5}),
            CapacityPhase(start=5.0, scale=0.8),
        ))
        batch = densify_realizations(
            (scenario,), inc, extra_boundaries=(1.0, 3.0)
        )
        flow_source = np.array(
            [d.source for d in sol.demands], dtype=np.int64
        )
        cancel = batch_cancel_times(inc, flow_source, batch)
        return _run_batch_fn(), device_args(
            dev, batch.starts, batch.capacity, cancel, max_events=10_000
        )

    return make


def _make_price_case():
    """``StochasticTau.price``'s batch path: the designer's
    ``DeviceIncidence`` cache helper + churned realizations."""

    def make():
        from repro.core.priced_training import _device_incidence_for
        from repro.net.jax_engine import (
            batch_cancel_times,
            device_args,
        )

        sol, ov, inc, dev_unused = _line_instance()
        cache: dict = {}
        dev = _device_incidence_for(
            sol, ov, [(0, 1)], routing_cache=cache
        )
        batch = _stochastic(ov, churn=True).realization_batch(
            0, 4, dev.source
        )
        flow_source = np.array(
            [d.source for d in sol.demands], dtype=np.int64
        )
        cancel = batch_cancel_times(dev.source, flow_source, batch)
        return _run_batch_fn(), device_args(
            dev, batch.starts, batch.capacity, cancel, max_events=10_000
        )

    return make


TARGETS: tuple[TraceTarget, ...] = (
    TraceTarget(
        name="rollout-batch",
        path="src/repro/net/jax_engine.py",
        scope="simulate_rollout_batch",
        cases=(
            TraceCase("line2-r4", _make_rollout_case(4)),
            TraceCase("line2-r8", _make_rollout_case(8)),
        ),
    ),
    TraceTarget(
        name="phased-scan",
        path="src/repro/net/jax_engine.py",
        scope="_simulate_batch",
        cases=(TraceCase("line2-phased", _make_phased_case()),),
    ),
    TraceTarget(
        name="stochastic-price",
        path="src/repro/core/priced_training.py",
        scope="StochasticTau.price",
        cases=(TraceCase("line2-churn-r4", _make_price_case()),),
    ),
)
