"""Dtype-discipline lint for pricing paths.

PRs 1–5 established (and the parity tests depend on) a hard rule on
every path that prices a design — simulator, router, categories,
designer, FMMD/mixing/SCA: **all priced quantities are float64, all
index arrays are int64**. A single float32 literal perturbs makespans
enough to break bitwise reference parity; an int32 index array
overflows silently at the 5000-agent scale ROADMAP item 5 targets
(5000² dense link ids exceed int32).

Scanned: ``net/`` plus the pricing modules of ``core/`` (the learning
half — gossip/dpsgd/compression — legitimately trades in float32
wire formats and is out of scope).

``narrow-float-dtype``  np/jnp float32/float16/half/single references
``narrow-int-dtype``    np/jnp int32/int16/int8/uint* references
``narrow-dtype-string`` "float32"/"single"/"int32"/"f4"/"i4"… string
                        dtype literals in array constructors/casts,
                        including the method spellings
                        ``.astype("float32")`` / ``.view("float32")``
                        (``.astype(np.float32)`` is caught by the
                        attribute rules at the dtype reference)
``implicit-jnp-dtype``  dtype-less ``jnp.zeros``/``ones``/``empty``/
                        ``full``/``arange`` — numpy defaults to
                        float64 but jax defaults to float32 (and
                        int32 for ``arange``) unless x64 is on, so an
                        implicit jnp dtype silently narrows whenever
                        the x64 guard is bypassed
"""

from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.common import (
    Finding,
    ScopedVisitor,
    dotted_name,
    iter_python_files,
    parse_file,
    rel,
)

CHECKER = "dtypes"

# Pricing paths: the whole network stack plus core's design/pricing
# modules. core/gossip.py, core/dpsgd.py and runtime/compression.py
# are the *learning* half (float32 wire formats are intentional there).
SCAN_DIRS = [
    "src/repro/net",
    "src/repro/core/designer.py",
    "src/repro/core/fmmd.py",
    "src/repro/core/mixing.py",
    "src/repro/core/sca.py",
    "src/repro/core/topology_baselines.py",
    "src/repro/core/weight_opt.py",
]

_NARROW_FLOAT = {"float32", "float16", "half", "single", "longdouble"}
_NARROW_INT = {
    "int32", "int16", "int8", "uint8", "uint16", "uint32", "uint64",
    "short", "intc",
}
_NARROW_STRINGS = {
    "float32", "float16", "half", "single", "f4", "f2", "<f4", "<f2",
    "int32", "int16", "int8", "i4", "i2", "i1",
    "<i4", "<i2", "uint8", "uint16", "uint32", "u4",
}
# ``view``/``astype`` are *method* spellings of a cast — narrowing via
# ``x.view("float32")`` is the same violation as ``np.float32(x)``.
_ARRAY_BUILDERS = {
    "array", "asarray", "zeros", "ones", "empty", "full", "arange",
    "astype", "view", "dtype", "frombuffer", "fromiter",
}
# jnp builders whose *implicit* dtype is jax's (float32/int32 without
# x64) rather than numpy's float64 — these must spell dtype= on
# pricing paths. Maps builder -> number of positional args after which
# a positional dtype appears (arange's positionals are all numeric, so
# only a dtype= keyword counts there).
_JNP_DEFAULT_BUILDERS = {
    "zeros": 1, "ones": 1, "empty": 1, "full": 2, "arange": None,
}


def _numeric_module(chain: str) -> bool:
    head = chain.split(".", 1)[0]
    return head in ("np", "numpy", "jnp", "jax")


class _Visitor(ScopedVisitor):
    def __init__(self, path: str) -> None:
        super().__init__()
        self.path = path
        self.findings: list[Finding] = []

    def _emit(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(Finding(
            checker=CHECKER, path=self.path,
            line=getattr(node, "lineno", 0), scope=self.scope,
            code=code, message=message,
        ))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        chain = dotted_name(node)
        if chain and _numeric_module(chain):
            leaf = chain.rsplit(".", 1)[-1]
            if leaf in _NARROW_FLOAT:
                self._emit(
                    node, "narrow-float-dtype",
                    f"{chain} on a pricing path — every priced quantity "
                    "is float64 (bitwise reference parity depends on it)",
                )
            elif leaf in _NARROW_INT:
                self._emit(
                    node, "narrow-int-dtype",
                    f"{chain} on a pricing path — index arrays are "
                    "int64 (int32 dense link ids overflow at the "
                    "5000-agent scale)",
                )
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        leaf = None
        if isinstance(func, ast.Attribute):
            leaf = func.attr
        elif isinstance(func, ast.Name):
            leaf = func.id
        if leaf in _ARRAY_BUILDERS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if (
                    isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)
                    and arg.value in _NARROW_STRINGS
                ):
                    self._emit(
                        arg, "narrow-dtype-string",
                        f"dtype string {arg.value!r} on a pricing path — "
                        "use np.float64 / np.int64 explicitly",
                    )
        chain = dotted_name(func) if isinstance(func, ast.Attribute) \
            else None
        if chain and chain.split(".", 1)[0] in ("jnp", "jax"):
            builder = chain.rsplit(".", 1)[-1]
            dtype_pos = _JNP_DEFAULT_BUILDERS.get(builder)
            if builder in _JNP_DEFAULT_BUILDERS:
                has_kw = any(kw.arg == "dtype" for kw in node.keywords)
                has_pos = (
                    dtype_pos is not None and len(node.args) > dtype_pos
                )
                if not has_kw and not has_pos:
                    self._emit(
                        node, "implicit-jnp-dtype",
                        f"{chain}(...) without dtype= on a pricing path "
                        "— jax defaults to float32/int32 when x64 is "
                        "off; spell dtype=jnp.float64 / jnp.int64 so "
                        "narrowing cannot depend on the x64 flag",
                    )
        self.generic_visit(node)


def check(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_python_files(root, SCAN_DIRS):
        tree = parse_file(path)
        if tree is None:
            continue
        visitor = _Visitor(rel(path, root))
        visitor.visit(tree)
        findings.extend(visitor.findings)
    return findings
