"""Trace lint — jaxpr-level proof of the pricing path's contracts.

The JAX rollout engine's load-bearing properties — "one XLA launch per
pricing call", float64 on every priced quantity, no silent retraces
across the benchmark grid — were docstring claims checked indirectly
by runtime parity tests. This checker makes them lint invariants by
*tracing* every registered entry point (``tracelint_targets.py``, a
per-tree registry of ``TraceTarget``\\ s with concrete small-instance
argument builders) and walking the resulting ``ClosedJaxpr``:

IR-level sub-checks (need jax; degrade to a named skip without it):

``narrow-float-in-trace``   a primitive on the pricing path produces a
                            float16/bfloat16/float32/complex64 value —
                            silent promotion the AST ``dtypes`` checker
                            structurally cannot see (e.g. introduced
                            inside a ``lax.scan`` carry).
``narrow-float-literal``    a literal or captured constant enters the
                            trace at a narrow float dtype.
``host-callback``           a ``pure_callback``/``io_callback``/
                            ``debug_callback`` primitive anywhere in
                            the trace — a host round-trip inside the
                            "one launch".
``multiple-launches``       the entry does not lower to exactly one
                            top-level jit computation (e.g. the kernel
                            was split into two jitted calls, or traced
                            un-jitted).
``eqn-budget-exceeded``     the recursive equation count outgrew the
                            per-target budget in
                            ``tracelint_manifest.txt`` — the tripwire
                            for "someone added a host round-trip or an
                            accidental unrolling".
``missing-eqn-budget``      a registered target has no manifest entry.
``stale-eqn-budget-entry``  a manifest entry names no registered
                            target.
``malformed-eqn-budget``    a manifest line that does not parse.
``trace-error``             a registered case failed to build or
                            trace (the registry itself is broken).
``targets-import-error``    the registry module failed to load.

AST sub-pass (always runs, jax or not) over the retrace-critical
modules (``RETRACE_SCAN_DIRS``): starting from jit-decorated functions
(and ``jax.jit(...)`` aliases), the transitive module-local call
closure is *device scope* — code that runs under trace. Within it:

``traced-python-branch``    ``if``/``while``/ternary/``assert`` whose
                            test reads a traced value — concretizes
                            the tracer (TracerBoolConversionError at
                            best, shape-dependent retraces at worst).
                            Static reads (``.shape``/``.ndim``/
                            ``.size``/``.dtype``/``.itemsize``,
                            ``len()``/``isinstance()``) are exempt.
``closure-captured-array``  a module-level numpy array read inside a
                            device scope — baked into the compiled
                            program as a constant; rebinding it never
                            retraces, so results silently go stale.
``unhashable-static-arg``   a call site passes a list/dict/set display
                            or an ``np.array(...)`` expression in a
                            ``static_argnums``/``static_argnames``
                            position — unhashable statics raise, and
                            array-valued statics retrace per call.

A trace-counting harness (``count_compilations``) backs the
"exactly one compilation per shape signature" assertion in
``tests/test_tracelint.py``, and ``collect_metrics`` statically
computes the water-filling round's carry/operand/round-pair bytes from
the jaxpr — the Pallas-readiness numbers ROADMAP open item 1 tracks
through ``benchmarks/analysis_bench.py`` + ``trend.py``.
"""

from __future__ import annotations

import ast
import dataclasses
import importlib.util
from pathlib import Path
from typing import Any, Callable, Iterator, Sequence

from repro.analysis.common import (
    Finding,
    dotted_name,
    iter_python_files,
    parse_file,
    rel,
    repo_root,
)

CHECKER = "tracelint"

TARGETS_REL_PATH = "src/repro/analysis/tracelint_targets.py"
MANIFEST_REL_PATH = "src/repro/analysis/tracelint_manifest.txt"
MANIFEST_FILENAME = "tracelint_manifest.txt"

# The retrace-critical surface: the device engine itself plus the
# pricing loop that drives it. core/dpsgd.py and core/weight_opt.py
# jit learning-side math with host-scalar closures by design and are
# covered by their own parity tests, not this pass.
RETRACE_SCAN_DIRS = [
    "src/repro/net",
    "src/repro/core/priced_training.py",
]

# Reading these off a traced array is static (shape metadata, not the
# tracer's value) — branching on them is how bucketed programs are
# *supposed* to specialize.
_STATIC_ATTRS = {
    "shape", "ndim", "size", "dtype", "itemsize", "weak_type", "sharding",
}
_STATIC_WRAPPERS = {"len", "isinstance", "type", "hasattr", "range"}

_NARROW_FLOAT_DTYPES = {"float16", "bfloat16", "float32", "complex64"}
_CALLBACK_PRIMITIVES = {"pure_callback", "io_callback", "debug_callback"}
_CALL_PRIMITIVES = {"pjit", "jit", "xla_call", "closed_call", "core_call"}

# Notes the CLI prints after a run — a named skip is visible, a silent
# one is a hole in the gate. Reset on every check().
LAST_SKIP_NOTES: list[str] = []


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TraceCase:
    """One concrete shape point of a target: ``make()`` returns the
    ``(fn, args)`` pair to hand ``jax.make_jaxpr`` — ``fn`` must be the
    jit-wrapped entry exactly as the host path launches it."""

    label: str
    make: Callable[[], tuple[Callable, tuple]]


@dataclasses.dataclass(frozen=True)
class TraceTarget:
    """A registered JAX entry point.

    ``name`` keys the eqn-budget manifest; ``path``/``scope`` anchor
    findings (and waiver keys) at the entry the target certifies.
    """

    name: str
    path: str
    scope: str
    cases: tuple[TraceCase, ...]


_TARGETS_CACHE: dict[Path, Any] = {}


def _load_targets(root: Path) -> tuple[tuple[TraceTarget, ...], list[Finding]]:
    path = (root / TARGETS_REL_PATH).resolve()
    if not path.is_file():
        return (), []
    mod = _TARGETS_CACHE.get(path)
    if mod is None:
        spec = importlib.util.spec_from_file_location(
            f"_tracelint_targets_{len(_TARGETS_CACHE)}", path
        )
        mod = importlib.util.module_from_spec(spec)
        try:
            spec.loader.exec_module(mod)
        except Exception as exc:  # registry code is arbitrary
            return (), [Finding(
                checker=CHECKER, path=TARGETS_REL_PATH, line=1,
                scope="<module>", code="targets-import-error",
                message=(
                    f"target registry failed to import: {exc!r} — the "
                    "jaxpr pass has nothing to certify until it loads"
                ),
            )]
        _TARGETS_CACHE[path] = mod
    targets = getattr(mod, "TARGETS", None)
    if not targets:
        return (), [Finding(
            checker=CHECKER, path=TARGETS_REL_PATH, line=1,
            scope="<module>", code="targets-import-error",
            message=(
                "target registry defines no TARGETS tuple — register "
                "every JAX entry point (see TraceTarget)"
            ),
        )]
    return tuple(targets), []


# ---------------------------------------------------------------------------
# Eqn-budget manifest
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BudgetEntry:
    name: str
    max_eqns: int
    line: int


def load_manifest(path: Path) -> tuple[dict[str, BudgetEntry], list[Finding]]:
    """``<target-name> <max-eqns>`` per line; ``#`` comments."""
    budgets: dict[str, BudgetEntry] = {}
    findings: list[Finding] = []
    if not path.is_file():
        return budgets, findings
    for lineno, raw in enumerate(path.read_text().splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        fields = line.split()
        if len(fields) != 2 or not fields[1].isdigit() \
                or fields[0] in budgets:
            why = "duplicate target" if len(fields) == 2 \
                and fields[0] in budgets else "expected '<target> <max-eqns>'"
            findings.append(Finding(
                checker=CHECKER, path=path.name, line=lineno,
                scope="<module>", code="malformed-eqn-budget",
                message=f"cannot use manifest line {raw!r}: {why}",
            ))
            continue
        budgets[fields[0]] = BudgetEntry(fields[0], int(fields[1]), lineno)
    return budgets, findings


# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------


def _as_jaxprs(value: Any) -> Iterator[Any]:
    """Sub-jaxprs inside an eqn param value, duck-typed so no jax
    import is needed here: ClosedJaxpr carries ``.jaxpr``/``.consts``,
    a raw Jaxpr carries ``.eqns``/``.invars``, branch params are
    tuples of either."""
    if hasattr(value, "jaxpr") and hasattr(value, "consts"):
        yield value.jaxpr
    elif hasattr(value, "eqns") and hasattr(value, "invars"):
        yield value
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from _as_jaxprs(item)


def iter_jaxprs(jaxpr: Any, depth: int = 0) -> Iterator[tuple[Any, int]]:
    """(jaxpr, nesting depth) for the jaxpr and every sub-jaxpr hiding
    in its equations' params (scan/while/cond/pjit bodies)."""
    yield jaxpr, depth
    for eqn in jaxpr.eqns:
        for sub in _as_jaxprs_of_eqn(eqn):
            yield from iter_jaxprs(sub, depth + 1)


def _as_jaxprs_of_eqn(eqn: Any) -> Iterator[Any]:
    for value in eqn.params.values():
        yield from _as_jaxprs(value)


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    for sub, _depth in iter_jaxprs(jaxpr):
        yield from sub.eqns


def count_eqns(jaxpr: Any) -> int:
    return sum(1 for _ in iter_eqns(jaxpr))


def _aval_bytes(aval: Any) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for extent in shape:
        n *= int(extent)
    return n * int(getattr(dtype, "itemsize", 0) or 0)


def _dtype_name(var: Any) -> str | None:
    aval = getattr(var, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return None if dtype is None else str(dtype)


def _is_literal(var: Any) -> bool:
    return hasattr(var, "val")


# ---------------------------------------------------------------------------
# Per-target IR checks
# ---------------------------------------------------------------------------


class _Issues:
    """Deduplicated per-target findings: one finding per code, with an
    occurrence count — a narrow dtype inside a scan body would
    otherwise flood one finding per unrolled primitive."""

    def __init__(self, target: TraceTarget) -> None:
        self.target = target
        self._first: dict[str, str] = {}
        self._count: dict[str, int] = {}

    def add(self, code: str, message: str) -> None:
        self._first.setdefault(code, message)
        self._count[code] = self._count.get(code, 0) + 1

    def findings(self) -> list[Finding]:
        out = []
        for code, message in self._first.items():
            n = self._count[code]
            if n > 1:
                message = f"{message} (+{n - 1} more site(s))"
            out.append(Finding(
                checker=CHECKER, path=self.target.path, line=1,
                scope=self.target.scope, code=code, message=message,
            ))
        return out


def _check_launch(issues: _Issues, label: str, closed: Any) -> None:
    top = list(closed.jaxpr.eqns)
    prims = [str(eqn.primitive) for eqn in top]
    if len(top) != 1 or prims[0] not in _CALL_PRIMITIVES:
        issues.add(
            "multiple-launches",
            f"case {label!r} lowers to {len(top)} top-level equation(s) "
            f"{prims[:6]!r} — the registered entry must be exactly one "
            "jit-wrapped computation (one XLA launch per pricing call); "
            "re-fuse the split or jit the composite",
        )


def _check_callbacks(issues: _Issues, label: str, closed: Any) -> None:
    for eqn in iter_eqns(closed.jaxpr):
        name = str(eqn.primitive)
        if name in _CALLBACK_PRIMITIVES or "callback" in name:
            issues.add(
                "host-callback",
                f"case {label!r} traces a {name} primitive — a host "
                "round-trip inside the one-launch kernel; compute on "
                "device or hoist the host work out of the jitted scope",
            )


def _check_dtypes(issues: _Issues, label: str, closed: Any) -> None:
    for const in getattr(closed, "consts", ()):
        dtype = str(getattr(const, "dtype", ""))
        if dtype in _NARROW_FLOAT_DTYPES:
            issues.add(
                "narrow-float-literal",
                f"case {label!r} captures a {dtype} constant — every "
                "priced quantity is float64 (bitwise parity with the "
                "numpy oracle depends on it)",
            )
    for eqn in iter_eqns(closed.jaxpr):
        for var in eqn.invars:
            if _is_literal(var):
                dtype = _dtype_name(var)
                if dtype in _NARROW_FLOAT_DTYPES:
                    issues.add(
                        "narrow-float-literal",
                        f"case {label!r}: a {dtype} literal feeds "
                        f"{eqn.primitive} — spell float64 (or let the "
                        "x64-weak default promote)",
                    )
        for var in eqn.outvars:
            dtype = _dtype_name(var)
            if dtype in _NARROW_FLOAT_DTYPES:
                issues.add(
                    "narrow-float-in-trace",
                    f"case {label!r}: {eqn.primitive} produces {dtype} "
                    "on the pricing path — silent narrowing inside the "
                    "trace; every priced quantity is float64",
                )


def _trace_target(
    target: TraceTarget,
    budgets: dict[str, BudgetEntry],
    jax_mod: Any,
) -> list[Finding]:
    issues = _Issues(target)
    max_eqns = 0
    for case in target.cases:
        try:
            fn, args = case.make()
            closed = jax_mod.make_jaxpr(fn)(*args)
        except Exception as exc:
            issues.add(
                "trace-error",
                f"case {case.label!r} failed to build/trace: {exc!r} — "
                "the registry must stay runnable on every lint host",
            )
            continue
        _check_launch(issues, case.label, closed)
        _check_callbacks(issues, case.label, closed)
        _check_dtypes(issues, case.label, closed)
        max_eqns = max(max_eqns, count_eqns(closed.jaxpr))
    findings = issues.findings()
    entry = budgets.get(target.name)
    if entry is None:
        findings.append(Finding(
            checker=CHECKER, path=MANIFEST_FILENAME, line=1,
            scope=target.name, code="missing-eqn-budget",
            message=(
                f"target {target.name!r} has no entry in "
                f"{MANIFEST_FILENAME} — record its equation budget "
                f"(measured {max_eqns} eqn(s); leave ~30% headroom for "
                "jax-version drift)"
            ),
        ))
    elif max_eqns > entry.max_eqns:
        findings.append(Finding(
            checker=CHECKER, path=MANIFEST_FILENAME, line=entry.line,
            scope=target.name, code="eqn-budget-exceeded",
            message=(
                f"target {target.name!r} traces to {max_eqns} eqn(s), "
                f"budget is {entry.max_eqns} — the kernel grew; either "
                "a host round-trip/unrolling crept in (fix it) or the "
                "growth is intentional (raise the budget in review)"
            ),
        ))
    return findings


# ---------------------------------------------------------------------------
# AST retrace pass
# ---------------------------------------------------------------------------


def _jit_decoration(node: ast.AST) -> tuple[bool, set[str], set[int]]:
    """(is jax.jit, static_argnames, static_argnums) of a decorator or
    wrapper expression: ``jax.jit`` / ``jit`` / ``jax.jit(...)`` /
    ``(functools.)partial(jax.jit, ...)``."""
    chain = dotted_name(node)
    if chain in ("jax.jit", "jit"):
        return True, set(), set()
    if isinstance(node, ast.Call):
        fchain = dotted_name(node.func)
        inner_jit = False
        if fchain in ("jax.jit", "jit"):
            inner_jit = True
        elif fchain in ("functools.partial", "partial") and node.args:
            if dotted_name(node.args[0]) in ("jax.jit", "jit"):
                inner_jit = True
        if inner_jit:
            names: set[str] = set()
            nums: set[int] = set()
            for kw in node.keywords:
                if kw.arg == "static_argnames":
                    names |= _str_constants(kw.value)
                elif kw.arg == "static_argnums":
                    nums |= _int_constants(kw.value)
            return True, names, nums
    return False, set(), set()


def _str_constants(node: ast.AST) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.add(sub.value)
    return out


def _int_constants(node: ast.AST) -> set[int]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, int) \
                and not isinstance(sub.value, bool):
            out.add(sub.value)
    return out


def _param_names(node: ast.AST) -> list[str]:
    a = node.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def _mentions_traced(node: ast.AST, traced: set[str]) -> bool:
    """Does the expression read a traced value non-statically?"""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Call):
        fname = node.func.id if isinstance(node.func, ast.Name) else None
        if fname in _STATIC_WRAPPERS:
            return False
    if isinstance(node, ast.Name):
        return node.id in traced
    return any(
        _mentions_traced(child, traced)
        for child in ast.iter_child_nodes(node)
    )


def _is_unhashable_expr(node: ast.AST,
                        module_arrays: dict[str, int]) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        chain = dotted_name(node.func) or ""
        head = chain.split(".", 1)[0]
        leaf = chain.rsplit(".", 1)[-1]
        if head in ("np", "numpy", "jnp") and leaf in (
            "array", "asarray", "zeros", "ones", "empty", "full", "arange",
        ):
            return True
    if isinstance(node, ast.Name) and node.id in module_arrays:
        return True
    return False


class _ModuleRetraceScan:
    """One scanned module: device-scope closure + the three findings."""

    def __init__(self, tree: ast.Module, path: str) -> None:
        self.tree = tree
        self.path = path
        self.findings: list[Finding] = []
        self.module_funcs: dict[str, ast.FunctionDef] = {}
        self.module_arrays: dict[str, int] = {}  # name -> lineno
        # callable name -> (static names, static nums): jit-decorated
        # defs plus ``alias = jax.jit(fn, ...)`` wrapper aliases (call
        # sites go through these names).
        self.jitted: dict[str, tuple[set[str], set[int]]] = {}
        # def names that run under trace (decorated defs AND the
        # ``fn`` inside wrapper assigns) — the device-scope seeds.
        self.device_seeds: dict[str, tuple[set[str], set[int]]] = {}
        self._collect_module_level()

    def _collect_module_level(self) -> None:
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.module_funcs[node.name] = node
                for deco in node.decorator_list:
                    is_jit, names, nums = _jit_decoration(deco)
                    if is_jit:
                        self.jitted[node.name] = (names, nums)
                        self.device_seeds[node.name] = (names, nums)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                value = node.value
                chain = dotted_name(getattr(value, "func", value)) or ""
                if isinstance(value, ast.Call) \
                        and chain.split(".", 1)[0] in ("np", "numpy"):
                    self.module_arrays[name] = node.lineno
                is_jit, names, nums = _jit_decoration(value)
                if is_jit and isinstance(value, ast.Call) and value.args:
                    # name = jax.jit(fn, static_arg...=...) wrapper:
                    # call sites use the alias; ``fn`` runs under trace.
                    self.jitted[name] = (names, nums)
                    wrapped = value.args[0]
                    if isinstance(wrapped, ast.Name):
                        self.device_seeds[wrapped.id] = (names, nums)

    def _emit(self, node: ast.AST, scope: str, code: str,
              message: str) -> None:
        self.findings.append(Finding(
            checker=CHECKER, path=self.path,
            line=getattr(node, "lineno", 0), scope=scope,
            code=code, message=message,
        ))

    def run(self) -> list[Finding]:
        seeds: list[tuple[ast.FunctionDef, set[str]]] = []
        for name, (static_names, static_nums) in self.device_seeds.items():
            fndef = self.module_funcs.get(name)
            if fndef is None:
                continue
            params = _param_names(fndef)
            traced = {
                p for i, p in enumerate(params)
                if p not in static_names and i not in static_nums
            }
            seeds.append((fndef, traced))
        visited: set[str] = {fndef.name for fndef, _ in seeds}
        queue = list(seeds)
        while queue:
            fndef, traced = queue.pop()
            called = self._scan_device_scope(fndef, traced, fndef.name)
            for name in called:
                if name in visited:
                    continue
                callee = self.module_funcs.get(name)
                if callee is None:
                    continue
                visited.add(name)
                queue.append((callee, set(_param_names(callee))))
        self._scan_static_call_sites()
        return self.findings

    def _scan_device_scope(self, fndef: ast.AST, traced: set[str],
                           scope: str) -> set[str]:
        """Findings inside one device-scope function; returns the
        module-local function names it calls (closure expansion).
        Nested defs are device scope too (they trace with the parent),
        with their own params joining the traced set."""
        called: set[str] = set()

        def walk(node: ast.AST, traced: set[str], scope: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    inner = traced | set(_param_names(child))
                    walk(child, inner, f"{scope}.{child.name}")
                    continue
                if isinstance(child, (ast.If, ast.While)):
                    self._check_branch(child.test, child, traced, scope)
                elif isinstance(child, ast.IfExp):
                    self._check_branch(child.test, child, traced, scope)
                elif isinstance(child, ast.Assert):
                    self._check_branch(child.test, child, traced, scope)
                if isinstance(child, ast.Call) \
                        and isinstance(child.func, ast.Name) \
                        and child.func.id in self.module_funcs:
                    called.add(child.func.id)
                if isinstance(child, ast.Name) \
                        and isinstance(child.ctx, ast.Load) \
                        and child.id in self.module_arrays:
                    self._emit(
                        child, scope, "closure-captured-array",
                        f"device scope reads module-level numpy array "
                        f"{child.id!r} (defined at line "
                        f"{self.module_arrays[child.id]}) — it is baked "
                        "into the compiled program as a constant; pass "
                        "it as an argument so rebinding cannot silently "
                        "serve stale results",
                    )
                walk(child, traced, scope)

        walk(fndef, traced, scope)
        return called

    def _check_branch(self, test: ast.AST, node: ast.AST,
                      traced: set[str], scope: str) -> None:
        if _mentions_traced(test, traced):
            kind = type(node).__name__.lower()
            self._emit(
                node, scope, "traced-python-branch",
                f"Python {kind} branches on a traced value — this "
                "concretizes the tracer (error or per-value retrace); "
                "use lax.cond/jnp.where, or read only static "
                "shape/dtype attributes in the test",
            )

    def _scan_static_call_sites(self) -> None:
        if not any(names or nums for names, nums in self.jitted.values()):
            return
        scopes: list[str] = []

        class V(ast.NodeVisitor):
            def visit_FunctionDef(v, node):  # noqa: N805
                scopes.append(node.name)
                v.generic_visit(node)
                scopes.pop()

            visit_AsyncFunctionDef = visit_FunctionDef
            visit_ClassDef = visit_FunctionDef

            def visit_Call(v, node):  # noqa: N805
                if isinstance(node.func, ast.Name) \
                        and node.func.id in self.jitted:
                    names, nums = self.jitted[node.func.id]
                    scope = ".".join(scopes) or "<module>"
                    for i, arg in enumerate(node.args):
                        if i in nums and _is_unhashable_expr(
                                arg, self.module_arrays):
                            self._emit_static(node, scope, i)
                    for kw in node.keywords:
                        if kw.arg in names and _is_unhashable_expr(
                                kw.value, self.module_arrays):
                            self._emit_static(node, scope, kw.arg)
                v.generic_visit(node)

        V().visit(self.tree)

    def _emit_static(self, node: ast.Call, scope: str,
                     which: int | str) -> None:
        self._emit(
            node, scope, "unhashable-static-arg",
            f"static argument {which!r} of {node.func.id} receives an "
            "unhashable/array-valued expression — static args key the "
            "jit cache by hash; pass a hashable scalar/tuple or make "
            "the argument traced",
        )


def _retrace_ast_pass(root: Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_python_files(root, RETRACE_SCAN_DIRS):
        tree = parse_file(path)
        if tree is None:
            continue
        findings.extend(_ModuleRetraceScan(tree, rel(path, root)).run())
    return findings


# ---------------------------------------------------------------------------
# Harness + metrics (tests and benchmarks; not part of check())
# ---------------------------------------------------------------------------


def count_compilations(fn: Callable, arg_sets: Sequence[tuple]) -> int:
    """Compilations a *fresh* jit of ``fn`` performs over ``arg_sets``.
    ``fn`` may already be jitted (its ``__wrapped__`` is unwrapped),
    and the unwrapped function is re-wrapped through a new closure:
    jit's compilation cache is keyed by function identity, so reusing
    the original object would inherit — and count — every compilation
    prior callers already paid. The retrace contract: the result
    equals the number of distinct shape signatures in ``arg_sets``."""
    import jax

    inner = getattr(fn, "__wrapped__", fn)

    def fresh(*args):
        return inner(*args)

    jitted = jax.jit(fresh)
    for args in arg_sets:
        jitted(*args)
    return int(jitted._cache_size())


def _deepest_while(jaxpr: Any) -> Any | None:
    best, best_depth = None, -1
    for sub, depth in iter_jaxprs(jaxpr):
        for eqn in sub.eqns:
            if str(eqn.primitive) == "while" and depth >= best_depth:
                best, best_depth = eqn, depth
    return best


def waterfill_metrics(closed: Any) -> dict[str, int]:
    """Pallas-readiness numbers for the water-filling round, read off
    the jaxpr statically: the innermost ``while`` is the water-fill
    loop (its body is the 2x-unrolled round pair).

    ``waterfill_carry_bytes``     carried state crossing each round
                                  pair (what a fused kernel keeps
                                  resident in registers/VMEM);
    ``waterfill_operand_bytes``   loop-invariant operands (tables,
                                  capacities) re-read every round;
    ``waterfill_roundpair_bytes`` total IR-level operand+result bytes
                                  of the round-pair body — the
                                  HLO-boundary traffic the Pallas
                                  kernel (ROADMAP item 1) removes.
    """
    eqn = _deepest_while(closed.jaxpr)
    if eqn is None:
        return {}
    body = eqn.params["body_jaxpr"].jaxpr
    nconsts = int(eqn.params.get("body_nconsts", 0))
    consts, carry = body.invars[:nconsts], body.invars[nconsts:]
    moved = 0
    for body_eqn in body.eqns:
        for var in body_eqn.invars:
            if not _is_literal(var):
                moved += _aval_bytes(getattr(var, "aval", None))
        for var in body_eqn.outvars:
            moved += _aval_bytes(getattr(var, "aval", None))
    return {
        "waterfill_carry_bytes": sum(
            _aval_bytes(v.aval) for v in carry
        ),
        "waterfill_operand_bytes": sum(
            _aval_bytes(v.aval) for v in consts
        ),
        "waterfill_roundpair_bytes": moved,
    }


def collect_metrics(root: Path | None = None) -> dict[str, int]:
    """Per-target eqn counts plus water-fill bytes, at each target's
    *first* (canonical) case shapes — the numbers
    ``benchmarks/analysis_bench.py`` emits for the nightly trend."""
    import jax

    root = (root or repo_root()).resolve()
    targets, findings = _load_targets(root)
    if findings:
        raise RuntimeError(findings[0].message)
    metrics: dict[str, int] = {}
    for target in targets:
        fn, args = target.cases[0].make()
        closed = jax.make_jaxpr(fn)(*args)
        key = "eqns_" + target.name.replace("-", "_")
        metrics[key] = count_eqns(closed.jaxpr)
        if target.name == "rollout-batch":
            metrics.update(waterfill_metrics(closed))
    return metrics


# ---------------------------------------------------------------------------
# Checker entry
# ---------------------------------------------------------------------------


def _try_import_jax() -> Any | None:
    try:
        import jax
    except Exception:
        return None
    return jax


def check(root: Path) -> list[Finding]:
    LAST_SKIP_NOTES.clear()
    findings = _retrace_ast_pass(root)
    jax_mod = _try_import_jax()
    if jax_mod is None:
        LAST_SKIP_NOTES.append(
            "tracelint: jax is not importable here — the jaxpr pass "
            "(dtype/launch/eqn-budget certification) was SKIPPED; the "
            "AST retrace pass still ran. Run on a host with jax before "
            "trusting the one-launch/f64 claims."
        )
        return findings
    targets, target_findings = _load_targets(root)
    findings.extend(target_findings)
    if not targets and not (root / MANIFEST_REL_PATH).is_file():
        # Tree registers no JAX entry points (and budgets none) —
        # nothing for the jaxpr pass to certify.
        return findings
    budgets, manifest_findings = load_manifest(root / MANIFEST_REL_PATH)
    findings.extend(manifest_findings)
    traced_names: set[str] = set()
    for target in targets:
        findings.extend(_trace_target(target, budgets, jax_mod))
        traced_names.add(target.name)
    for name, entry in budgets.items():
        if name not in traced_names:
            findings.append(Finding(
                checker=CHECKER, path=MANIFEST_FILENAME, line=entry.line,
                scope=name, code="stale-eqn-budget-entry",
                message=(
                    f"manifest budgets unknown target {name!r} — the "
                    "target was renamed or deleted; update the entry "
                    "(and make sure the launch certification moved "
                    "with the code)"
                ),
            ))
    return findings
