"""Host-side input pipeline: sharded batch assembly + prefetch.

Production shape: each host builds only ITS shard of the global batch
(process-local agents × local microbatches), places it via
``jax.device_put`` onto the per-cell NamedShardings, and a small
background thread keeps ``prefetch`` batches in flight so step N+1's
host work overlaps step N's device work (one of the standard
compute/comm overlap levers).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.data.synthetic import SyntheticTokenStream


def make_batch_fn(
    stream: SyntheticTokenStream,
    batch_shapes: Any,
    vocab_size: int,
) -> Callable[[int], dict]:
    """Build the stacked [A, k, mb, S+1] batch dict for one step."""
    tok_shape = batch_shapes["tokens"].shape

    def fn(step: int) -> dict:
        a, k, mb, s1 = tok_shape
        toks = np.stack(
            [
                np.stack(
                    [
                        stream.batch(agent, step * k + i, mb, s1 - 1)
                        for i in range(k)
                    ]
                )
                for agent in range(a)
            ]
        )
        batch = {"tokens": toks}
        if "patch_embeds" in batch_shapes:
            pe = batch_shapes["patch_embeds"]
            rng = np.random.default_rng((step, 0xBEEF))
            batch["patch_embeds"] = rng.standard_normal(pe.shape).astype(
                np.float32
            )
        return batch

    return fn


class Prefetcher:
    """Background-thread prefetch of device-placed batches."""

    def __init__(
        self,
        batch_fn: Callable[[int], dict],
        shardings: Any,
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self._fn = batch_fn
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            host = self._fn(step)
            dev = jax.device_put(host, self._shardings)
            try:
                self._q.put((step, dev), timeout=1.0)
                step += 1
            except queue.Full:
                # retry the same (already built) batch on next loop tick
                while not self._stop.is_set():
                    try:
                        self._q.put((step, dev), timeout=1.0)
                        step += 1
                        break
                    except queue.Full:
                        continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so the worker can exit
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
