"""Data: synthetic non-IID token streams + sharded prefetch pipeline."""

from repro.data.pipeline import Prefetcher, make_batch_fn
from repro.data.synthetic import DataConfig, SyntheticTokenStream
