"""Synthetic token data with controllable non-IID agent partitions.

The paper distributes CIFAR-10 across 10 agents; for LM training we
generate deterministic synthetic token streams whose *unigram skew*
varies per agent (Dirichlet over topic mixtures), reproducing the data
heterogeneity (ζ̂ of assumption (3)) that makes decentralized mixing
matter. Everything is stateless-deterministic in (seed, agent, step) so
restarts resume identically with no data-loader checkpoints.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    num_agents: int = 1
    num_topics: int = 16
    dirichlet_alpha: float = 0.3   # smaller = more heterogeneous agents
    seed: int = 0


class SyntheticTokenStream:
    """Markov-ish topic-mixture token generator, one mixture per agent."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # Topic-conditional unigram distributions (shared across agents).
        self.topic_logits = root.standard_normal(
            (cfg.num_topics, cfg.vocab_size)
        ).astype(np.float32)
        # Per-agent topic mixtures (the non-IID knob).
        self.agent_mix = root.dirichlet(
            np.full(cfg.num_topics, cfg.dirichlet_alpha), size=cfg.num_agents
        ).astype(np.float32)

    def agent_distribution(self, agent: int) -> np.ndarray:
        logits = self.agent_mix[agent] @ self.topic_logits
        e = np.exp(logits - logits.max())
        return e / e.sum()

    def batch(
        self, agent: int, step: int, batch_size: int, seq_len: int | None = None
    ) -> np.ndarray:
        """[batch, seq_len+1] int32 tokens, deterministic in (agent, step)."""
        s = seq_len or self.cfg.seq_len
        rng = np.random.default_rng(
            (self.cfg.seed, agent, step, 0xD1F7)
        )
        p = self.agent_distribution(agent)
        return rng.choice(
            self.cfg.vocab_size, size=(batch_size, s + 1), p=p
        ).astype(np.int32)

    def stacked_batch(self, step: int, per_agent_batch: int,
                      seq_len: int | None = None) -> np.ndarray:
        """[num_agents, per_agent_batch, seq+1] for the stacked trainer."""
        return np.stack(
            [
                self.batch(a, step, per_agent_batch, seq_len)
                for a in range(self.cfg.num_agents)
            ]
        )

    def heterogeneity(self) -> float:
        """Mean TV-distance between agent unigram distributions — an
        observable proxy for ζ̂."""
        dists = [
            self.agent_distribution(a) for a in range(self.cfg.num_agents)
        ]
        mean = np.mean(dists, axis=0)
        return float(
            np.mean([0.5 * np.abs(d - mean).sum() for d in dists])
        )
